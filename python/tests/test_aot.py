"""AOT path tests: HLO text is emitted, parseable-looking, and the
manifest is complete and consistent. (The authoritative load test is on
the Rust side — rust/tests/runtime_pjrt.rs compiles and runs these
artifacts through PJRT.)"""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestManifest:
    def test_tiny_config_artifacts_present(self):
        man = manifest()
        names = {a["name"] for a in man["artifacts"]}
        assert "fwdbwd_tiny" in names
        assert any(n.startswith("rsvd_tiny_") for n in names)
        assert any(n.startswith("lowrank_adam_tiny_") for n in names)
        assert "adam_full_tiny_embed" in names

    def test_files_exist_and_look_like_hlo(self):
        man = manifest()
        for a in man["artifacts"]:
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), a["file"]
            head = open(path).read(200)
            assert "HloModule" in head, f"{a['file']} missing HloModule header"

    def test_input_output_specs_are_consistent(self):
        man = manifest()
        for a in man["artifacts"]:
            assert len(a["inputs"]) > 0 and len(a["outputs"]) > 0
            for s in a["inputs"] + a["outputs"]:
                assert "shape" in s and "dtype" in s

    def test_fwdbwd_grads_mirror_params(self):
        man = manifest()
        cfg = man["configs"]["tiny"]
        fb = next(a for a in man["artifacts"] if a["name"] == "fwdbwd_tiny")
        n_params = len(cfg["params"])
        # inputs: params + tokens + targets; outputs: loss + grads
        assert len(fb["inputs"]) == n_params + 2
        assert len(fb["outputs"]) == n_params + 1
        for p, g in zip(cfg["params"], fb["outputs"][1:]):
            assert p["shape"] == g["shape"]

    def test_lowrank_adam_shapes(self):
        man = manifest()
        for a in man["artifacts"]:
            if not a["name"].startswith("lowrank_adam_"):
                continue
            m, n, r = a["m"], a["n"], a["rank"]
            low = [r, n] if a["side_left"] else [m, r]
            pshape = [m, r] if a["side_left"] else [n, r]
            ins = [s["shape"] for s in a["inputs"]]
            assert ins[0] == [m, n] and ins[1] == [m, n]
            assert ins[2] == pshape
            assert ins[3] == low and ins[4] == low and ins[5] == low
            outs = [s["shape"] for s in a["outputs"]]
            assert outs[0] == [m, n] and outs[4] == low
