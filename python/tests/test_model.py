"""L2 model/optimizer graph tests: shapes, causality, loss decrease and
the lowrank-adam step's agreement with composing the refs."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as M
from compile import optim as O
from compile.kernels import ref

CFG = M.CONFIGS["tiny"]


def make_params(seed=0):
    return M.init_params(CFG, jax.random.PRNGKey(seed))


def batch(seed=1, b=2):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (b, CFG.seq_len), 0, CFG.vocab)
    tgts = jax.random.randint(k2, (b, CFG.seq_len), 0, CFG.vocab)
    return toks, tgts


class TestModel:
    def test_param_shapes_count(self):
        shapes = CFG.param_shapes()
        # embed + 9 per layer + final_norm
        assert len(shapes) == 1 + 9 * CFG.n_layers + 1

    def test_loss_near_uniform_at_init(self):
        params = make_params()
        toks, tgts = batch()
        loss = float(M.loss_fn(params, toks, tgts, CFG))
        uniform = float(np.log(CFG.vocab))
        assert abs(loss - uniform) < 1.5, (loss, uniform)

    def test_grads_shapes_match_params(self):
        params = make_params()
        toks, tgts = batch()
        out = M.loss_and_grads(params, toks, tgts, CFG)
        assert len(out) == 1 + len(params)
        for p, g in zip(params, out[1:]):
            assert p.shape == g.shape

    def test_causality(self):
        params = make_params()
        toks, _ = batch()
        h0 = M.forward(params, toks, CFG)
        toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
        h1 = M.forward(params, toks2, CFG)
        # all positions before the last must be identical
        assert_allclose(np.asarray(h0[:, :-1]), np.asarray(h1[:, :-1]),
                        rtol=1e-6, atol=1e-6)
        assert np.abs(np.asarray(h0[:, -1]) - np.asarray(h1[:, -1])).max() > 1e-6

    def test_sgd_on_grads_reduces_loss(self):
        params = make_params()
        toks, tgts = batch()
        l0 = float(M.loss_fn(params, toks, tgts, CFG))
        for _ in range(5):
            out = M.loss_and_grads(params, toks, tgts, CFG)
            params = [p - 0.5 * g for p, g in zip(params, out[1:])]
        l1 = float(M.loss_fn(params, toks, tgts, CFG))
        assert l1 < l0, (l0, l1)


class TestLowRankStep:
    def test_composes_like_refs(self):
        key = jax.random.PRNGKey(11)
        ks = jax.random.split(key, 4)
        m, n, r = 32, 48, 8
        w = jax.random.normal(ks[0], (m, n))
        g = jax.random.normal(ks[1], (m, n))
        p = jnp.linalg.qr(jax.random.normal(ks[2], (m, r)))[0]
        m0 = 0.1 * jax.random.normal(ks[3], (r, n))
        v0 = jnp.abs(0.01 * jax.random.normal(ks[3], (r, n)))
        d_init = ref.normalize_fro(jax.random.normal(ks[2], (r, n)))
        t, lr, scale = jnp.float32(3), jnp.float32(1e-3), jnp.float32(0.5)

        w2, m2, v2, disp, d_cur = O.lowrank_adam_step(
            w, g, p, m0, v0, d_init, t, lr, scale, True
        )
        # reference composition
        low = ref.project_down(p, g, True)
        rm, rv, rd = ref.adam_moments(low, m0, v0, 3, lr=1e-3)
        rw = w - 0.5 * ref.project_up(p, rd, True)
        r_dcur = ref.normalize_fro(low)
        r_disp = jnp.sqrt(jnp.sum((r_dcur - d_init) ** 2))
        assert_allclose(np.asarray(w2), np.asarray(rw), rtol=1e-4, atol=1e-5)
        assert_allclose(np.asarray(m2), np.asarray(rm), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(v2), np.asarray(rv), rtol=1e-5, atol=1e-7)
        assert_allclose(float(disp), float(r_disp), rtol=1e-4)
        assert_allclose(np.asarray(d_cur), np.asarray(r_dcur), rtol=1e-4, atol=1e-5)

    def test_update_stays_in_span(self):
        key = jax.random.PRNGKey(12)
        m, n, r = 24, 40, 6
        w = jnp.zeros((m, n))
        g = jax.random.normal(key, (m, n))
        p = jnp.linalg.qr(jax.random.normal(key, (m, r)))[0]
        z = jnp.zeros((r, n))
        w2, *_ = O.lowrank_adam_step(w, g, p, z, z, z, jnp.float32(1),
                                     jnp.float32(1e-3), jnp.float32(1.0), True)
        dw = np.asarray(w2 - w)
        # project ΔW onto span(P): P Pᵀ ΔW must equal ΔW
        pp = np.asarray(p)
        rec = pp @ (pp.T @ dw)
        assert_allclose(rec, dw, rtol=1e-4, atol=1e-6)

    def test_adam_full_step_matches_ref(self):
        key = jax.random.PRNGKey(13)
        w = jax.random.normal(key, (16, 8))
        g = jax.random.normal(key, (16, 8))
        z = jnp.zeros_like(w)
        w2, m2, v2 = O.adam_full_step(w, g, z, z, jnp.float32(1), jnp.float32(0.1))
        rm, rv, rd = ref.adam_moments(g, z, z, 1, lr=0.1)
        assert_allclose(np.asarray(w2), np.asarray(w - rd), rtol=1e-4, atol=1e-5)
        assert_allclose(np.asarray(m2), np.asarray(rm), rtol=1e-5, atol=1e-7)
        assert_allclose(np.asarray(v2), np.asarray(rv), rtol=1e-5, atol=1e-7)


class TestEncoder:
    def test_encoder_shapes_and_grads(self):
        from compile import encoder as E

        cfg = E.EncoderConfig(64, 32, 1, 2, 48, 8, 3)
        key = jax.random.PRNGKey(0)
        params = []
        for _, s in cfg.param_shapes():
            key, sub = jax.random.split(key)
            params.append(0.05 * jax.random.normal(sub, s, jnp.float32))
        toks = jax.random.randint(key, (4, cfg.seq_len), 0, cfg.vocab)
        labels = jnp.array([0, 1, 2, 1], jnp.int32)
        out = E.loss_and_grads(params, toks, labels, cfg)
        assert len(out) == 1 + len(params)
        assert np.isfinite(float(out[0]))
