"""L1 kernel correctness: hypothesis sweeps shapes; every Pallas kernel
must match its pure-jnp oracle in ref.py to f32 tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import adam_update as ak
from compile.kernels import matmul as mm
from compile.kernels import projection as pk
from compile.kernels import ref
from compile.kernels import rsvd as rk

DIM = st.integers(min_value=1, max_value=96)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = rand(k1, (m, k))
        y = rand(k2, (k, n))
        got = mm.matmul(x, y)
        want = ref.matmul(x, y)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
    def test_transposed_variants(self, m, k, n, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = rand(k1, (k, m))
        y = rand(k2, (k, n))
        assert_allclose(np.asarray(mm.matmul_tn(x, y)), np.asarray(x.T @ y),
                        rtol=2e-5, atol=2e-5)
        z = rand(k2, (n, k))
        x2 = rand(k1, (m, k))
        assert_allclose(np.asarray(mm.matmul_nt(x2, z)), np.asarray(x2 @ z.T),
                        rtol=2e-5, atol=2e-5)

    def test_mxu_structural_metrics(self):
        # perfectly-shaped tiles: full utilization
        assert mm.mxu_utilization(256, 256, 256) == 1.0
        # odd shapes degrade but stay positive
        u = mm.mxu_utilization(100, 100, 100)
        assert 0.0 < u < 1.0
        assert mm.vmem_bytes(256, 256, 256) == 4 * 3 * 128 * 128


class TestAdamFused:
    @settings(max_examples=20, deadline=None)
    @given(r=st.integers(1, 48), n=st.integers(1, 96),
           t=st.integers(1, 500), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, r, n, t, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        g = rand(keys[0], (r, n))
        m0 = rand(keys[1], (r, n), 0.1)
        v0 = jnp.abs(rand(keys[2], (r, n), 0.01))
        hp = jnp.array([1e-3, 0.9, 0.999, 1e-8], jnp.float32)
        m2, v2, d = ak.adam_update(g, m0, v0, jnp.float32(t), hp)
        rm, rv, rd = ref.adam_moments(g, m0, v0, t)
        assert_allclose(np.asarray(m2), np.asarray(rm), rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(v2), np.asarray(rv), rtol=1e-5, atol=1e-7)
        assert_allclose(np.asarray(d), np.asarray(rd), rtol=2e-4, atol=1e-7)

    def test_first_step_direction_is_lr_sign(self):
        g = jnp.array([[3.0, -2.0, 0.0]], jnp.float32)
        hp = jnp.array([0.1, 0.9, 0.999, 1e-8], jnp.float32)
        _, _, d = ak.adam_update(g, jnp.zeros_like(g), jnp.zeros_like(g),
                                 jnp.float32(1), hp)
        np.testing.assert_allclose(np.asarray(d)[0, :2], [0.1, -0.1], rtol=1e-3)
        assert float(d[0, 2]) == 0.0


class TestProjection:
    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(2, 64), n=st.integers(2, 64), r=st.integers(1, 16),
           seed=st.integers(0, 2**31 - 1))
    def test_down_up_both_sides(self, m, n, r, seed):
        r = min(r, m, n)
        keys = jax.random.split(jax.random.PRNGKey(seed), 2)
        g = rand(keys[0], (m, n))
        for side_left in (True, False):
            dim = m if side_left else n
            p = jnp.linalg.qr(rand(keys[1], (dim, r)))[0]
            low = pk.project_down(p, g, side_left)
            want_low = ref.project_down(p, g, side_left)
            assert_allclose(np.asarray(low), np.asarray(want_low),
                            rtol=2e-5, atol=2e-5)
            up = pk.project_up(p, low, side_left)
            want_up = ref.project_up(p, want_low, side_left)
            assert_allclose(np.asarray(up), np.asarray(want_up),
                            rtol=2e-5, atol=2e-5)
            assert up.shape == (m, n)


class TestRsvd:
    def test_orthonormal_and_captures_subspace(self):
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        # strongly low-rank signal + noise
        u = jnp.linalg.qr(rand(k1, (80, 4)))[0]
        vt = rand(k2, (4, 60))
        g = 10.0 * (u @ vt) + 0.05 * rand(k3, (80, 60))
        p = rk.rsvd_range(g, key, 4, oversample=4, power_iters=2)
        # orthonormal
        eye_err = np.abs(np.asarray(p.T @ p) - np.eye(4)).max()
        assert eye_err < 1e-4
        # principal angle vs the planted basis
        s = np.linalg.svd(np.asarray(p.T @ u), compute_uv=False)
        assert s.min() > 0.999

    def test_matches_ref_same_key(self):
        # MGS (kernel) and Householder QR (ref) agree on the *subspace*
        # (P Pᵀ), though individual columns may differ in sign.
        key = jax.random.PRNGKey(7)
        g = rand(key, (48, 32))
        got = np.asarray(rk.rsvd_range(g, key, 8, 4, 1))
        want = np.asarray(ref.rsvd_range(g, key, 8, 4, 1))
        assert_allclose(got @ got.T, want @ want.T, rtol=2e-3, atol=2e-3)

    def test_mgs_orthonormalizes(self):
        key = jax.random.PRNGKey(8)
        y = rand(key, (40, 12))
        q = np.asarray(rk.mgs_orthonormalize(y))
        assert_allclose(q.T @ q, np.eye(12), atol=2e-5)
        # spans the same space as the input
        proj = q @ (q.T @ np.asarray(y))
        assert_allclose(proj, np.asarray(y), rtol=1e-3, atol=1e-3)

    def test_projector_with_dinit_both_sides(self):
        key = jax.random.PRNGKey(9)
        g = rand(key, (40, 64))
        p, d = rk.rsvd_projector_with_dinit(g, key, 8, True)
        assert p.shape == (40, 8) and d.shape == (8, 64)
        assert abs(float(jnp.sum(d * d)) - 1.0) < 1e-4  # unit Frobenius
        gt = g.T
        p2, d2 = rk.rsvd_projector_with_dinit(gt, key, 8, False)
        assert p2.shape == (40, 8) and d2.shape == (64, 8)


class TestDisplacement:
    def test_unit_displacement_scale_invariant(self):
        key = jax.random.PRNGKey(3)
        g = rand(key, (8, 16))
        d0 = ref.normalize_fro(rand(jax.random.PRNGKey(4), (8, 16)))
        a = ref.unit_displacement(g, d0, 10.0)
        b = ref.unit_displacement(1000.0 * g, d0, 10.0)
        assert abs(float(a) - float(b)) < 1e-5

    def test_zero_displacement_for_same_direction(self):
        key = jax.random.PRNGKey(5)
        g = rand(key, (8, 16))
        d0 = ref.normalize_fro(g)
        assert float(ref.unit_displacement(3.0 * g, d0, 5.0)) < 1e-6
