"""L2 JAX model: LLaMA-flavoured decoder LM, numerically identical to the
Rust simulator (`rust/src/sim/model.rs`): tied embedding, RMSNorm
(eps 1e-5), causal MHA with ALiBi bias (slope 2^(-8(h+1)/H)), SwiGLU FFN.
`rust/tests/runtime_pjrt.rs` uploads identical weights to both paths and
asserts the losses/gradients agree.

Params are a flat list (PJRT-friendly), layout shared with Rust:
  [embed, (wq wk wv wo w1 w3 w2 norm1 norm2) × L, final_norm]
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5


@dataclass(frozen=True)
class LlamaConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self):
        """Flat parameter layout (name, shape), matching the Rust side."""
        d, f = self.d_model, self.d_ff
        shapes = [("embed", (self.vocab, d))]
        for l in range(self.n_layers):
            shapes += [
                (f"layer{l}.wq", (d, d)),
                (f"layer{l}.wk", (d, d)),
                (f"layer{l}.wv", (d, d)),
                (f"layer{l}.wo", (d, d)),
                (f"layer{l}.w1", (d, f)),
                (f"layer{l}.w3", (d, f)),
                (f"layer{l}.w2", (f, d)),
                (f"layer{l}.norm1", (d,)),
                (f"layer{l}.norm2", (d,)),
            ]
        shapes.append(("final_norm", (d,)))
        return shapes


# 60M/130M-family scaled configs, mirrored from rust/src/models/mod.rs.
CONFIGS = {
    "tiny": LlamaConfig(512, 128, 2, 4, 344, 64),
    "mini": LlamaConfig(2048, 256, 4, 8, 688, 128),
    "20m": LlamaConfig(4096, 384, 6, 8, 1024, 128),
    "100m": LlamaConfig(8192, 768, 12, 12, 2048, 128),
}


def init_params(cfg: LlamaConfig, key):
    """LLaMA-style init (1/sqrt(fan_in); damped output projections)."""
    params = []
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith(("norm1", "norm2")) or name == "final_norm":
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("wo", "w2")):
            fan_in = shape[0]
            std = (1.0 / fan_in) ** 0.5 / (2.0 * cfg.n_layers) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[1] if name == "embed" else shape[0]
            std = (1.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def rmsnorm(x, g):
    r = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)
    return g * x / r


def alibi_slopes(n_heads: int):
    h = jnp.arange(1, n_heads + 1, dtype=jnp.float32)
    return 2.0 ** (-8.0 * h / n_heads)


def attention(x, wq, wk, wv, wo, cfg: LlamaConfig):
    """Causal multi-head attention with ALiBi bias. x: (B, T, d)."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)  # B H T hd
    k = (x @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(
        jnp.asarray(hd, jnp.float32)
    )
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    dist = (i - j).astype(jnp.float32)
    slopes = alibi_slopes(h)[:, None, None]
    scores = scores - slopes[None] * dist[None, None]
    causal = j <= i
    scores = jnp.where(causal[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def swiglu(x, w1, w3, w2):
    a = x @ w1
    return (a * jax.nn.sigmoid(a) * (x @ w3)) @ w2


def forward(params, tokens, cfg: LlamaConfig):
    """Final hidden states (B, T, d) before the tied head."""
    embed = params[0]
    x = embed[tokens]  # B T d
    per = 9
    for l in range(cfg.n_layers):
        base = 1 + l * per
        wq, wk, wv, wo, w1, w3, w2, n1, n2 = params[base : base + per]
        xa = attention(rmsnorm(x, n1), wq, wk, wv, wo, cfg)
        x = x + xa
        xf = swiglu(rmsnorm(x, n2), w1, w3, w2)
        x = x + xf
    return rmsnorm(x, params[-1])


def loss_fn(params, tokens, targets, cfg: LlamaConfig):
    """Mean next-token cross-entropy (nats) over all positions."""
    xf = forward(params, tokens, cfg)
    logits = xf @ params[0].T  # tied head
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def logits_fn(params, tokens, cfg: LlamaConfig):
    xf = forward(params, tokens, cfg)
    return xf @ params[0].T


@partial(jax.jit, static_argnames=("cfg",))
def loss_and_grads(params, tokens, targets, cfg: LlamaConfig):
    """The `fwdbwd` artifact body: (loss, *grads) in param order."""
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets, cfg)
    return (loss, *grads)
