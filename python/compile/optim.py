"""L2 optimizer-step graphs — the artifacts the Rust coordinator calls on
the training hot path. Each composes the L1 Pallas kernels:

* ``lowrank_adam_step`` — project G down (Pallas), fused low-rank Adam
  (Pallas), lift the step back up (Pallas), apply to W, and emit the
  Lotus displacement statistic the L3 switching policy consumes.
* ``rsvd_fit`` — Lotus's projector refresh (Pallas GEMM range finder).
* ``adam_full_step`` — full-rank Adam baseline (used by the GaLore-path
  embedding/vector updates and the Full-Rank method).
"""

import jax.numpy as jnp

from .kernels import adam_update as ak
from .kernels import projection as pk
from .kernels import rsvd as rk


def lowrank_adam_step(w, g, p, m, v, d_init, t, lr, scale, side_left: bool,
                      beta1=0.9, beta2=0.999, eps=1e-8):
    """One projected Adam step (GaLore/Lotus shared math).

    Returns (w', m', v', disp, d_cur):
      disp  = ‖normalize(R) − d_init‖_F   (Algorithm 1's Δd norm; the L3
              policy divides by its projection count T)
      d_cur = normalize(R), so Rust can roll the subspace state forward.
    """
    r = pk.project_down(p, g, side_left)
    hp = jnp.stack([lr, jnp.asarray(beta1, jnp.float32),
                    jnp.asarray(beta2, jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    m2, v2, direction = ak.adam_update(r, m, v, t, hp)
    full_dir = pk.project_up(p, direction, side_left)
    w2 = w - scale * full_dir
    norm = jnp.sqrt(jnp.sum(r * r))
    d_cur = r / jnp.maximum(norm, 1e-30)
    disp = jnp.sqrt(jnp.sum((d_cur - d_init) ** 2))
    return w2, m2, v2, disp, d_cur


def rsvd_fit(g, key, rank: int, side_left: bool, oversample: int = 4,
             power_iters: int = 1):
    """Projector refresh: (P, d_init) from the current full-rank grad."""
    return rk.rsvd_projector_with_dinit(
        g, key, rank, side_left, oversample, power_iters
    )


def adam_full_step(w, g, m, v, t, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Full-rank fused Adam step via the same Pallas kernel."""
    hp = jnp.stack([lr, jnp.asarray(beta1, jnp.float32),
                    jnp.asarray(beta2, jnp.float32),
                    jnp.asarray(eps, jnp.float32)])
    m2, v2, direction = ak.adam_update(g, m, v, t, hp)
    return w - direction, m2, v2
