"""L1 projection kernels: gradient ↔ subspace maps built on the Pallas
tiled matmul. Thin wrappers, but kept as named kernels so the lowered
HLO is recognisable and the per-kernel VMEM accounting stays explicit.
"""

from . import matmul as mm


def project_down(p, g, side_left: bool):
    """R = Pᵀ G (left) or G P (right) — full-rank grad into the subspace."""
    return mm.matmul_tn(p, g) if side_left else mm.matmul(g, p)


def project_up(p, r, side_left: bool):
    """G̃ = P R (left) or R Pᵀ (right) — lift the update back."""
    return mm.matmul(p, r) if side_left else mm.matmul_nt(r, p)
