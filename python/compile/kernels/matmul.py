"""L1 Pallas tiled matmul — the building block for the rSVD range finder
and the projection kernels.

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks are sized for the
128×128 MXU; the k-loop accumulates into the resident output tile so each
output tile is written back to HBM once. On this testbed kernels run
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls), so the
BlockSpec schedule is validated structurally (``vmem_bytes`` /
``mxu_utilization`` feed EXPERIMENTS.md §Perf) and numerically against
``ref.py``.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: o_tile += x_tile @ y_tile.

    The output BlockSpec maps every k to the same (i, j) tile, so the
    tile stays resident (VMEM on TPU) across the k loop.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (MXU-friendly when
    possible, and always exact so no padding is needed)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = x @ y via the Pallas kernel. Shapes (m, k) @ (k, n) in f32."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def matmul_tn(x, y, **kw):
    """C = xᵀ @ y (x stored (m, k) → result (k, n))."""
    return matmul(x.T, y, **kw)


def matmul_nt(x, y, **kw):
    """C = x @ yᵀ (y stored (n, k) → result (m, n))."""
    return matmul(x, y.T, **kw)


def vmem_bytes(m: int, n: int, k: int, bm: int = 128, bn: int = 128, bk: int = 128) -> int:
    """Estimated VMEM working set per grid step (x tile + y tile + out
    tile, f32) — the L1 perf metric recorded in EXPERIMENTS.md §Perf."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int = 128, bn: int = 128, bk: int = 128) -> float:
    """Fraction of the 128×128 MXU a tile-step occupies — structural
    estimate (1.0 = perfectly shaped tiles)."""
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return min(bm / 128.0, 1.0) * min(bn / 128.0, 1.0) * min(bk / 128.0, 1.0)
