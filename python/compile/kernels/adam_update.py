"""L1 fused low-rank Adam kernel.

One elementwise pass over the projected gradient R and the subspace
moments (m, v): update both moments, apply bias correction and emit the
lr-scaled step direction. Fusing the three outputs means R, m, v stream
through VMEM exactly once per step (the CUDA version's "one kernel
launch" becomes "one HBM pass" on TPU — DESIGN.md §Hardware-Adaptation).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adam_kernel(r_ref, m_ref, v_ref, t_ref, hp_ref, m2_ref, v2_ref, dir_ref):
    """hp = [lr, beta1, beta2, eps] broadcast from SMEM-like operands."""
    r = r_ref[...]
    lr = hp_ref[0]
    b1 = hp_ref[1]
    b2 = hp_ref[2]
    eps = hp_ref[3]
    t = t_ref[0]
    m2 = b1 * m_ref[...] + (1.0 - b1) * r
    v2 = b2 * v_ref[...] + (1.0 - b2) * r * r
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)
    mhat = m2 / c1
    vhat = jnp.sqrt(v2 / c2) + eps
    m2_ref[...] = m2
    v2_ref[...] = v2
    dir_ref[...] = lr * mhat / vhat


def _pick_block(dim, target):
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@partial(jax.jit, static_argnames=("bm", "bn"))
def adam_update(r, m, v, t, hp, *, bm: int = 256, bn: int = 256):
    """Fused low-rank Adam: returns (m', v', direction).

    r, m, v: (rows, cols) f32 in the projected space.
    t: () f32 step count (1-based, for bias correction).
    hp: (4,) f32 = [lr, beta1, beta2, eps].
    """
    rows, cols = r.shape
    bm = _pick_block(rows, bm)
    bn = _pick_block(cols, bn)
    grid = (rows // bm, cols // bn)
    shape = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    scalar_t = pl.BlockSpec((1,), lambda i, j: (0,))
    scalar_hp = pl.BlockSpec((4,), lambda i, j: (0,))
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, scalar_t, scalar_hp],
        out_specs=(tile, tile, tile),
        out_shape=(shape, shape, shape),
        interpret=True,
    )(r, m, v, jnp.reshape(t, (1,)), hp)


def vmem_bytes(rows, cols, bm=256, bn=256):
    """VMEM working set per grid step: 3 input tiles + 3 output tiles."""
    bm = _pick_block(rows, bm)
    bn = _pick_block(cols, bn)
    return 4 * 6 * bm * bn
