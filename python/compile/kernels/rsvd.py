"""L1/L2 randomized-SVD projector — Lotus's replacement for GaLore's
exact SVD (§3.2 of the paper).

The O(r·mn) GEMMs (sketch + power iterations) run through the Pallas
tiled matmul (`kernels.matmul`); the O(m·l²) thin QR between iterations
stays at L2 (`jnp.linalg.qr`) — it is not the hot spot and XLA's QR is
already fused. On TPU the test matrix Ω (n×l) and the sketch panel
(m×l) are the VMEM residents; G streams tile-by-tile.
"""

import jax
import jax.numpy as jnp

from . import matmul as mm


def mgs_orthonormalize(y):
    """Orthonormalize the columns of y (m×l) by two-pass classical
    Gram–Schmidt (CGS2), in pure jnp ops.

    Deliberately NOT `jnp.linalg.qr`: on CPU that lowers to a LAPACK
    typed-FFI custom call which xla_extension 0.5.1 (behind the `xla`
    crate) cannot compile ("Unknown custom-call API version").

    Structure matters for compile time (§Perf L1 iteration 1): a naive
    column-by-column MGS unrolls to O(l²) HLO ops — the lowered rsvd
    artifact was 4.3 MB of HLO text and took minutes to compile in the
    Rust engine. Here Q is a zero-padded m×l panel updated in place, so
    each column orthogonalizes against the *whole* panel with two GEMVs
    (zero columns contribute nothing): O(l) HLO ops, same O(m·l²) FLOPs.
    CGS2 ("twice is enough") gives MGS-grade stability in f32.
    """
    m, l = y.shape
    q = jnp.zeros_like(y)
    for j in range(l):
        v = y[:, j]
        for _pass in range(2):  # CGS2 for f32 stability
            v = v - q @ (q.T @ v)
        norm = jnp.sqrt(jnp.sum(v * v))
        # guard rank-deficient sketches: zero column stays zero
        v = v / jnp.maximum(norm, 1e-30)
        q = q.at[:, j].set(v)
    return q


def rsvd_range(g, key, rank: int, oversample: int = 4, power_iters: int = 1):
    """Orthonormal P (m×rank) ≈ dominant left subspace of g (m×n)."""
    m, n = g.shape
    l = min(rank + oversample, m, n)
    omega = jax.random.normal(key, (n, l), dtype=jnp.float32) / jnp.sqrt(
        jnp.asarray(l, jnp.float32)
    )
    y = mm.matmul(g, omega)  # sketch: Pallas GEMM
    for _ in range(power_iters):
        q = mgs_orthonormalize(y)
        z = mm.matmul_tn(g, q)  # Gᵀ Q : Pallas GEMM
        qz = mgs_orthonormalize(z)
        y = mm.matmul(g, qz)  # G Qz : Pallas GEMM
    q = mgs_orthonormalize(y)
    return q[:, :rank]


def rsvd_projector_with_dinit(g, key, rank: int, side_left: bool,
                              oversample: int = 4, power_iters: int = 1):
    """Fit the projector for one layer and capture Algorithm 1's
    ``d_init`` (the unit low-rank gradient at subspace birth).

    Left side (m<=n): P (m×r), low-rank grad Pᵀ G (r×n).
    Right side: P (n×r), low-rank grad G P (m×r).
    """
    work = g if side_left else g.T
    p = rsvd_range(work, key, rank, oversample, power_iters)
    low = mm.matmul_tn(p, g) if side_left else mm.matmul(g, p)
    norm = jnp.sqrt(jnp.sum(low * low))
    d_init = low / jnp.maximum(norm, 1e-30)
    return p, d_init


def rsvd_flops(m: int, n: int, r: int, oversample: int = 4, q: int = 1) -> int:
    """Analytic FLOPs (matches rust/src/linalg/rsvd.rs::rsvd_flops)."""
    l = r + oversample
    gemms = (1 + 2 * q) * 2 * m * n * l
    qr = 2 * m * l * l
    return gemms + qr
