"""Pure-jnp oracles for every L1 Pallas kernel.

pytest (``python/tests/test_kernels.py``) sweeps shapes/dtypes with
hypothesis and asserts each kernel matches its oracle to float32
tolerance. These are also the semantics the Rust simulator re-implements
(``rust/src/linalg``, ``rust/src/optim``).
"""

import jax
import jax.numpy as jnp


def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def project_down(p, g, side_left: bool):
    """R = Pᵀ G (left) or G P (right)."""
    return p.T @ g if side_left else g @ p


def project_up(p, r, side_left: bool):
    """G̃ = P R (left) or R Pᵀ (right)."""
    return p @ r if side_left else r @ p.T


def adam_moments(r, m, v, t, beta1=0.9, beta2=0.999, eps=1e-8, lr=1e-3):
    """Low-rank Adam moment update + step direction (matches
    ``rust/src/optim/adam.rs::Adam::direction``)."""
    m2 = beta1 * m + (1.0 - beta1) * r
    v2 = beta2 * v + (1.0 - beta2) * r * r
    c1 = 1.0 - beta1**t
    c2 = 1.0 - beta2**t
    mhat = m2 / c1
    vhat = jnp.sqrt(v2 / c2) + eps
    return m2, v2, lr * mhat / vhat


def rsvd_range(g, key, rank, oversample=4, power_iters=1):
    """Randomized range finder (HMT): orthonormal P ≈ top-r left
    singular basis of g."""
    m, n = g.shape
    l = min(rank + oversample, m, n)
    omega = jax.random.normal(key, (n, l), dtype=jnp.float32) / jnp.sqrt(
        jnp.asarray(l, jnp.float32)
    )
    y = g @ omega
    for _ in range(power_iters):
        q, _ = jnp.linalg.qr(y)
        z = g.T @ q
        qz, _ = jnp.linalg.qr(z)
        y = g @ qz
    q, _ = jnp.linalg.qr(y)
    return q[:, :rank]


def normalize_fro(x, eps=1e-30):
    """x / ||x||_F (NORMALIZE in Algorithm 1)."""
    n = jnp.sqrt(jnp.sum(x * x))
    return x / jnp.maximum(n, eps)


def unit_displacement(g_cur_low, d_init, t):
    """Algorithm 1's ‖d̄‖ = ‖normalize(G_cur) − d_init‖ / T."""
    d_cur = normalize_fro(g_cur_low)
    return jnp.sqrt(jnp.sum((d_cur - d_init) ** 2)) / t
