"""L2 JAX bidirectional encoder (RoBERTa-like), mirroring
`rust/src/sim/encoder.rs`: token + learned positional embeddings,
full-attention blocks (RMSNorm/SwiGLU), mean-pool, classifier head.

Used to AOT fine-tuning artifacts for the GLUE-sim suite; the Rust sim
path is the primary engine for Table 2 (see DESIGN.md), so only the
forward/loss graphs are lowered (grads via jax.grad like model.py).
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5


@dataclass(frozen=True)
class EncoderConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    n_classes: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_shapes(self):
        d, f = self.d_model, self.d_ff
        shapes = [("embed", (self.vocab, d)), ("pos", (self.seq_len, d))]
        for l in range(self.n_layers):
            shapes += [
                (f"layer{l}.wq", (d, d)),
                (f"layer{l}.wk", (d, d)),
                (f"layer{l}.wv", (d, d)),
                (f"layer{l}.wo", (d, d)),
                (f"layer{l}.ff1", (d, f)),
                (f"layer{l}.ff3", (d, f)),
                (f"layer{l}.ff2", (f, d)),
                (f"layer{l}.norm1", (d,)),
                (f"layer{l}.norm2", (d,)),
            ]
        shapes += [("final_norm", (d,)), ("head", (d, self.n_classes))]
        return shapes


def rmsnorm(x, g):
    r = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)
    return g * x / r


def forward(params, tokens, cfg: EncoderConfig):
    embed, pos = params[0], params[1]
    b, t = tokens.shape
    x = embed[tokens] + pos[None, :t, :]
    per = 9
    h, hd = cfg.n_heads, cfg.head_dim
    for l in range(cfg.n_layers):
        base = 2 + l * per
        wq, wk, wv, wo, f1, f3, f2, n1, n2 = params[base : base + per]
        xn = rmsnorm(x, n1)
        q = (xn @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        k = (xn @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        v = (xn @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(
            jnp.asarray(hd, jnp.float32)
        )
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bhjd->bhid", p, v).transpose(0, 2, 1, 3).reshape(b, t, -1)
        x = x + o @ wo
        xn2 = rmsnorm(x, n2)
        a = xn2 @ f1
        x = x + (a * jax.nn.sigmoid(a) * (xn2 @ f3)) @ f2
    xf = rmsnorm(x, params[-2])
    pooled = jnp.mean(xf, axis=1)
    return pooled @ params[-1]  # B × C


def classify_loss(params, tokens, labels, cfg: EncoderConfig):
    logits = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


@partial(jax.jit, static_argnames=("cfg",))
def loss_and_grads(params, tokens, labels, cfg: EncoderConfig):
    loss, grads = jax.value_and_grad(classify_loss)(params, tokens, labels, cfg)
    return (loss, *grads)
