"""AOT lowering: every (function, shape) pair → HLO **text** artifact +
manifest.json for the Rust runtime.

HLO text, not `.serialize()`: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (behind the published `xla`
crate) rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids,
so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:
  python -m compile.aot --out ../artifacts [--configs tiny,20m]
                        [--vmem-report]

Artifacts per model config `c` (rank r from RANKS[c]):
  fwdbwd_<c>            (params…, tokens, targets) → (loss, grads…)
  logits_<c>            (params…, tokens) → logits          [eval path]
  lowrank_adam_<c>_<s>  per distinct layer shape s = <side>_r<r>_<m>x<n>
  rsvd_<c>_<s>          projector refresh for shape s
  adam_full_<c>_embed   full-rank Adam for the embedding table
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim as O

# Per-config projection rank (matches rust config presets).
RANKS = {"tiny": 16, "mini": 32, "20m": 64, "100m": 128}
# Per-config batch for the lowered fwdbwd graph.
BATCHES = {"tiny": 4, "mini": 8, "20m": 8, "100m": 4}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def lower_fwdbwd(cfg: M.LlamaConfig, batch: int):
    shapes = cfg.param_shapes()
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    targets = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)

    def fn(params, tokens, targets):
        return M.loss_and_grads(params, tokens, targets, cfg)

    lowered = jax.jit(fn).lower(params, tokens, targets)
    inputs = [spec(s) for _, s in shapes]
    inputs += [spec((batch, cfg.seq_len), "i32")] * 2
    outputs = [spec(())] + [spec(s) for _, s in shapes]
    return lowered, inputs, outputs


def lower_logits(cfg: M.LlamaConfig, batch: int):
    shapes = cfg.param_shapes()
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in shapes]
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)

    def fn(params, tokens):
        return (M.logits_fn(params, tokens, cfg),)

    lowered = jax.jit(fn).lower(params, tokens)
    inputs = [spec(s) for _, s in shapes] + [spec((batch, cfg.seq_len), "i32")]
    outputs = [spec((batch, cfg.seq_len, cfg.vocab))]
    return lowered, inputs, outputs


def layer_shapes(cfg: M.LlamaConfig):
    """Distinct projected-matrix (m, n) shapes in the model."""
    d, f = cfg.d_model, cfg.d_ff
    return sorted({(d, d), (d, f), (f, d)})


def lower_lowrank_adam(m, n, r):
    side_left = m <= n
    low = (r, n) if side_left else (m, r)
    pshape = (m, r) if side_left else (n, r)

    def fn(w, g, p, mm, vv, d_init, t, lr, scale):
        return O.lowrank_adam_step(w, g, p, mm, vv, d_init, t, lr, scale, side_left)

    args = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),  # w
        jax.ShapeDtypeStruct((m, n), jnp.float32),  # g
        jax.ShapeDtypeStruct(pshape, jnp.float32),  # p
        jax.ShapeDtypeStruct(low, jnp.float32),     # m
        jax.ShapeDtypeStruct(low, jnp.float32),     # v
        jax.ShapeDtypeStruct(low, jnp.float32),     # d_init
        jax.ShapeDtypeStruct((), jnp.float32),      # t
        jax.ShapeDtypeStruct((), jnp.float32),      # lr
        jax.ShapeDtypeStruct((), jnp.float32),      # scale
    ]
    lowered = jax.jit(fn).lower(*args)
    inputs = [spec((m, n)), spec((m, n)), spec(pshape), spec(low), spec(low),
              spec(low), spec(()), spec(()), spec(())]
    outputs = [spec((m, n)), spec(low), spec(low), spec(()), spec(low)]
    return lowered, inputs, outputs, side_left


def lower_rsvd(m, n, r):
    side_left = m <= n
    low = (r, n) if side_left else (m, r)
    pshape = (m, r) if side_left else (n, r)

    def fn(g, seed):
        key = jax.random.PRNGKey(seed)
        return O.rsvd_fit(g, key, r, side_left)

    args = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    lowered = jax.jit(fn).lower(*args)
    inputs = [spec((m, n)), spec((), "i32")]
    outputs = [spec(pshape), spec(low)]
    return lowered, inputs, outputs, side_left


def lower_adam_full(m, n):
    def fn(w, g, mm, vv, t, lr):
        return O.adam_full_step(w, g, mm, vv, t, lr)

    s = jax.ShapeDtypeStruct((m, n), jnp.float32)
    sc = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(s, s, s, s, sc, sc)
    inputs = [spec((m, n))] * 4 + [spec(())] * 2
    outputs = [spec((m, n))] * 3
    return lowered, inputs, outputs


def vmem_report(cfg: M.LlamaConfig, r: int):
    """L1 BlockSpec structural stats for EXPERIMENTS.md §Perf."""
    from .kernels import adam_update as ak
    from .kernels import matmul as mm

    rows = []
    for (m, n) in layer_shapes(cfg):
        side_left = m <= n
        low = (r, n) if side_left else (m, r)
        l = r + 4
        rows.append({
            "shape": [m, n],
            "rank": r,
            "sketch_gemm_vmem": mm.vmem_bytes(m, l, n),
            "sketch_gemm_mxu": mm.mxu_utilization(m, l, n),
            "project_gemm_vmem": mm.vmem_bytes(low[0], low[1], m if side_left else n),
            "adam_fused_vmem": ak.vmem_bytes(*low),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,20m")
    ap.add_argument("--vmem-report", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"artifacts": [], "configs": {}}

    def emit(name, lowered, inputs, outputs, extra=None):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        if extra:
            entry.update(extra)
        manifest["artifacts"].append(entry)
        print(f"  {name}: {len(text)} chars, {len(inputs)} in / {len(outputs)} out",
              flush=True)

    for cname in args.configs.split(","):
        cfg = M.CONFIGS[cname]
        r = RANKS[cname]
        batch = BATCHES[cname]
        print(f"[aot] config {cname}: d={cfg.d_model} L={cfg.n_layers} "
              f"V={cfg.vocab} T={cfg.seq_len} r={r} B={batch}", flush=True)
        manifest["configs"][cname] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "rank": r, "batch": batch,
            "params": [{"name": n, "shape": list(s)} for n, s in cfg.param_shapes()],
        }

        lowered, ins, outs = lower_fwdbwd(cfg, batch)
        emit(f"fwdbwd_{cname}", lowered, ins, outs)
        lowered, ins, outs = lower_logits(cfg, batch)
        emit(f"logits_{cname}", lowered, ins, outs)

        for (m, n) in layer_shapes(cfg):
            lo, ins, outs, side_left = lower_lowrank_adam(m, n, r)
            tag = f"{'L' if side_left else 'R'}_r{r}_{m}x{n}"
            emit(f"lowrank_adam_{cname}_{tag}", lo, ins, outs,
                 {"side_left": side_left, "m": m, "n": n, "rank": r})
            lo, ins, outs, side_left = lower_rsvd(m, n, r)
            emit(f"rsvd_{cname}_{tag}", lo, ins, outs,
                 {"side_left": side_left, "m": m, "n": n, "rank": r})

        lo, ins, outs = lower_adam_full(cfg.vocab, cfg.d_model)
        emit(f"adam_full_{cname}_embed", lo, ins, outs,
             {"m": cfg.vocab, "n": cfg.d_model})

        if args.vmem_report:
            manifest["configs"][cname]["vmem_report"] = vmem_report(cfg, r)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['artifacts'])} artifacts + manifest "
          f"to {args.out}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
