//! Power-iteration randomized SVD — the core numerical kernel of Lotus
//! (§3.2): replace GaLore's exact SVD of the gradient `G ∈ ℝ^{m×n}` with
//! the Halko–Martinsson–Tropp randomized range finder:
//!
//! ```text
//! Ω ~ N(0, 1/r)^{n×(r+p)}          (test matrix, p oversampling)
//! Y = G Ω                           (sketch,     O(mn(r+p)))
//! for q power iterations:           (sharpen the spectrum)
//!     Y = G (Gᵀ Y)                  (2 GEMMs each, re-orthonormalized)
//! Q = qr(Y).Q                       (O(m(r+p)²))
//! P = Q[:, :r]                      (the projector)
//! ```
//!
//! Total cost O((2q+2)·mn·(r+p)) versus Jacobi/LAPACK SVD's
//! O(min(m,n)·mn) with a much larger constant — this asymmetry is the
//! paper's 30 % end-to-end time claim. `benches/rsvd_speed.rs` measures
//! the crossover. The Pallas twin of this routine lives in
//! `python/compile/kernels/rsvd.py` and is checked against the same
//! math in `python/tests/`.

use crate::linalg::matmul::{matmul, matmul_tn};
use crate::linalg::par::{matmul_into_pooled, matmul_tn_into_pooled};
use crate::linalg::qr::{orthonormalize, orthonormalize_into};
use crate::linalg::svd::svd_jacobi;
use crate::runtime::pool::Pool;
use crate::tensor::{Matrix, Workspace};
use crate::util::Rng;

/// Options for the randomized range finder.
#[derive(Clone, Copy, Debug)]
pub struct RsvdOpts {
    /// Target rank r.
    pub rank: usize,
    /// Oversampling p (columns beyond r in the sketch; 4–8 typical).
    pub oversample: usize,
    /// Power iterations q (1–2 suffice for gradient spectra).
    pub power_iters: usize,
}

impl Default for RsvdOpts {
    fn default() -> Self {
        RsvdOpts { rank: 8, oversample: 4, power_iters: 1 }
    }
}

/// Compute an orthonormal basis `P` (m×r) approximating the range of the
/// top-r left singular subspace of `a`.
///
/// This is exactly what GaLore needs from its SVD call — it only keeps
/// `U[:, :r]` — so Lotus swaps it in transparently.
pub fn rsvd_range(a: &Matrix, opts: RsvdOpts, rng: &mut Rng) -> Matrix {
    let (m, n) = a.shape();
    let l = (opts.rank + opts.oversample).min(n).min(m);
    // Test matrix Ω ∈ ℝ^{n×l}, entries N(0, 1/l) (JL scaling).
    let omega = Matrix::randn(n, l, (1.0 / l as f32).sqrt(), rng);
    // Sketch Y = A Ω.
    let mut y = matmul(a, &omega);
    // Power iterations with re-orthonormalization for stability:
    // Y ← A (Aᵀ Y); orthonormalize between products to avoid collapse.
    for _ in 0..opts.power_iters {
        let q = orthonormalize(&y);
        let z = matmul_tn(a, &q); // n×l = Aᵀ Q
        let qz = orthonormalize(&z);
        y = matmul(a, &qz); // m×l
    }
    let q = orthonormalize(&y);
    q.take_cols(opts.rank.min(q.cols))
}

/// Reusable scratch for repeated [`rsvd_range_into`] calls: the sketch,
/// power-iteration and QR buffers all live here, so a steady-state
/// refresh at a fixed layer shape performs zero heap allocations.
#[derive(Debug)]
pub struct RsvdScratch {
    ws: Workspace,
    omega: Matrix,
    y: Matrix,
    z: Matrix,
    q: Matrix,
    qz: Matrix,
}

impl RsvdScratch {
    pub fn new() -> Self {
        RsvdScratch {
            ws: Workspace::new(),
            omega: Matrix::zeros(0, 0),
            y: Matrix::zeros(0, 0),
            z: Matrix::zeros(0, 0),
            q: Matrix::zeros(0, 0),
            qz: Matrix::zeros(0, 0),
        }
    }
}

impl Default for RsvdScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Allocation-free, pool-parallel twin of [`rsvd_range`]: writes the
/// orthonormal basis into `out`, drawing all intermediates from
/// `scratch` and fanning the GEMMs across `pool`.
///
/// Consumes `rng` exactly like [`rsvd_range`] and produces bit-identical
/// results at any thread count (row-band parallelism preserves the
/// serial accumulation order; see `EXPERIMENTS.md` §Perf).
pub fn rsvd_range_into(
    a: &Matrix,
    opts: RsvdOpts,
    rng: &mut Rng,
    pool: &Pool,
    scratch: &mut RsvdScratch,
    out: &mut Matrix,
) {
    let (m, n) = a.shape();
    if m == 0 || n == 0 {
        out.reset_to(m, 0);
        return;
    }
    let l = (opts.rank + opts.oversample).min(n).min(m);
    let s = scratch;
    // Test matrix Ω ∈ ℝ^{n×l}, entries N(0, 1/l) (JL scaling).
    s.omega.ensure_shape(n, l);
    rng.fill_normal(&mut s.omega.data, (1.0 / l as f32).sqrt());
    // Sketch Y = A Ω.
    s.y.ensure_shape(m, l);
    matmul_into_pooled(pool, a, &s.omega, &mut s.y);
    // Power iterations with re-orthonormalization for stability.
    for _ in 0..opts.power_iters {
        orthonormalize_into(&s.y, &mut s.q, &mut s.ws);
        s.z.ensure_shape(n, l);
        matmul_tn_into_pooled(pool, a, &s.q, &mut s.z); // n×l = Aᵀ Q
        orthonormalize_into(&s.z, &mut s.qz, &mut s.ws);
        matmul_into_pooled(pool, a, &s.qz, &mut s.y); // m×l
    }
    orthonormalize_into(&s.y, &mut s.q, &mut s.ws);
    let r = opts.rank.min(s.q.cols);
    out.ensure_shape(m, r);
    for i in 0..m {
        out.row_mut(i).copy_from_slice(&s.q.row(i)[..r]);
    }
}

/// Full randomized SVD: project to the sketch range, do a small exact
/// SVD there, and lift back. Returns (U m×r, s, Vt r×n).
pub fn rsvd(a: &Matrix, opts: RsvdOpts, rng: &mut Rng) -> (Matrix, Vec<f32>, Matrix) {
    let q = {
        // range with oversampled width retained for accuracy
        let (m, n) = a.shape();
        let l = (opts.rank + opts.oversample).min(n).min(m);
        let omega = Matrix::randn(n, l, (1.0 / l as f32).sqrt(), rng);
        let mut y = matmul(a, &omega);
        for _ in 0..opts.power_iters {
            let qy = orthonormalize(&y);
            let z = matmul_tn(a, &qy);
            let qz = orthonormalize(&z);
            y = matmul(a, &qz);
        }
        orthonormalize(&y)
    };
    // B = Qᵀ A  (l×n), small exact SVD on B.
    let b = matmul_tn(&q, a);
    let svd_b = svd_jacobi(&b);
    let r = opts.rank.min(svd_b.s.len());
    // U = Q · U_b[:, :r]
    let u = matmul(&q, &svd_b.u.take_cols(r));
    let s = svd_b.s[..r].to_vec();
    // Vt = first r rows of svd_b.vt
    let mut vt = Matrix::zeros(r, a.cols);
    for i in 0..r {
        vt.row_mut(i).copy_from_slice(svd_b.vt.row(i));
    }
    (u, s, vt)
}

/// FLOP estimate for one rSVD range-finder call (used by the analytic
/// cost model behind Fig. 2's ETA extrapolation).
pub fn rsvd_flops(m: usize, n: usize, r: usize, oversample: usize, q: usize) -> u64 {
    let l = (r + oversample) as u64;
    let mn = (m as u64) * (n as u64);
    // sketch + q power iterations (2 GEMMs each) + QR
    let gemms = (1 + 2 * q as u64) * 2 * mn * l;
    let qr = 2 * (m as u64) * l * l;
    gemms + qr
}

/// FLOP estimate for an exact SVD (Golub–Kahan style constant ≈ 14 for
/// U,Σ only on the smaller side; Jacobi is higher, we use the LAPACK-ish
/// constant to be fair to GaLore's GPU implementation).
pub fn svd_flops(m: usize, n: usize) -> u64 {
    let (lo, hi) = if m < n { (m as u64, n as u64) } else { (n as u64, m as u64) };
    14 * lo * lo * hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{orthonormality_error, principal_angle_cos};

    #[test]
    fn range_is_orthonormal() {
        let mut rng = Rng::new(51);
        let a = Matrix::randn(100, 60, 1.0, &mut rng);
        let p = rsvd_range(&a, RsvdOpts { rank: 8, oversample: 4, power_iters: 1 }, &mut rng);
        assert_eq!(p.shape(), (100, 8));
        assert!(orthonormality_error(&p) < 1e-4);
    }

    #[test]
    fn captures_dominant_subspace_of_lowrank_plus_noise() {
        let mut rng = Rng::new(52);
        // A = U0 S V0 + small noise, with strong top-4 spectrum
        let u0 = orthonormalize(&Matrix::randn(80, 4, 1.0, &mut rng));
        let v0 = Matrix::randn(4, 50, 1.0, &mut rng);
        let mut a = matmul(&u0, &v0);
        a.scale(10.0);
        let noise = Matrix::randn(80, 50, 0.05, &mut rng);
        let a = a.add(&noise);

        let p = rsvd_range(&a, RsvdOpts { rank: 4, oversample: 4, power_iters: 2 }, &mut rng);
        // principal angles between span(P) and span(U0) must be tiny
        let cos_min = principal_angle_cos(&p, &u0);
        assert!(cos_min > 0.999, "cos_min={cos_min}");
    }

    #[test]
    fn rsvd_matches_exact_svd_values() {
        let mut rng = Rng::new(53);
        let a = Matrix::randn(60, 40, 1.0, &mut rng);
        let exact = svd_jacobi(&a);
        let (_, s, _) = rsvd(&a, RsvdOpts { rank: 6, oversample: 6, power_iters: 2 }, &mut rng);
        for (i, sv) in s.iter().enumerate() {
            let rel = (sv - exact.s[i]).abs() / exact.s[i];
            assert!(rel < 0.05, "σ{i}: {sv} vs {} rel={rel}", exact.s[i]);
        }
    }

    #[test]
    fn rsvd_reconstruction_close_to_optimal() {
        let mut rng = Rng::new(54);
        let a = Matrix::randn(50, 50, 1.0, &mut rng);
        let r = 10;
        let exact = svd_jacobi(&a);
        let opt_err_sq: f64 = exact.s[r..].iter().map(|x| (*x as f64).powi(2)).sum();

        let (u, s, vt) = rsvd(&a, RsvdOpts { rank: r, oversample: 8, power_iters: 2 }, &mut rng);
        let mut us = u.clone();
        for i in 0..us.rows {
            for j in 0..r {
                *us.at_mut(i, j) *= s[j];
            }
        }
        let rec = matmul(&us, &vt);
        let err_sq = rec.sub(&a).fro_norm_sq();
        // within 15% of the Eckart–Young optimum
        assert!(err_sq < opt_err_sq * 1.15, "err {err_sq} vs opt {opt_err_sq}");
    }

    #[test]
    fn power_iterations_improve_capture() {
        let mut rng = Rng::new(55);
        // flat-ish spectrum makes q matter
        let a = Matrix::randn(120, 80, 1.0, &mut rng);
        let exact = svd_jacobi(&a);
        let u_true = exact.u.take_cols(6);
        let mut cos_q = Vec::new();
        for q in [0usize, 2] {
            let mut rng_q = Rng::new(56); // same Ω stream for fairness
            let p = rsvd_range(&a, RsvdOpts { rank: 6, oversample: 2, power_iters: q }, &mut rng_q);
            cos_q.push(principal_angle_cos(&p, &u_true));
        }
        assert!(cos_q[1] >= cos_q[0] - 1e-3, "q=2 {:?} should beat q=0", cos_q);
    }

    #[test]
    fn flop_model_ordering() {
        // rSVD must be asymptotically cheaper than SVD for r << min(m,n)
        let (m, n) = (4096, 4096);
        assert!(rsvd_flops(m, n, 128, 8, 1) < svd_flops(m, n) / 5);
        // and the model should grow linearly in r
        let f1 = rsvd_flops(m, n, 64, 8, 1);
        let f2 = rsvd_flops(m, n, 128, 8, 1);
        assert!(f2 < f1 * 2 + f1 / 2);
    }

    #[test]
    fn rank_clamped_to_matrix_size() {
        let mut rng = Rng::new(57);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let p = rsvd_range(&a, RsvdOpts { rank: 20, oversample: 4, power_iters: 1 }, &mut rng);
        assert!(p.cols <= 6);
        assert!(orthonormality_error(&p) < 1e-4);
    }

    #[test]
    fn range_into_matches_allocating_bit_for_bit_at_any_thread_count() {
        let mut rng = Rng::new(58);
        let a = Matrix::randn(96, 56, 1.0, &mut rng);
        let opts = RsvdOpts { rank: 8, oversample: 4, power_iters: 2 };
        let mut rng_ref = Rng::new(59);
        let reference = rsvd_range(&a, opts, &mut rng_ref);
        for threads in [1usize, 2, 8] {
            let pool = Pool::with_threads(threads);
            let mut scratch = RsvdScratch::new();
            let mut out = Matrix::zeros(0, 0);
            let mut rng_t = Rng::new(59);
            rsvd_range_into(&a, opts, &mut rng_t, &pool, &mut scratch, &mut out);
            assert_eq!(out.shape(), reference.shape());
            assert_eq!(out.data, reference.data, "threads={threads}");
        }
    }

    #[test]
    fn scratch_reuse_never_changes_results() {
        // 100 refreshes through one scratch arena: every result matches
        // the allocating path with the same RNG stream (stale-scratch
        // corruption would break equality), and the arena stops growing.
        let mut rng = Rng::new(60);
        let a = Matrix::randn(48, 40, 1.0, &mut rng);
        let b = Matrix::randn(40, 24, 1.0, &mut rng); // second shape in the working set
        let opts = RsvdOpts { rank: 6, oversample: 4, power_iters: 1 };
        let pool = Pool::with_threads(2);
        let mut scratch = RsvdScratch::new();
        let mut out = Matrix::zeros(0, 0);
        let mut rng_into = Rng::new(61);
        let mut rng_ref = Rng::new(61);
        for it in 0..100 {
            let target = if it % 2 == 0 { &a } else { &b };
            rsvd_range_into(target, opts, &mut rng_into, &pool, &mut scratch, &mut out);
            let reference = rsvd_range(target, opts, &mut rng_ref);
            assert_eq!(out.data, reference.data, "iteration {it}");
        }
    }
}
