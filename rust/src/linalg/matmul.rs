//! Blocked matrix multiplication kernels.
//!
//! Three product shapes cover everything the optimizers need without
//! materializing transposes:
//!   * [`matmul`] / [`matmul_into`]       — `C = A · B`
//!   * [`matmul_tn`] / [`matmul_tn_into`] — `C = Aᵀ · B` (A stored normally)
//!   * [`matmul_nt`] / [`matmul_nt_into`] — `C = A · Bᵀ`
//!
//! Each comes in an allocating and a caller-owned-buffer (`*_into`)
//! variant; the `*_axpy_into` forms accumulate `C += α·A·B` for the fused
//! optimizer update. The inner loops are written i-k-j (or j-blocked dot
//! for `nt`) so the innermost traversal is contiguous in both operands
//! and branch-free — exactly what the auto-vectorizer needs; blocking
//! keeps panels in L1/L2. All variants share the same band kernels, so
//! the allocating wrappers, the `*_into` forms and the row-band parallel
//! versions in [`crate::linalg::par`] are bit-for-bit identical. This is
//! the L3 hot path for the Rust-native simulator — methodology and
//! measured numbers live in `EXPERIMENTS.md` §Perf.

use crate::tensor::Matrix;

/// Cache-block size for the k dimension (tuned in the perf pass).
const KB: usize = 64;
/// Cache-block size for the i dimension.
const IB: usize = 32;

/// Band kernel for `C = A · B`: accumulates `band_rows` rows of C from
/// the matching rows of A. `c_band` must be zeroed (or hold a partial
/// accumulation) on entry. Per output row the k-accumulation order is
/// fixed (k-blocks in order), so any row partition yields bit-identical
/// results.
pub(crate) fn mm_band(
    a_band: &[f32],
    b: &[f32],
    c_band: &mut [f32],
    band_rows: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a_band.len(), band_rows * k);
    debug_assert_eq!(c_band.len(), band_rows * n);
    debug_assert_eq!(b.len(), k * n);
    for i0 in (0..band_rows).step_by(IB) {
        let i1 = (i0 + IB).min(band_rows);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let arow = &a_band[i * k..(i + 1) * k];
                let crow = &mut c_band[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    // contiguous, branch-free fused multiply-add over j
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/// Band kernel for `C += α · A · B` (α folded into the A element, so the
/// per-element cost matches [`mm_band`]).
pub(crate) fn mm_axpy_band(
    a_band: &[f32],
    b: &[f32],
    c_band: &mut [f32],
    band_rows: usize,
    k: usize,
    n: usize,
    alpha: f32,
) {
    debug_assert_eq!(a_band.len(), band_rows * k);
    debug_assert_eq!(c_band.len(), band_rows * n);
    for i0 in (0..band_rows).step_by(IB) {
        let i1 = (i0 + IB).min(band_rows);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let arow = &a_band[i * k..(i + 1) * k];
                let crow = &mut c_band[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = alpha * arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/// Band kernel for `C = Aᵀ · B`, producing output rows `ka0..ka1` of the
/// k×n result. Every worker streams all m rows of A and B; the
/// i-accumulation order per output row matches the serial kernel, so any
/// row partition yields bit-identical results.
pub(crate) fn mm_tn_band(
    a: &[f32],
    b: &[f32],
    c_band: &mut [f32],
    ka0: usize,
    ka1: usize,
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c_band.len(), (ka1 - ka0) * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for ka in ka0..ka1 {
            let aik = arow[ka];
            let crow = &mut c_band[(ka - ka0) * n..(ka - ka0 + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Band kernel for `C = A · Bᵀ`: rows of C from the matching rows of A;
/// each element is an independent contiguous dot product.
pub(crate) fn mm_nt_band(
    a_band: &[f32],
    bt: &[f32],
    c_band: &mut [f32],
    band_rows: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a_band.len(), band_rows * k);
    debug_assert_eq!(c_band.len(), band_rows * n);
    debug_assert_eq!(bt.len(), n * k);
    for i in 0..band_rows {
        let arow = &a_band[i * k..(i + 1) * k];
        let crow = &mut c_band[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bt[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
}

/// Band kernel for `C += α · A · Bᵀ`.
pub(crate) fn mm_nt_axpy_band(
    a_band: &[f32],
    bt: &[f32],
    c_band: &mut [f32],
    band_rows: usize,
    k: usize,
    n: usize,
    alpha: f32,
) {
    debug_assert_eq!(a_band.len(), band_rows * k);
    debug_assert_eq!(c_band.len(), band_rows * n);
    for i in 0..band_rows {
        let arow = &a_band[i * k..(i + 1) * k];
        let crow = &mut c_band[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bt[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] += alpha * acc;
        }
    }
}

/// C = A (m×k) · B (k×n), written into a caller-owned, pre-shaped `c`.
/// Overwrites `c` completely; no allocation.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_into output shape");
    c.data.fill(0.0);
    mm_band(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.cols);
}

/// C = Aᵀ (k×m stored as m×k) · B (m×n) → (k×n), into a caller-owned `c`.
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_tn outer dims");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_tn_into output shape");
    c.data.fill(0.0);
    mm_tn_band(&a.data, &b.data, &mut c.data, 0, a.cols, a.rows, a.cols, b.cols);
}

/// C = A (m×k) · Bᵀ (n×k stored as n×k) → (m×n), into a caller-owned `c`.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_nt_into output shape");
    mm_nt_band(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.rows);
}

/// C += α · A · B (accumulating; `c` must already be m×n).
pub fn matmul_axpy_into(a: &Matrix, b: &Matrix, alpha: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul_axpy inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_axpy output shape");
    mm_axpy_band(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.cols, alpha);
}

/// C += α · A · Bᵀ (accumulating; `c` must already be m×n).
pub fn matmul_nt_axpy_into(a: &Matrix, b: &Matrix, alpha: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_nt_axpy inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_nt_axpy output shape");
    mm_nt_axpy_band(&a.data, &b.data, &mut c.data, a.rows, a.cols, b.rows, alpha);
}

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = Aᵀ (k×m stored as m×k) · B (m×n)  →  (k×n).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_tn_into(a, b, &mut c);
    c
}

/// C = A (m×k) · Bᵀ (n×k stored as n×k)  →  (m×n).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_nt_into(a, b, &mut c);
    c
}

/// y = A · x for a flat vector x (len = A.cols).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (r, xv) in row.iter().zip(x) {
            acc += r * xv;
        }
        y[i] = acc;
    }
    y
}

/// y = Aᵀ · x for a flat vector x (len = A.rows).
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0f32; a.cols];
    for i in 0..a.rows {
        let row = a.row(i);
        let xi = x[i];
        for (yv, r) in y.iter_mut().zip(row) {
            *yv += xi * r;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = a.fro_norm().max(1.0);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 65, 70), (100, 1, 100)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Rng::new(22);
        let a = Matrix::randn(31, 17, 1.0, &mut rng);
        let b = Matrix::randn(31, 23, 1.0, &mut rng);
        assert_close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
        let c = Matrix::randn(19, 17, 1.0, &mut rng);
        assert_close(&matmul_nt(&a, &c), &matmul(&a, &c.transpose()), 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(23);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        assert_close(&matmul(&a, &Matrix::eye(12)), &a, 1e-6);
        assert_close(&matmul(&Matrix::eye(12), &a), &a, 1e-6);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Rng::new(24);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let x = Matrix::randn(14, 1, 1.0, &mut rng);
        let y = matvec(&a, &x.data);
        let y2 = matmul(&a, &x);
        for (u, v) in y.iter().zip(&y2.data) {
            assert!((u - v).abs() < 1e-4);
        }
        let z = matvec_t(&a, &matvec(&a, &x.data));
        let z2 = matmul_tn(&a, &matmul(&a, &x));
        for (u, v) in z.iter().zip(&z2.data) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn into_variants_match_allocating_bit_for_bit() {
        let mut rng = Rng::new(25);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 65, 70), (40, 12, 40)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let mut c = Matrix::zeros(m, n);
            matmul_into(&a, &b, &mut c);
            assert_eq!(c.data, matmul(&a, &b).data);

            let bt = b.transpose(); // n×k
            let mut cnt = Matrix::zeros(m, n);
            matmul_nt_into(&a, &bt, &mut cnt);
            assert_eq!(cnt.data, matmul_nt(&a, &bt).data);

            let a2 = Matrix::randn(k, m, 1.0, &mut rng);
            let b2 = Matrix::randn(k, n, 1.0, &mut rng);
            let mut ctn = Matrix::zeros(m, n);
            matmul_tn_into(&a2, &b2, &mut ctn);
            assert_eq!(ctn.data, matmul_tn(&a2, &b2).data);
        }
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        let mut rng = Rng::new(26);
        let a = Matrix::randn(7, 11, 1.0, &mut rng);
        let b = Matrix::randn(11, 5, 1.0, &mut rng);
        let mut c = Matrix::from_fn(7, 5, |i, j| (i + j) as f32 + 13.0);
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data, matmul(&a, &b).data, "stale output leaked through");
    }

    #[test]
    fn axpy_variants_accumulate() {
        let mut rng = Rng::new(27);
        let a = Matrix::randn(9, 6, 1.0, &mut rng);
        let b = Matrix::randn(6, 8, 1.0, &mut rng);
        let base = Matrix::randn(9, 8, 1.0, &mut rng);
        let alpha = -0.37f32;

        let mut c = base.clone();
        matmul_axpy_into(&a, &b, alpha, &mut c);
        let mut expect = base.clone();
        expect.axpy(alpha, &matmul(&a, &b));
        assert_close(&c, &expect, 1e-5);

        let bt = b.transpose(); // 8×6
        let mut c2 = base.clone();
        matmul_nt_axpy_into(&a, &bt, alpha, &mut c2);
        let mut expect2 = base.clone();
        expect2.axpy(alpha, &matmul_nt(&a, &bt));
        assert_close(&c2, &expect2, 1e-5);
    }
}
