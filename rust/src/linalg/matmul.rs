//! Blocked matrix multiplication kernels.
//!
//! Three variants cover every product the optimizers need without
//! materializing transposes:
//!   * [`matmul`]     — `C = A · B`
//!   * [`matmul_tn`]  — `C = Aᵀ · B` (A stored normally)
//!   * [`matmul_nt`]  — `C = A · Bᵀ`
//!
//! The inner loops are written i-k-j (or j-blocked dot for `nt`) so the
//! innermost traversal is contiguous in both operands, which is what the
//! auto-vectorizer needs; blocking keeps panels in L1/L2. This is the L3
//! hot path for the Rust-native simulator — see EXPERIMENTS.md §Perf.

use crate::tensor::Matrix;

/// Cache-block size for the k dimension (tuned in the perf pass).
const KB: usize = 64;
/// Cache-block size for the i dimension.
const IB: usize = 32;

/// C = A (m×k) · B (k×n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // i-k-j loop order with k/i blocking: B rows stream contiguously.
    for i0 in (0..m).step_by(IB) {
        let i1 = (i0 + IB).min(m);
        for k0 in (0..k).step_by(KB) {
            let k1 = (k0 + KB).min(k);
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    // contiguous fused multiply-add over j
                    for j in 0..n {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// C = Aᵀ (k×m stored as m×k) · B (m×n)  →  (k×n).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn outer dims");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(k, n);
    // For each row i of A and B: C[ka, :] += A[i, ka] * B[i, :]
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * n..(i + 1) * n];
        for (ka, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[ka * n..(ka + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = A (m×k) · Bᵀ (n×k stored as n×k)  →  (m×n).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            // dot product over contiguous slices — vectorizes well
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            crow[j] = acc;
        }
    }
    c
}

/// y = A · x for a flat vector x (len = A.cols).
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let mut y = vec![0.0f32; a.rows];
    for i in 0..a.rows {
        let row = a.row(i);
        let mut acc = 0.0f32;
        for (r, xv) in row.iter().zip(x) {
            acc += r * xv;
        }
        y[i] = acc;
    }
    y
}

/// y = Aᵀ · x for a flat vector x (len = A.rows).
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0f32; a.cols];
    for i in 0..a.rows {
        let row = a.row(i);
        let xi = x[i];
        for (yv, r) in y.iter_mut().zip(row) {
            *yv += xi * r;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f64;
                for k in 0..a.cols {
                    acc += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = acc as f32;
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let scale = a.fro_norm().max(1.0);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * scale, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 65, 70), (100, 1, 100)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
        }
    }

    #[test]
    fn tn_and_nt_match_explicit_transpose() {
        let mut rng = Rng::new(22);
        let a = Matrix::randn(31, 17, 1.0, &mut rng);
        let b = Matrix::randn(31, 23, 1.0, &mut rng);
        assert_close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
        let c = Matrix::randn(19, 17, 1.0, &mut rng);
        assert_close(&matmul_nt(&a, &c), &matmul(&a, &c.transpose()), 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(23);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        assert_close(&matmul(&a, &Matrix::eye(12)), &a, 1e-6);
        assert_close(&matmul(&Matrix::eye(12), &a), &a, 1e-6);
    }

    #[test]
    fn matvec_consistent_with_matmul() {
        let mut rng = Rng::new(24);
        let a = Matrix::randn(9, 14, 1.0, &mut rng);
        let x = Matrix::randn(14, 1, 1.0, &mut rng);
        let y = matvec(&a, &x.data);
        let y2 = matmul(&a, &x);
        for (u, v) in y.iter().zip(&y2.data) {
            assert!((u - v).abs() < 1e-4);
        }
        let z = matvec_t(&a, &matvec(&a, &x.data));
        let z2 = matmul_tn(&a, &matmul(&a, &x));
        for (u, v) in z.iter().zip(&z2.data) {
            assert!((u - v).abs() < 1e-3);
        }
    }
}
