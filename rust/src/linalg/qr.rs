//! Thin (economy) QR factorization via Householder reflections.
//!
//! Used by the randomized SVD's range finder (orthonormalizing the
//! sketch `Y = G Ω`) and by re-orthonormalization between power
//! iterations. For the m×r panels Lotus produces (r ≪ m) Householder QR
//! is O(m r²) — negligible next to the O(r·mn) GEMMs, which is why the
//! factorization stays serial while the GEMMs go through the pool.
//!
//! Two entry points share the same kernels: the allocating [`qr_thin`] /
//! [`orthonormalize`], and the workspace-backed [`orthonormalize_into`]
//! that performs zero steady-state allocations (scratch comes from a
//! [`Workspace`] arena, the Q output from a caller-owned buffer).

use crate::tensor::{Matrix, Workspace};

/// Thin QR result: Q is m×k orthonormal, R is k×k upper-triangular,
/// with k = min(m, n).
pub struct QrThin {
    pub q: Matrix,
    pub r: Matrix,
}

/// In-place Householder factorization (LAPACK geqrf layout: reflector
/// vectors below the diagonal, R on/above it). `tau` must have length
/// min(m, n).
fn householder_factor(w: &mut Matrix, tau: &mut [f32]) {
    let (m, n) = w.shape();
    let k = m.min(n);
    debug_assert!(tau.len() >= k);

    for j in 0..k {
        // Build the Householder reflector for column j, rows j..m.
        let mut norm_sq = 0.0f64;
        for i in j..m {
            let v = w.at(i, j) as f64;
            norm_sq += v * v;
        }
        let norm = norm_sq.sqrt() as f32;
        if norm <= f32::EPSILON {
            tau[j] = 0.0;
            continue;
        }
        let ajj = w.at(j, j);
        let alpha = if ajj >= 0.0 { -norm } else { norm };
        // v = x - alpha e1, normalized so v[0] = 1
        let v0 = ajj - alpha;
        tau[j] = -v0 / alpha; // = 2 / (vᵀv) * v0² scaling under v0-normalization
        let inv_v0 = 1.0 / v0;
        for i in (j + 1)..m {
            *w.at_mut(i, j) *= inv_v0;
        }
        *w.at_mut(j, j) = alpha;

        // Apply reflector to the trailing columns: A ← (I - τ v vᵀ) A.
        for c in (j + 1)..n {
            // s = vᵀ A[:, c]  (v[j] = 1 implicitly)
            let mut s = w.at(j, c) as f64;
            for i in (j + 1)..m {
                s += w.at(i, j) as f64 * w.at(i, c) as f64;
            }
            let s = (s * tau[j] as f64) as f32;
            *w.at_mut(j, c) -= s;
            for i in (j + 1)..m {
                let vij = w.at(i, j);
                *w.at_mut(i, c) -= s * vij;
            }
        }
    }
}

/// Form Q (m×k) explicitly from a factored `w`/`tau` pair by applying the
/// reflectors in reverse to the leading k columns of the identity. `q` is
/// reshaped in place (no allocation once its buffer is large enough).
fn form_q(w: &Matrix, tau: &[f32], q: &mut Matrix) {
    let (m, n) = w.shape();
    let k = m.min(n);
    q.reset_to(m, k);
    for i in 0..k {
        *q.at_mut(i, i) = 1.0;
    }
    for j in (0..k).rev() {
        if tau[j] == 0.0 {
            continue;
        }
        for c in 0..k {
            let mut s = q.at(j, c) as f64;
            for i in (j + 1)..m {
                s += w.at(i, j) as f64 * q.at(i, c) as f64;
            }
            let s = (s * tau[j] as f64) as f32;
            *q.at_mut(j, c) -= s;
            for i in (j + 1)..m {
                let vij = w.at(i, j);
                *q.at_mut(i, c) -= s * vij;
            }
        }
    }
}

/// Compute the thin QR of `a` (m×n). Requires m >= n for the thin form
/// to be the useful one (Lotus always orthonormalizes tall panels).
pub fn qr_thin(a: &Matrix) -> QrThin {
    let (m, n) = a.shape();
    let k = m.min(n);
    let mut w = a.clone();
    let mut tau = vec![0.0f32; k];
    householder_factor(&mut w, &mut tau);

    // Extract R (k×n upper part; for thin usage n <= m ⇒ k = n).
    let mut r = Matrix::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            *r.at_mut(i, j) = w.at(i, j);
        }
    }

    let mut q = Matrix::zeros(0, 0);
    form_q(&w, &tau, &mut q);
    QrThin { q, r }
}

/// Orthonormalize the columns of `a` (returns Q of its thin QR).
pub fn orthonormalize(a: &Matrix) -> Matrix {
    qr_thin(a).q
}

/// Orthonormalize the columns of `a` into the caller-owned `q`, borrowing
/// all scratch from `ws`. Numerically identical to [`orthonormalize`];
/// performs zero allocations once the workspace and `q` are warm.
pub fn orthonormalize_into(a: &Matrix, q: &mut Matrix, ws: &mut Workspace) {
    let (m, n) = a.shape();
    let k = m.min(n);
    let mut w = ws.take_copy(a);
    // tau must come from `take`: householder_factor relies on it being
    // zero-initialized, matching the allocating path's `vec![0.0; k]`.
    let mut tau = ws.take(1, k);
    householder_factor(&mut w, &mut tau.data);
    form_q(&w, &tau.data, q);
    ws.give(tau);
    ws.give(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::matmul;
    use crate::linalg::norms::orthonormality_error;
    use crate::util::Rng;

    fn reconstruct_ok(a: &Matrix) {
        let QrThin { q, r } = qr_thin(a);
        let qr = matmul(&q, &r);
        let err = qr.sub(a).fro_norm() / a.fro_norm().max(1e-12);
        assert!(err < 5e-5, "reconstruction err {err}");
        let oe = orthonormality_error(&q);
        assert!(oe < 5e-5, "orthonormality err {oe}");
    }

    #[test]
    fn qr_random_tall() {
        let mut rng = Rng::new(31);
        for &(m, n) in &[(8, 8), (40, 7), (128, 16), (257, 33), (64, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            reconstruct_ok(&a);
        }
    }

    #[test]
    fn qr_rank_deficient() {
        let mut rng = Rng::new(32);
        // duplicate-column matrix (rank < n) — Q should still be built and
        // reconstruction should hold
        let b = Matrix::randn(50, 4, 1.0, &mut rng);
        let mut a = Matrix::zeros(50, 8);
        for i in 0..50 {
            for j in 0..8 {
                *a.at_mut(i, j) = b.at(i, j % 4);
            }
        }
        let QrThin { q, r } = qr_thin(&a);
        let qr = matmul(&q, &r);
        let err = qr.sub(&a).fro_norm() / a.fro_norm();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn qr_of_orthonormal_is_identity_r() {
        let mut rng = Rng::new(33);
        let a = Matrix::randn(60, 10, 1.0, &mut rng);
        let q = orthonormalize(&a);
        let QrThin { q: q2, r: r2 } = qr_thin(&q);
        // R should be ±identity
        for i in 0..10 {
            for j in 0..10 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((r2.at(i, j).abs() - expect).abs() < 1e-4);
            }
        }
        assert!(orthonormality_error(&q2) < 1e-4);
    }

    #[test]
    fn workspace_variant_is_bit_identical() {
        let mut rng = Rng::new(34);
        let mut ws = Workspace::new();
        let mut q = Matrix::zeros(0, 0);
        for &(m, n) in &[(8, 8), (40, 7), (128, 16), (64, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            orthonormalize_into(&a, &mut q, &mut ws);
            assert_eq!(q.data, orthonormalize(&a).data, "({m},{n})");
            assert_eq!(q.shape(), (m, m.min(n)));
        }
    }

    #[test]
    fn workspace_variant_reuse_is_stable() {
        // 100 repeats over the same shapes: results never drift (stale
        // scratch would corrupt them) and the workspace stops allocating.
        let mut rng = Rng::new(35);
        let a = Matrix::randn(48, 12, 1.0, &mut rng);
        let reference = orthonormalize(&a);
        let mut ws = Workspace::new();
        let mut q = Matrix::zeros(0, 0);
        orthonormalize_into(&a, &mut q, &mut ws);
        let cap = ws.capacity_bytes();
        for _ in 0..100 {
            orthonormalize_into(&a, &mut q, &mut ws);
            assert_eq!(q.data, reference.data);
        }
        assert_eq!(ws.capacity_bytes(), cap, "workspace kept growing");
    }
}
