//! One-sided Jacobi SVD — the exact decomposition GaLore performs on the
//! gradient at every projector refresh (via `torch.linalg.svd` / LAPACK
//! in the original). Cubic cost with a high constant; Lotus's whole point
//! is to avoid calling this on the hot path.
//!
//! One-sided Jacobi works on A directly (no AᵀA formation), giving good
//! relative accuracy for small singular values and a simple, auditable
//! implementation.

use crate::tensor::Matrix;

/// Full thin SVD result: `a ≈ u · diag(s) · vt`.
pub struct Svd {
    /// m×k orthonormal left singular vectors (k = min(m,n)).
    pub u: Matrix,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// k×n matrix of right singular vectors (rows are vᵢᵀ).
    pub vt: Matrix,
}

/// Compute the thin SVD by one-sided Jacobi rotations on columns.
///
/// Converges quadratically; we cap sweeps at 30 and stop when all
/// off-diagonal column dot products are tiny relative to column norms.
pub fn svd_jacobi(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // Work on the transpose and swap U/V at the end.
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }

    // W starts as A; Jacobi rotations orthogonalize its columns.
    let mut w = a.clone();
    // V accumulates the right rotations.
    let mut v = Matrix::eye(n);

    let eps = 1e-9f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let wp = w.at(i, p) as f64;
                    let wq = w.at(i, q) as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation that annihilates apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let wp = w.at(i, p);
                    let wq = w.at(i, q);
                    *w.at_mut(i, p) = cf * wp - sf * wq;
                    *w.at_mut(i, q) = sf * wp + cf * wq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = cf * vp - sf * vq;
                    *v.at_mut(i, q) = sf * vp + cf * vq;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Column norms of W are the singular values; normalize to get U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sv = vec![0.0f32; n];
    for j in 0..n {
        let mut acc = 0.0f64;
        for i in 0..m {
            let x = w.at(i, j) as f64;
            acc += x * x;
        }
        sv[j] = acc.sqrt() as f32;
    }
    order.sort_by(|&i, &j| sv[j].partial_cmp(&sv[i]).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut s = vec![0.0f32; n];
    let mut vt = Matrix::zeros(n, n);
    for (out_j, &j) in order.iter().enumerate() {
        s[out_j] = sv[j];
        let inv = if sv[j] > 1e-20 { 1.0 / sv[j] } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, out_j) = w.at(i, j) * inv;
        }
        for i in 0..n {
            *vt.at_mut(out_j, i) = v.at(i, j);
        }
    }

    Svd { u, s, vt }
}

impl Svd {
    /// Reconstruct `u[:, :r] diag(s[:r]) vt[:r, :]`.
    pub fn reconstruct(&self, r: usize) -> Matrix {
        let (m, n) = (self.u.rows, self.vt.cols);
        let r = r.min(self.s.len());
        let mut out = Matrix::zeros(m, n);
        for k in 0..r {
            let sk = self.s[k];
            for i in 0..m {
                let uik = self.u.at(i, k) * sk;
                if uik == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                let vrow = self.vt.row(k);
                for j in 0..n {
                    orow[j] += uik * vrow[j];
                }
            }
        }
        out
    }

    /// Leading r left singular vectors (m×r) — GaLore's projector P.
    pub fn left_vectors(&self, r: usize) -> Matrix {
        self.u.take_cols(r.min(self.s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, norms::orthonormality_error};
    use crate::util::Rng;

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::new(41);
        for &(m, n) in &[(10, 10), (24, 8), (7, 15), (60, 20)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_jacobi(&a);
            let rec = svd.reconstruct(m.min(n));
            let err = rec.sub(&a).fro_norm() / a.fro_norm();
            assert!(err < 1e-4, "({m},{n}) err={err}");
            assert!(orthonormality_error(&svd.u) < 1e-4);
            assert!(orthonormality_error(&svd.vt.transpose()) < 1e-4);
        }
    }

    #[test]
    fn singular_values_sorted_and_nonneg() {
        let mut rng = Rng::new(42);
        let a = Matrix::randn(30, 12, 1.0, &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(svd.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn known_diagonal_case() {
        // A = diag(3, 2, 1) embedded in 5x3
        let mut a = Matrix::zeros(5, 3);
        *a.at_mut(0, 0) = 3.0;
        *a.at_mut(1, 1) = 2.0;
        *a.at_mut(2, 2) = 1.0;
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn low_rank_matrix_truncates_exactly() {
        let mut rng = Rng::new(43);
        // rank-3 matrix
        let u = Matrix::randn(40, 3, 1.0, &mut rng);
        let v = Matrix::randn(3, 25, 1.0, &mut rng);
        let a = matmul(&u, &v);
        let svd = svd_jacobi(&a);
        let rec = svd.reconstruct(3);
        let err = rec.sub(&a).fro_norm() / a.fro_norm();
        assert!(err < 1e-4, "err={err}");
        // 4th singular value should be ~0
        assert!(svd.s[3] < 1e-3 * svd.s[0]);
    }

    #[test]
    fn eckart_young_truncation_is_best() {
        let mut rng = Rng::new(44);
        let a = Matrix::randn(20, 20, 1.0, &mut rng);
        let svd = svd_jacobi(&a);
        let r = 5;
        let rec = svd.reconstruct(r);
        let err_sq = rec.sub(&a).fro_norm_sq();
        let tail: f64 = svd.s[r..].iter().map(|x| (*x as f64) * (*x as f64)).sum();
        assert!((err_sq - tail).abs() / tail.max(1e-12) < 1e-3, "{err_sq} vs {tail}");
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Rng::new(45);
        let a = Matrix::randn(6, 30, 1.0, &mut rng);
        let svd = svd_jacobi(&a);
        assert_eq!(svd.u.shape(), (6, 6));
        assert_eq!(svd.vt.shape(), (6, 30));
        let err = svd.reconstruct(6).sub(&a).fro_norm() / a.fro_norm();
        assert!(err < 1e-4);
    }
}
