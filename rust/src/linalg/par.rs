//! Row-band parallel matmul kernels over the shared-nothing worker pool
//! ([`crate::runtime::pool`]).
//!
//! Every variant splits the *output* matrix into one contiguous row band
//! per worker; each band is produced by the same serial band kernel as
//! the single-threaded path ([`crate::linalg::matmul`]), with the same
//! per-row accumulation order. Band boundaries therefore never change
//! the arithmetic: pooled results are bit-for-bit identical to the
//! serial kernels at any thread count (asserted in the tests below and
//! in `rust/tests/par_linalg.rs`).
//!
//! Measured speedups and the bench invocations live in `EXPERIMENTS.md`
//! §Perf; `benches/headline.rs` records serial-vs-pooled GFLOP/s to
//! `BENCH_headline.json` on every run.

use super::matmul::{mm_axpy_band, mm_band, mm_nt_axpy_band, mm_nt_band, mm_tn_band};
use crate::runtime::pool::Pool;
use crate::tensor::Matrix;

/// Minimum multiply-add count before fanning out: below this the
/// per-region thread-spawn overhead (~tens of µs) exceeds the kernel
/// time, so the pooled entry points fall back to the serial band kernel.
/// Band decomposition never changes results, so the cutoff is purely a
/// scheduling decision.
const MIN_PAR_MACS: usize = 1 << 20;

#[inline]
fn serial_for(pool: &Pool, macs: usize) -> bool {
    pool.threads() <= 1 || macs < MIN_PAR_MACS
}

/// C = A · B into a caller-owned buffer, rows of C fanned across `pool`.
pub fn matmul_into_pooled(pool: &Pool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul inner dims: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_into_pooled output shape");
    let (k, n) = (a.cols, b.cols);
    c.data.fill(0.0);
    if n == 0 || a.rows == 0 {
        return;
    }
    if serial_for(pool, a.rows * k * n) {
        mm_band(&a.data, &b.data, &mut c.data, a.rows, k, n);
        return;
    }
    let a_data = &a.data;
    let b_data = &b.data;
    pool.par_row_bands(&mut c.data, a.rows, n, |r0, band| {
        let band_rows = band.len() / n;
        mm_band(&a_data[r0 * k..(r0 + band_rows) * k], b_data, band, band_rows, k, n);
    });
}

/// C = Aᵀ · B into a caller-owned buffer, output rows fanned across
/// `pool` (each worker streams all of A and B but owns its rows of C).
pub fn matmul_tn_into_pooled(pool: &Pool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_tn outer dims");
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_tn_into_pooled output shape");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.fill(0.0);
    if n == 0 || k == 0 {
        return;
    }
    if serial_for(pool, m * k * n) {
        mm_tn_band(&a.data, &b.data, &mut c.data, 0, k, m, k, n);
        return;
    }
    let a_data = &a.data;
    let b_data = &b.data;
    pool.par_row_bands(&mut c.data, k, n, |ka0, band| {
        let rows = band.len() / n;
        mm_tn_band(a_data, b_data, band, ka0, ka0 + rows, m, k, n);
    });
}

/// C = A · Bᵀ into a caller-owned buffer, rows of C fanned across `pool`.
pub fn matmul_nt_into_pooled(pool: &Pool, a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_nt_into_pooled output shape");
    let (k, n) = (a.cols, b.rows);
    if n == 0 || a.rows == 0 {
        c.data.fill(0.0);
        return;
    }
    if serial_for(pool, a.rows * k * n) {
        mm_nt_band(&a.data, &b.data, &mut c.data, a.rows, k, n);
        return;
    }
    let a_data = &a.data;
    let b_data = &b.data;
    pool.par_row_bands(&mut c.data, a.rows, n, |r0, band| {
        let band_rows = band.len() / n;
        mm_nt_band(&a_data[r0 * k..(r0 + band_rows) * k], b_data, band, band_rows, k, n);
    });
}

/// C += α · A · B into a caller-owned accumulator, rows of C fanned
/// across `pool` — the pooled twin of
/// [`crate::linalg::matmul::matmul_axpy_into`], used by the fused
/// low-rank optimizer lift at large shapes. Each output row accumulates
/// in the same k-block order as the serial kernel, so results are
/// bit-identical at any thread count.
pub fn matmul_axpy_into_pooled(pool: &Pool, a: &Matrix, b: &Matrix, alpha: f32, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul_axpy inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_axpy_into_pooled output shape");
    let (k, n) = (a.cols, b.cols);
    if n == 0 || a.rows == 0 {
        return;
    }
    if serial_for(pool, a.rows * k * n) {
        mm_axpy_band(&a.data, &b.data, &mut c.data, a.rows, k, n, alpha);
        return;
    }
    let a_data = &a.data;
    let b_data = &b.data;
    pool.par_row_bands(&mut c.data, a.rows, n, |r0, band| {
        let band_rows = band.len() / n;
        mm_axpy_band(&a_data[r0 * k..(r0 + band_rows) * k], b_data, band, band_rows, k, n, alpha);
    });
}

/// C += α · A · Bᵀ into a caller-owned accumulator, rows of C fanned
/// across `pool` (pooled twin of
/// [`crate::linalg::matmul::matmul_nt_axpy_into`]).
pub fn matmul_nt_axpy_into_pooled(
    pool: &Pool,
    a: &Matrix,
    bt: &Matrix,
    alpha: f32,
    c: &mut Matrix,
) {
    assert_eq!(a.cols, bt.cols, "matmul_nt_axpy inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, bt.rows), "matmul_nt_axpy_into_pooled output shape");
    let (k, n) = (a.cols, bt.rows);
    if n == 0 || a.rows == 0 {
        return;
    }
    if serial_for(pool, a.rows * k * n) {
        mm_nt_axpy_band(&a.data, &bt.data, &mut c.data, a.rows, k, n, alpha);
        return;
    }
    let a_data = &a.data;
    let b_data = &bt.data;
    pool.par_row_bands(&mut c.data, a.rows, n, |r0, band| {
        let band_rows = band.len() / n;
        let a_band = &a_data[r0 * k..(r0 + band_rows) * k];
        mm_nt_axpy_band(a_band, b_data, band, band_rows, k, n, alpha);
    });
}

/// Allocating convenience: pooled C = A · B.
pub fn matmul_pooled(pool: &Pool, a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into_pooled(pool, a, b, &mut c);
    c
}

/// Allocating convenience: pooled C = Aᵀ · B.
pub fn matmul_tn_pooled(pool: &Pool, a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_tn_into_pooled(pool, a, b, &mut c);
    c
}

/// Allocating convenience: pooled C = A · Bᵀ.
pub fn matmul_nt_pooled(pool: &Pool, a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_nt_into_pooled(pool, a, b, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul::{matmul, matmul_nt, matmul_tn};
    use crate::util::Rng;

    #[test]
    fn pooled_matches_serial_bit_for_bit_across_thread_counts() {
        let mut rng = Rng::new(121);
        // (130, 110, 90) sits above MIN_PAR_MACS, so real row-band
        // parallelism (not the small-shape serial fallback) is exercised.
        for &(m, k, n) in
            &[(1, 1, 1), (5, 3, 7), (33, 17, 29), (64, 80, 48), (100, 1, 100), (130, 110, 90)]
        {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let bt = b.transpose();
            let a_t_src = Matrix::randn(k, m, 1.0, &mut rng);
            let b_tn = Matrix::randn(k, n, 1.0, &mut rng);
            let serial = matmul(&a, &b);
            let serial_nt = matmul_nt(&a, &bt);
            let serial_tn = matmul_tn(&a_t_src, &b_tn);
            for threads in [1usize, 2, 8] {
                let pool = Pool::with_threads(threads);
                assert_eq!(matmul_pooled(&pool, &a, &b).data, serial.data, "nn t={threads}");
                assert_eq!(matmul_nt_pooled(&pool, &a, &bt).data, serial_nt.data, "nt t={threads}");
                assert_eq!(
                    matmul_tn_pooled(&pool, &a_t_src, &b_tn).data,
                    serial_tn.data,
                    "tn t={threads}"
                );
            }
        }
    }

    #[test]
    fn pooled_axpy_matches_serial_bit_for_bit_across_thread_counts() {
        use crate::linalg::matmul::{matmul_axpy_into, matmul_nt_axpy_into};
        let mut rng = Rng::new(124);
        // (130, 110, 90) exceeds MIN_PAR_MACS so the real fan-out runs.
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (17, 9, 23), (130, 110, 90)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let bt = b.transpose();
            let base = Matrix::randn(m, n, 1.0, &mut rng);
            let alpha = -0.37f32;
            let mut serial = base.clone();
            matmul_axpy_into(&a, &b, alpha, &mut serial);
            let mut serial_nt = base.clone();
            matmul_nt_axpy_into(&a, &bt, alpha, &mut serial_nt);
            for threads in [1usize, 2, 8] {
                let pool = Pool::with_threads(threads);
                let mut c = base.clone();
                matmul_axpy_into_pooled(&pool, &a, &b, alpha, &mut c);
                assert_eq!(c.data, serial.data, "axpy t={threads} ({m},{k},{n})");
                let mut cnt = base.clone();
                matmul_nt_axpy_into_pooled(&pool, &a, &bt, alpha, &mut cnt);
                assert_eq!(cnt.data, serial_nt.data, "nt_axpy t={threads} ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn pooled_into_overwrites_dirty_buffers() {
        let mut rng = Rng::new(122);
        let a = Matrix::randn(19, 7, 1.0, &mut rng);
        let b = Matrix::randn(7, 13, 1.0, &mut rng);
        let pool = Pool::with_threads(4);
        let mut c = Matrix::from_fn(19, 13, |i, j| (i * j) as f32 - 3.0);
        matmul_into_pooled(&pool, &a, &b, &mut c);
        assert_eq!(c.data, matmul(&a, &b).data);
    }

    #[test]
    fn more_threads_than_rows() {
        let mut rng = Rng::new(123);
        let a = Matrix::randn(2, 40, 1.0, &mut rng);
        let b = Matrix::randn(40, 6, 1.0, &mut rng);
        let pool = Pool::with_threads(16);
        assert_eq!(matmul_pooled(&pool, &a, &b).data, matmul(&a, &b).data);
    }
}
