//! Dense linear algebra on [`crate::tensor::Matrix`]: blocked matmul,
//! Householder QR, one-sided Jacobi SVD, and the power-iteration
//! randomized SVD at the core of Lotus (§3.2 of the paper).
//!
//! The exact Jacobi SVD is the stand-in for the LAPACK `gesvd` call that
//! GaLore performs at every projector refresh; the randomized SVD is
//! Lotus's replacement. `benches/rsvd_speed.rs` sweeps both to reproduce
//! the paper's complexity claim (rSVD cost `O(r·mn)` vs SVD
//! `O(min(m,n)·mn)` with a much larger constant).

pub mod matmul;
pub mod par;
pub mod qr;
pub mod svd;
pub mod rsvd;
pub mod norms;

pub use matmul::{matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_tn, matmul_tn_into};
pub use par::{matmul_into_pooled, matmul_nt_into_pooled, matmul_pooled, matmul_tn_into_pooled};
pub use qr::{orthonormalize_into, qr_thin, QrThin};
pub use svd::{svd_jacobi, Svd};
pub use rsvd::{rsvd, rsvd_range, rsvd_range_into, RsvdOpts, RsvdScratch};
pub use norms::{spectral_norm_est, principal_angle_cos, orthonormality_error};
