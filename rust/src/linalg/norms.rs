//! Matrix norms and subspace-distance diagnostics used by the tests and
//! the subspace-quality instrumentation.

use crate::linalg::matmul::{matmul_tn, matvec, matvec_t};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Estimate the spectral norm ‖A‖₂ by power iteration on AᵀA.
pub fn spectral_norm_est(a: &Matrix, iters: usize, rng: &mut Rng) -> f32 {
    let n = a.cols;
    let mut v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut norm = 0.0f32;
    for _ in 0..iters {
        let av = matvec(a, &v);
        let atav = matvec_t(a, &av);
        norm = atav.iter().map(|x| x * x).sum::<f32>().sqrt().sqrt();
        let inv = 1.0 / atav.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-30);
        v = atav.iter().map(|x| x * inv).collect();
    }
    // one more application for the Rayleigh quotient
    let av = matvec(a, &v);
    let num = av.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
    let den = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().max(1e-30);
    let _ = norm;
    (num / den).sqrt() as f32
}

/// ‖QᵀQ − I‖_F — zero iff the columns of Q are orthonormal.
pub fn orthonormality_error(q: &Matrix) -> f32 {
    let g = matmul_tn(q, q);
    let mut err = 0.0f64;
    for i in 0..g.rows {
        for j in 0..g.cols {
            let expect = if i == j { 1.0 } else { 0.0 };
            let d = (g.at(i, j) - expect) as f64;
            err += d * d;
        }
    }
    err.sqrt() as f32
}

/// Smallest cosine of the principal angles between the column spans of
/// two orthonormal bases P (m×r) and U (m×r): σ_min(Pᵀ U). 1.0 means the
/// subspaces coincide.
pub fn principal_angle_cos(p: &Matrix, u: &Matrix) -> f32 {
    assert_eq!(p.rows, u.rows);
    let g = matmul_tn(p, u); // r×r
    let svd = crate::linalg::svd::svd_jacobi(&g);
    *svd.s.last().unwrap_or(&0.0)
}

/// Fraction of `a`'s Frobenius energy captured by projecting onto the
/// column span of orthonormal `p`: ‖Pᵀa‖²_F / ‖a‖²_F ∈ [0, 1].
pub fn captured_energy(p: &Matrix, a: &Matrix) -> f64 {
    let pa = matmul_tn(p, a);
    pa.fro_norm_sq() / a.fro_norm_sq().max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::orthonormalize;
    use crate::linalg::svd::svd_jacobi;

    #[test]
    fn spectral_norm_matches_svd() {
        let mut rng = Rng::new(61);
        let a = Matrix::randn(40, 30, 1.0, &mut rng);
        let est = spectral_norm_est(&a, 50, &mut rng);
        let exact = svd_jacobi(&a).s[0];
        assert!((est - exact).abs() / exact < 0.02, "est={est} exact={exact}");
    }

    #[test]
    fn orthonormality_error_zero_for_identity() {
        assert!(orthonormality_error(&Matrix::eye(8)) < 1e-6);
    }

    #[test]
    fn principal_angle_self_is_one() {
        let mut rng = Rng::new(62);
        let q = orthonormalize(&Matrix::randn(50, 5, 1.0, &mut rng));
        assert!((principal_angle_cos(&q, &q) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn principal_angle_orthogonal_is_zero() {
        // e1..e3 span vs e4..e6 span
        let mut p = Matrix::zeros(10, 3);
        let mut u = Matrix::zeros(10, 3);
        for i in 0..3 {
            *p.at_mut(i, i) = 1.0;
            *u.at_mut(i + 3, i) = 1.0;
        }
        assert!(principal_angle_cos(&p, &u) < 1e-6);
    }

    #[test]
    fn captured_energy_bounds() {
        let mut rng = Rng::new(63);
        let a = Matrix::randn(30, 20, 1.0, &mut rng);
        let q = orthonormalize(&Matrix::randn(30, 5, 1.0, &mut rng));
        let e = captured_energy(&q, &a);
        assert!((0.0..=1.0 + 1e-6).contains(&e));
        // full basis captures everything
        let full = orthonormalize(&Matrix::randn(30, 30, 1.0, &mut rng));
        assert!((captured_energy(&full, &a) - 1.0).abs() < 1e-4);
    }
}
