//! Evaluation metrics matching the paper's Table 2 reporting: accuracy,
//! F1 (MRPC), Matthews correlation (CoLA), Pearson correlation (STS-B),
//! plus perplexity for the Table 1 pre-training runs.

/// Perplexity from a mean cross-entropy (nats).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// Classification accuracy.
pub fn accuracy(preds: &[usize], labels: &[usize]) -> f64 {
    assert_eq!(preds.len(), labels.len());
    if preds.is_empty() {
        return 0.0;
    }
    let ok = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    ok as f64 / preds.len() as f64
}

/// Binary confusion counts (positive class = 1).
fn confusion(preds: &[usize], labels: &[usize]) -> (f64, f64, f64, f64) {
    let (mut tp, mut fp, mut fn_, mut tn) = (0.0, 0.0, 0.0, 0.0);
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fn_ += 1.0,
            (0, 0) => tn += 1.0,
            _ => panic!("binary metric on non-binary labels"),
        }
    }
    (tp, fp, fn_, tn)
}

/// F1 score of the positive class (MRPC's reported metric).
pub fn f1(preds: &[usize], labels: &[usize]) -> f64 {
    let (tp, fp, fn_, _) = confusion(preds, labels);
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fn_);
    2.0 * precision * recall / (precision + recall)
}

/// Matthews correlation coefficient (CoLA's reported metric).
pub fn matthews(preds: &[usize], labels: &[usize]) -> f64 {
    let (tp, fp, fn_, tn) = confusion(preds, labels);
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (tp * tn - fp * fn_) / denom
}

/// Pearson correlation (STS-B's reported metric).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (xi, yi) in x.iter().zip(y) {
        let dx = xi - mx;
        let dy = yi - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Mean NLL → bits per token (diagnostic).
pub fn bits_per_token(mean_nll: f64) -> f64 {
    mean_nll / std::f64::consts::LN_2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert_eq!(f1(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1(&[0, 0], &[1, 1]), 0.0);
    }

    #[test]
    fn matthews_range_and_signs() {
        // perfect prediction → +1
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        // perfectly wrong → −1
        assert!((matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
        // constant prediction → 0
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 0, 1, 0]), 0.0);
    }

    #[test]
    fn pearson_known_values() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let y_neg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &y_neg) + 1.0).abs() < 1e-12);
        let y_const = [5.0, 5.0, 5.0, 5.0];
        assert_eq!(pearson(&x, &y_const), 0.0);
    }

    #[test]
    fn perplexity_of_uniform() {
        // uniform over 100 classes: nll = ln(100) → ppl = 100
        assert!((perplexity(100.0f64.ln()) - 100.0).abs() < 1e-9);
        assert!((bits_per_token(2.0f64.ln()) - 1.0).abs() < 1e-12);
    }
}
