//! Subspace switching policies — *when* to refresh the projector.
//!
//! This module is the paper's headline contribution (AdaSS, §3.1,
//! Algorithm 1) plus every policy it is compared against:
//!
//! * [`FixedInterval`] — GaLore: refresh every `T` steps, unconditionally.
//! * [`LotusAdaSS`] — Algorithm 1: track the *unit gradient displacement*
//!   inside the current subspace. Every `η` (verifying gap) steps,
//!   compute `‖d̄‖ = ‖d_cur − d_init‖ / T`; when it drops below `γ` the
//!   gradient direction has stopped moving in this subspace (saddle /
//!   minimum / exhausted subspace) → switch. `T_min` suppresses early
//!   noisy switches.
//! * [`PathEfficiency`] — the ρ_t variant (Eq. 3): windowed ratio of
//!   projected to ideal displacement; switch when ρ_t < γ_ρ.
//! * [`AdaRank`] — AdaRankGrad-like: fixed interval, but shrink the rank
//!   geometrically as training proceeds (captures its memory advantage).
//!
//! All policies implement [`SwitchPolicy`] and feed [`SubspaceStats`],
//! which reproduces Table 3 (subspace count / switching frequency).

pub mod theory;

use crate::tensor::Matrix;

/// Decision returned by a policy after observing a step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Keep the current subspace.
    Keep,
    /// Re-fit the projector from the current full-rank gradient.
    Switch(SwitchReason),
}

/// Why a switch was triggered (logged; benches bucket on this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchReason {
    /// Fixed interval elapsed (GaLore).
    Interval,
    /// Unit-gradient displacement fell below γ (Lotus Algorithm 1).
    Displacement,
    /// Path-efficiency ρ_t fell below threshold (Eq. 3 variant).
    PathEfficiency,
    /// First step (no subspace yet).
    Init,
}

/// Per-step observation handed to the policy: the *low-rank* gradient in
/// the current subspace (what Algorithm 1 calls `G_cur = O_G · G_F`).
pub struct Observation<'a> {
    /// Low-rank projected gradient R (r×n or m×r depending on side).
    pub low_grad: &'a Matrix,
    /// Global step index.
    pub step: u64,
}

/// A subspace switching policy. Implementations are per-layer (each
/// weight matrix carries its own policy state, as in GaLore/Lotus).
pub trait SwitchPolicy: Send {
    /// Called after a projector (re-)fit with the first projected
    /// gradient in the new subspace.
    fn reset(&mut self, first_low_grad: &Matrix, step: u64);
    /// Observe a step in the current subspace; decide whether to switch.
    fn observe(&mut self, obs: &Observation<'_>) -> Decision;
    /// Name for logs and bench tables.
    fn name(&self) -> &'static str;
    /// Optional: the diagnostic the policy thresholds on (‖d̄‖ or ρ_t),
    /// for Fig. 1 style traces. None when not yet defined.
    fn diagnostic(&self) -> Option<f64>;
    /// Optional: the scalar threshold the diagnostic is compared against
    /// (γ for Lotus AdaSS, γ_ρ for path efficiency). Policies without a
    /// threshold (fixed interval, rank schedules) return None. Together
    /// with [`SwitchPolicy::diagnostic`] this defines the probe margin
    /// `diagnostic − threshold` reported by `telemetry::diag` — negative
    /// means the policy is inside its switch region.
    fn threshold(&self) -> Option<f64> {
        None
    }
    /// Persistent policy state for checkpointing — decisions after a
    /// restore are identical to an uninterrupted run.
    fn export_state(&self) -> PolicyState;
    /// Restore an [`SwitchPolicy::export_state`] snapshot; rejects a
    /// snapshot taken from a different policy kind.
    fn restore_state(&mut self, state: PolicyState) -> Result<(), String>;
}

/// Typed persistent state of a [`SwitchPolicy`] — one variant per
/// policy, serialized into checkpoint tensors via the 16-bit-limb codec
/// ([`PolicyState::to_tensors`] / [`PolicyState::from_tensors`]).
#[derive(Clone, Debug)]
pub enum PolicyState {
    /// [`FixedInterval`]: the last-switch step.
    Fixed { last_switch: u64 },
    /// [`LotusAdaSS`]: birth unit gradient, projection count T,
    /// last-switch step.
    Lotus { d_init: Option<Matrix>, project_count: u64, last_switch: u64 },
    /// [`PathEfficiency`]: window accumulator, window fill, last switch.
    PathEfficiency { acc: Option<Matrix>, count: u64, last_switch: u64 },
    /// [`AdaRank`]: current (decayed) rank and last-switch step.
    AdaRank { current_rank: u64, last_switch: u64 },
}

impl PolicyState {
    /// Serialize as named f32 tensors under `prefix`: a `{prefix}/meta`
    /// row (`[kind, counters…]` with counters as exact 16-bit limbs)
    /// plus an optional matrix tensor for the Lotus/PathEfficiency
    /// accumulators.
    pub fn to_tensors(&self, prefix: &str, out: &mut Vec<(String, Matrix)>) {
        use crate::util::codec::push_u64;
        match self {
            PolicyState::Fixed { last_switch } => {
                let mut meta = vec![0.0f32];
                push_u64(&mut meta, *last_switch);
                let cols = meta.len();
                out.push((format!("{prefix}/meta"), Matrix::from_vec(1, cols, meta)));
            }
            PolicyState::Lotus { d_init, project_count, last_switch } => {
                let mut meta = vec![1.0f32];
                push_u64(&mut meta, *project_count);
                push_u64(&mut meta, *last_switch);
                meta.push(if d_init.is_some() { 1.0 } else { 0.0 });
                let cols = meta.len();
                out.push((format!("{prefix}/meta"), Matrix::from_vec(1, cols, meta)));
                if let Some(d) = d_init {
                    out.push((format!("{prefix}/d_init"), d.clone()));
                }
            }
            PolicyState::PathEfficiency { acc, count, last_switch } => {
                let mut meta = vec![2.0f32];
                push_u64(&mut meta, *count);
                push_u64(&mut meta, *last_switch);
                meta.push(if acc.is_some() { 1.0 } else { 0.0 });
                let cols = meta.len();
                out.push((format!("{prefix}/meta"), Matrix::from_vec(1, cols, meta)));
                if let Some(a) = acc {
                    out.push((format!("{prefix}/acc"), a.clone()));
                }
            }
            PolicyState::AdaRank { current_rank, last_switch } => {
                let mut meta = vec![3.0f32];
                push_u64(&mut meta, *current_rank);
                push_u64(&mut meta, *last_switch);
                let cols = meta.len();
                out.push((format!("{prefix}/meta"), Matrix::from_vec(1, cols, meta)));
            }
        }
    }

    /// Inverse of [`PolicyState::to_tensors`].
    pub fn from_tensors(
        prefix: &str,
        tensors: &[(String, Matrix)],
    ) -> Result<PolicyState, String> {
        use crate::util::codec::read_u64_limbs;
        let find = |leaf: &str| {
            let name = format!("{prefix}/{leaf}");
            tensors.iter().find(|(n, _)| *n == name).map(|(_, m)| m)
        };
        let meta = find("meta").ok_or_else(|| format!("missing policy meta at '{prefix}'"))?;
        match meta.data[0] as i64 {
            0 => Ok(PolicyState::Fixed { last_switch: read_u64_limbs(&meta.data, 1) }),
            1 => {
                let d_init = if meta.data[9] != 0.0 {
                    Some(
                        find("d_init")
                            .ok_or_else(|| format!("missing d_init at '{prefix}'"))?
                            .clone(),
                    )
                } else {
                    None
                };
                Ok(PolicyState::Lotus {
                    d_init,
                    project_count: read_u64_limbs(&meta.data, 1),
                    last_switch: read_u64_limbs(&meta.data, 5),
                })
            }
            2 => {
                let acc = if meta.data[9] != 0.0 {
                    Some(find("acc").ok_or_else(|| format!("missing acc at '{prefix}'"))?.clone())
                } else {
                    None
                };
                Ok(PolicyState::PathEfficiency {
                    acc,
                    count: read_u64_limbs(&meta.data, 1),
                    last_switch: read_u64_limbs(&meta.data, 5),
                })
            }
            3 => Ok(PolicyState::AdaRank {
                current_rank: read_u64_limbs(&meta.data, 1),
                last_switch: read_u64_limbs(&meta.data, 5),
            }),
            k => Err(format!("unknown policy kind {k} at '{prefix}'")),
        }
    }
}

// ---------------------------------------------------------------------
// GaLore: fixed interval
// ---------------------------------------------------------------------

/// Refresh every `interval` steps regardless of gradient behaviour.
pub struct FixedInterval {
    pub interval: u64,
    last_switch: u64,
}

impl FixedInterval {
    pub fn new(interval: u64) -> Self {
        assert!(interval > 0);
        FixedInterval { interval, last_switch: 0 }
    }

    /// Persistent policy state (the last-switch step) for checkpointing.
    pub fn snapshot(&self) -> u64 {
        self.last_switch
    }

    /// Restore a [`FixedInterval::snapshot`] (checkpoint resume).
    pub fn restore(&mut self, last_switch: u64) {
        self.last_switch = last_switch;
    }
}

impl SwitchPolicy for FixedInterval {
    fn reset(&mut self, _first: &Matrix, step: u64) {
        self.last_switch = step;
    }

    fn observe(&mut self, obs: &Observation<'_>) -> Decision {
        if obs.step - self.last_switch >= self.interval {
            Decision::Switch(SwitchReason::Interval)
        } else {
            Decision::Keep
        }
    }

    fn name(&self) -> &'static str {
        "fixed"
    }

    fn diagnostic(&self) -> Option<f64> {
        None
    }

    fn export_state(&self) -> PolicyState {
        PolicyState::Fixed { last_switch: self.last_switch }
    }

    fn restore_state(&mut self, state: PolicyState) -> Result<(), String> {
        match state {
            PolicyState::Fixed { last_switch } => {
                self.last_switch = last_switch;
                Ok(())
            }
            other => Err(format!("fixed-interval policy cannot restore {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------
// Lotus: adaptive subspace switching (Algorithm 1)
// ---------------------------------------------------------------------

/// Algorithm 1: displacement of the unit low-rank gradient.
///
/// State per subspace: `d_init = normalize(G_init)` captured at the fit,
/// and the project count `T`. Every `eta` steps compute
/// `‖d̄‖ = ‖normalize(G_cur) − d_init‖ / T` and switch when `‖d̄‖ < γ`
/// and at least `t_min` steps have passed since the last switch.
///
/// Intuition: while the subspace is useful, the unit gradient keeps
/// rotating away from where it started (large displacement per step).
/// When it stops moving — oscillation at a saddle/minimum, or all motion
/// now lives outside the span — displacement-per-step collapses and the
/// subspace should be refreshed.
pub struct LotusAdaSS {
    /// Displacement threshold γ (paper: 0.005–0.02; default 0.01).
    pub gamma: f64,
    /// Verifying gap η in steps (paper: 25–100; default 50).
    pub eta: u64,
    /// Minimum steps between switches T_min.
    pub t_min: u64,
    d_init: Option<Matrix>,
    /// Scratch for the normalized current gradient — reused every
    /// observation so the steady-state hot path never allocates.
    d_cur: Matrix,
    project_count: u64,
    last_switch_step: u64,
    last_diag: Option<f64>,
}

impl LotusAdaSS {
    pub fn new(gamma: f64, eta: u64, t_min: u64) -> Self {
        assert!(gamma > 0.0 && eta > 0);
        LotusAdaSS {
            gamma,
            eta,
            t_min,
            d_init: None,
            d_cur: Matrix::zeros(0, 0),
            project_count: 0,
            last_switch_step: 0,
            last_diag: None,
        }
    }

    /// Paper defaults for fine-tuning: γ=0.01, η=50, T_min=η.
    pub fn paper_defaults() -> Self {
        LotusAdaSS::new(0.01, 50, 50)
    }

    /// Persistent policy state for checkpointing: (d_init, projection
    /// count T, last-switch step). The scratch buffer and the cached
    /// diagnostic are not persistent (the diagnostic re-appears at the
    /// next η boundary).
    pub fn snapshot(&self) -> (Option<&Matrix>, u64, u64) {
        (self.d_init.as_ref(), self.project_count, self.last_switch_step)
    }

    /// Restore a [`LotusAdaSS::snapshot`] (checkpoint resume): decisions
    /// after the restore are identical to an uninterrupted run.
    pub fn restore(&mut self, d_init: Option<Matrix>, project_count: u64, last_switch_step: u64) {
        self.d_init = d_init;
        self.project_count = project_count;
        self.last_switch_step = last_switch_step;
        self.last_diag = None;
    }
}

/// `dst ← NORMALIZE(src)` into a reusable buffer — the arithmetic twin
/// of [`Matrix::normalized`] without the allocation.
fn normalize_into(src: &Matrix, dst: &mut Matrix) {
    dst.copy_from(src);
    let n = dst.fro_norm();
    if n > f32::EPSILON {
        dst.scale(1.0 / n);
    }
}

impl SwitchPolicy for LotusAdaSS {
    fn reset(&mut self, first_low_grad: &Matrix, step: u64) {
        // d_init ← NORMALIZE(G_init); T ← 1 (buffer reused across resets)
        match &mut self.d_init {
            Some(d) => normalize_into(first_low_grad, d),
            None => self.d_init = Some(first_low_grad.normalized()),
        }
        self.project_count = 1;
        self.last_switch_step = step;
        self.last_diag = None;
    }

    fn observe(&mut self, obs: &Observation<'_>) -> Decision {
        let d_init = match &self.d_init {
            Some(d) => d,
            None => return Decision::Switch(SwitchReason::Init),
        };
        // d_cur ← NORMALIZE(G_cur); T ← T + 1
        normalize_into(obs.low_grad, &mut self.d_cur);
        self.project_count += 1;

        if self.project_count % self.eta == 0 {
            // ‖d̄‖ ← ‖d_cur − d_init‖ / T, with the difference reduced
            // on the fly (same f32-subtract / f64-accumulate arithmetic
            // as the materialized `sub` + `fro_norm`).
            assert_eq!(
                self.d_cur.shape(),
                d_init.shape(),
                "low-rank gradient shape changed without a policy reset"
            );
            let mut acc = 0.0f64;
            for (a, b) in self.d_cur.data.iter().zip(&d_init.data) {
                let d = (*a - *b) as f64;
                acc += d * d;
            }
            let avg_disp = acc.sqrt() as f32 as f64 / self.project_count as f64;
            self.last_diag = Some(avg_disp);
            let elapsed = obs.step.saturating_sub(self.last_switch_step);
            if avg_disp < self.gamma && elapsed >= self.t_min {
                return Decision::Switch(SwitchReason::Displacement);
            }
        }
        Decision::Keep
    }

    fn name(&self) -> &'static str {
        "lotus-adass"
    }

    fn diagnostic(&self) -> Option<f64> {
        self.last_diag
    }

    fn threshold(&self) -> Option<f64> {
        Some(self.gamma)
    }

    fn export_state(&self) -> PolicyState {
        PolicyState::Lotus {
            d_init: self.d_init.clone(),
            project_count: self.project_count,
            last_switch: self.last_switch_step,
        }
    }

    fn restore_state(&mut self, state: PolicyState) -> Result<(), String> {
        match state {
            PolicyState::Lotus { d_init, project_count, last_switch } => {
                self.restore(d_init, project_count, last_switch);
                Ok(())
            }
            other => Err(format!("lotus-adass policy cannot restore {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------
// Path-efficiency variant (Eq. 3)
// ---------------------------------------------------------------------

/// ρ_t = ‖Σᵢ P ĝᵢ‖ / ‖Σᵢ ĝᵢ‖ over a sliding window of k unit gradients.
///
/// The paper defines ρ_t on the *full-rank* unit gradients with the
/// subspace projection applied; inside the trainer we receive the
/// low-rank gradient and its pre-projection norm, so we track
/// `‖Σ R̂ᵢ‖ / Σ 1` — the displacement the projected unit steps actually
/// achieve versus the ideal perfectly-aligned k·1 (Eq. 1/2 with unit
/// norms). ρ_t ∈ [0,1]; low values mean cancellation / drift out of span.
pub struct PathEfficiency {
    /// Window length k.
    pub window: usize,
    /// Threshold on ρ_t.
    pub gamma_rho: f64,
    /// Minimum steps between switches.
    pub t_min: u64,
    /// Accumulator of unit projected gradients (sum of k unit matrices).
    acc: Option<Matrix>,
    count: usize,
    last_switch_step: u64,
    last_diag: Option<f64>,
}

impl PathEfficiency {
    pub fn new(window: usize, gamma_rho: f64, t_min: u64) -> Self {
        assert!(window > 0);
        PathEfficiency {
            window,
            gamma_rho,
            t_min,
            acc: None,
            count: 0,
            last_switch_step: 0,
            last_diag: None,
        }
    }

    /// ρ_t of the current window (None until the window fills).
    pub fn rho(&self) -> Option<f64> {
        self.last_diag
    }
}

impl SwitchPolicy for PathEfficiency {
    fn reset(&mut self, first: &Matrix, step: u64) {
        let mut acc = first.normalized();
        acc.scale(1.0); // explicit copy semantics
        self.acc = Some(acc);
        self.count = 1;
        self.last_switch_step = step;
        self.last_diag = None;
    }

    fn observe(&mut self, obs: &Observation<'_>) -> Decision {
        let unit = obs.low_grad.normalized();
        match &mut self.acc {
            None => return Decision::Switch(SwitchReason::Init),
            Some(acc) => {
                acc.axpy(1.0, &unit);
                self.count += 1;
            }
        }
        if self.count >= self.window {
            let acc = self.acc.as_ref().unwrap();
            // ideal displacement of k unit steps = k; actual = ‖Σ ĝ‖
            let rho = acc.fro_norm() as f64 / self.count as f64;
            self.last_diag = Some(rho);
            let elapsed = obs.step.saturating_sub(self.last_switch_step);
            // restart the window either way
            self.acc = None;
            self.count = 0;
            if rho < self.gamma_rho && elapsed >= self.t_min {
                return Decision::Switch(SwitchReason::PathEfficiency);
            }
            // re-seed the accumulator with the current unit gradient
            self.acc = Some(unit);
            self.count = 1;
        }
        Decision::Keep
    }

    fn name(&self) -> &'static str {
        "path-efficiency"
    }

    fn diagnostic(&self) -> Option<f64> {
        self.last_diag
    }

    fn threshold(&self) -> Option<f64> {
        Some(self.gamma_rho)
    }

    fn export_state(&self) -> PolicyState {
        PolicyState::PathEfficiency {
            acc: self.acc.clone(),
            count: self.count as u64,
            last_switch: self.last_switch_step,
        }
    }

    fn restore_state(&mut self, state: PolicyState) -> Result<(), String> {
        match state {
            PolicyState::PathEfficiency { acc, count, last_switch } => {
                self.acc = acc;
                self.count = count as usize;
                self.last_switch_step = last_switch;
                self.last_diag = None;
                Ok(())
            }
            other => Err(format!("path-efficiency policy cannot restore {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------
// AdaRankGrad-like: fixed interval + geometric rank decay
// ---------------------------------------------------------------------

/// Fixed-interval switching with a rank schedule that shrinks over time
/// (AdaRankGrad observes the intrinsic gradient rank decays during
/// training and harvests memory by lowering r).
pub struct AdaRank {
    pub interval: u64,
    /// Multiplicative rank decay per switch (e.g. 0.9), floored.
    pub decay: f64,
    pub min_rank: usize,
    current_rank: usize,
    last_switch: u64,
}

impl AdaRank {
    pub fn new(interval: u64, start_rank: usize, decay: f64, min_rank: usize) -> Self {
        AdaRank { interval, decay, min_rank, current_rank: start_rank, last_switch: 0 }
    }

    /// Rank to use for the *next* projector fit.
    pub fn rank(&self) -> usize {
        self.current_rank
    }

    /// Called by the trainer after a switch to advance the schedule.
    pub fn advance(&mut self) {
        let next = (self.current_rank as f64 * self.decay).floor() as usize;
        self.current_rank = next.max(self.min_rank);
    }

    /// Rewind the schedule to a checkpointed rank (resume).
    pub fn restore_rank(&mut self, rank: usize) {
        self.current_rank = rank.max(self.min_rank);
    }
}

impl SwitchPolicy for AdaRank {
    fn reset(&mut self, _first: &Matrix, step: u64) {
        self.last_switch = step;
    }

    fn observe(&mut self, obs: &Observation<'_>) -> Decision {
        if obs.step - self.last_switch >= self.interval {
            Decision::Switch(SwitchReason::Interval)
        } else {
            Decision::Keep
        }
    }

    fn name(&self) -> &'static str {
        "adarank"
    }

    fn diagnostic(&self) -> Option<f64> {
        Some(self.current_rank as f64)
    }

    fn export_state(&self) -> PolicyState {
        PolicyState::AdaRank {
            current_rank: self.current_rank as u64,
            last_switch: self.last_switch,
        }
    }

    fn restore_state(&mut self, state: PolicyState) -> Result<(), String> {
        match state {
            PolicyState::AdaRank { current_rank, last_switch } => {
                self.current_rank = (current_rank as usize).max(self.min_rank);
                self.last_switch = last_switch;
                Ok(())
            }
            other => Err(format!("adarank policy cannot restore {other:?}")),
        }
    }
}

// ---------------------------------------------------------------------
// Stats (Table 3)
// ---------------------------------------------------------------------

/// Aggregate switching statistics across layers and steps — the data
/// behind Table 3 ("Subspace Account" = total subspaces instantiated,
/// "Switching Frequency" = switches per 100 steps per layer).
#[derive(Clone, Debug, Default)]
pub struct SubspaceStats {
    /// Total subspaces instantiated (across all layers).
    pub subspace_count: u64,
    /// Total policy observations (layer-steps).
    pub observations: u64,
    /// Switches by reason.
    pub by_reason: [u64; 4],
    /// Steps each retired subspace lived (for lifetime histograms).
    pub lifetimes: Vec<u64>,
    /// Adapter merge-and-restart events (ReLoRA's
    /// [`crate::optim::StepEvent::Merged`]).
    pub merges: u64,
}

impl SubspaceStats {
    pub fn record_switch(&mut self, reason: SwitchReason, lifetime: u64) {
        self.subspace_count += 1;
        self.by_reason[match reason {
            SwitchReason::Interval => 0,
            SwitchReason::Displacement => 1,
            SwitchReason::PathEfficiency => 2,
            SwitchReason::Init => 3,
        }] += 1;
        if reason != SwitchReason::Init {
            self.lifetimes.push(lifetime);
        }
    }

    pub fn record_observation(&mut self) {
        self.observations += 1;
    }

    pub fn record_merge(&mut self) {
        self.merges += 1;
    }

    /// Switches per 100 layer-steps (the paper's "frequency" column).
    pub fn frequency_per_100(&self) -> f64 {
        if self.observations == 0 {
            return 0.0;
        }
        100.0 * (self.subspace_count as f64) / (self.observations as f64)
    }

    pub fn mean_lifetime(&self) -> f64 {
        if self.lifetimes.is_empty() {
            return 0.0;
        }
        self.lifetimes.iter().sum::<u64>() as f64 / self.lifetimes.len() as f64
    }

    pub fn merge(&mut self, other: &SubspaceStats) {
        self.subspace_count += other.subspace_count;
        self.observations += other.observations;
        for i in 0..4 {
            self.by_reason[i] += other.by_reason[i];
        }
        self.lifetimes.extend_from_slice(&other.lifetimes);
        self.merges += other.merges;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randg(rng: &mut Rng) -> Matrix {
        Matrix::randn(4, 16, 1.0, rng)
    }

    #[test]
    fn fixed_interval_triggers_exactly() {
        let mut p = FixedInterval::new(10);
        let mut rng = Rng::new(81);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        for step in 1..10 {
            let g = randg(&mut rng);
            assert_eq!(p.observe(&Observation { low_grad: &g, step }), Decision::Keep);
        }
        let g = randg(&mut rng);
        assert_eq!(
            p.observe(&Observation { low_grad: &g, step: 10 }),
            Decision::Switch(SwitchReason::Interval)
        );
    }

    #[test]
    fn lotus_switches_on_stalled_direction() {
        // gradient direction frozen → displacement/Τ → 0 → must switch
        let mut p = LotusAdaSS::new(0.01, 5, 0);
        let mut rng = Rng::new(82);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        let mut switched = false;
        for step in 1..200 {
            // same direction, varying magnitude (magnitude must not matter)
            let mut g = g0.clone();
            g.scale(1.0 + (step as f32 * 0.37).sin().abs());
            if let Decision::Switch(r) = p.observe(&Observation { low_grad: &g, step }) {
                assert_eq!(r, SwitchReason::Displacement);
                switched = true;
                break;
            }
        }
        assert!(switched, "stalled unit gradient must trigger AdaSS");
    }

    #[test]
    fn lotus_keeps_moving_subspace() {
        // rapidly rotating gradient → large displacement → no switch
        let mut p = LotusAdaSS::new(0.01, 5, 0);
        let mut rng = Rng::new(83);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        for step in 1..100 {
            let g = randg(&mut rng); // fresh random direction every step
            assert_eq!(
                p.observe(&Observation { low_grad: &g, step }),
                Decision::Keep,
                "step {step}"
            );
        }
    }

    #[test]
    fn lotus_respects_t_min() {
        let mut p = LotusAdaSS::new(0.5, 2, 1000); // would switch immediately but for t_min
        let mut rng = Rng::new(84);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        for step in 1..100 {
            let g = g0.clone();
            assert_eq!(p.observe(&Observation { low_grad: &g, step }), Decision::Keep);
        }
    }

    #[test]
    fn lotus_checks_only_at_eta_boundaries() {
        let mut p = LotusAdaSS::new(10.0, 7, 0); // absurd γ: any check switches
        let mut rng = Rng::new(85);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0); // T = 1
        let mut first_switch_step = None;
        for step in 1..30 {
            let g = randg(&mut rng);
            if let Decision::Switch(_) = p.observe(&Observation { low_grad: &g, step }) {
                first_switch_step = Some(step);
                break;
            }
        }
        // T reaches 7 after 6 observations → first possible switch at step 6
        assert_eq!(first_switch_step, Some(6));
    }

    #[test]
    fn displacement_is_scale_invariant() {
        // Two runs, gradients differ only by a 1000x scale: identical decisions.
        let mut rng = Rng::new(86);
        let seq: Vec<Matrix> = (0..40).map(|_| randg(&mut rng)).collect();
        let run = |scale: f32| -> Vec<bool> {
            let mut p = LotusAdaSS::new(0.02, 5, 0);
            let mut g0 = seq[0].clone();
            g0.scale(scale);
            p.reset(&g0, 0);
            seq[1..]
                .iter()
                .enumerate()
                .map(|(i, g)| {
                    let mut gs = g.clone();
                    gs.scale(scale);
                    matches!(
                        p.observe(&Observation { low_grad: &gs, step: i as u64 + 1 }),
                        Decision::Switch(_)
                    )
                })
                .collect()
        };
        assert_eq!(run(1.0), run(1000.0));
    }

    #[test]
    fn lotus_snapshot_restore_preserves_decisions() {
        let mut rng = Rng::new(89);
        let seq: Vec<Matrix> = (0..30).map(|_| randg(&mut rng)).collect();
        let mut a = LotusAdaSS::new(0.02, 5, 0);
        a.reset(&seq[0], 0);
        for (i, g) in seq[1..11].iter().enumerate() {
            let _ = a.observe(&Observation { low_grad: g, step: i as u64 + 1 });
        }
        let (d, t, l) = {
            let (d, t, l) = a.snapshot();
            (d.cloned(), t, l)
        };
        let mut b = LotusAdaSS::new(0.02, 5, 0);
        b.restore(d, t, l);
        for (i, g) in seq[11..].iter().enumerate() {
            let step = i as u64 + 11;
            assert_eq!(
                a.observe(&Observation { low_grad: g, step }),
                b.observe(&Observation { low_grad: g, step }),
                "restored policy diverged at step {step}"
            );
        }
    }

    #[test]
    fn path_efficiency_bounds_and_triggers() {
        let mut p = PathEfficiency::new(8, 0.3, 0);
        let mut rng = Rng::new(87);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        // alternating ±g cancels → ρ → small → switch
        let mut switched = false;
        for step in 1..50 {
            let mut g = g0.clone();
            if step % 2 == 1 {
                g.scale(-1.0);
            }
            match p.observe(&Observation { low_grad: &g, step }) {
                Decision::Switch(r) => {
                    assert_eq!(r, SwitchReason::PathEfficiency);
                    if let Some(rho) = p.diagnostic() {
                        assert!((0.0..=1.0 + 1e-9).contains(&rho));
                    }
                    switched = true;
                    break;
                }
                Decision::Keep => {}
            }
        }
        assert!(switched);
    }

    #[test]
    fn path_efficiency_high_for_aligned_steps() {
        let mut p = PathEfficiency::new(8, 0.3, 0);
        let mut rng = Rng::new(88);
        let g0 = randg(&mut rng);
        p.reset(&g0, 0);
        for step in 1..40 {
            let g = g0.clone(); // perfectly aligned
            assert_eq!(p.observe(&Observation { low_grad: &g, step }), Decision::Keep);
        }
        // ρ for aligned steps is 1
        assert!(p.diagnostic().map(|d| d > 0.99).unwrap_or(false));
    }

    #[test]
    fn adarank_decays_rank_to_floor() {
        let mut p = AdaRank::new(10, 128, 0.5, 16);
        assert_eq!(p.rank(), 128);
        p.advance();
        assert_eq!(p.rank(), 64);
        for _ in 0..10 {
            p.advance();
        }
        assert_eq!(p.rank(), 16);
    }

    #[test]
    fn policy_state_roundtrips_through_tensors() {
        let mut rng = Rng::new(91);
        let mut p = LotusAdaSS::new(0.02, 5, 3);
        p.reset(&randg(&mut rng), 4);
        let probes: Vec<Matrix> = (0..30).map(|_| randg(&mut rng)).collect();
        for (i, g) in probes[..8].iter().enumerate() {
            let _ = p.observe(&Observation { low_grad: g, step: i as u64 + 5 });
        }
        let mut out = Vec::new();
        p.export_state().to_tensors("pol", &mut out);
        let back = PolicyState::from_tensors("pol", &out).unwrap();
        let mut q = LotusAdaSS::new(0.02, 5, 3);
        q.restore_state(back).unwrap();
        for (i, g) in probes[8..].iter().enumerate() {
            let step = i as u64 + 13;
            assert_eq!(
                p.observe(&Observation { low_grad: g, step }),
                q.observe(&Observation { low_grad: g, step }),
                "restored policy diverged at step {step}"
            );
        }
        // a snapshot from a different policy kind is rejected
        assert!(FixedInterval::new(5).restore_state(p.export_state()).is_err());
    }

    #[test]
    fn stats_frequency() {
        let mut s = SubspaceStats::default();
        for _ in 0..200 {
            s.record_observation();
        }
        for _ in 0..13 {
            s.record_switch(SwitchReason::Displacement, 15);
        }
        assert!((s.frequency_per_100() - 6.5).abs() < 1e-9);
        assert_eq!(s.by_reason[1], 13);
        assert!((s.mean_lifetime() - 15.0).abs() < 1e-9);
    }
}
