//! Empirical verification of the paper's theory section (§3.1):
//!
//! * **Lemma 3.1** (one-step projected decrease): for L-smooth loss and
//!   path-efficiency ρ, one projected step satisfies
//!   `L(w+1) ≤ L(w) − αρ²‖g‖² + ½α²L‖g‖²`.
//! * **Theorem 3.2** (adaptive beats fixed): the adaptive policy reaches
//!   a gradient-sum tolerance in no more iterations than the fixed one.
//!
//! These run as measurements on synthetic quadratics (where L-smoothness
//! is exact and ρ is controllable), turning the paper's claims into
//! executable checks rather than prose.

use crate::linalg::matmul::matvec;
use crate::tensor::Matrix;
use crate::util::Rng;

/// Quadratic loss L(w) = ½ wᵀ A w with SPD A (L = λ_max(A)).
pub struct Quadratic {
    pub a: Matrix,
    pub l_smooth: f64,
}

impl Quadratic {
    /// Random SPD quadratic with spectrum in [0.1, l_max].
    pub fn random(dim: usize, l_max: f64, rng: &mut Rng) -> Quadratic {
        // A = Q D Qᵀ with random orthogonal Q
        let q = crate::linalg::qr::orthonormalize(&Matrix::randn(dim, dim, 1.0, rng));
        let mut a = Matrix::zeros(dim, dim);
        for k in 0..dim {
            let d = 0.1 + (l_max - 0.1) * (k as f64 / (dim - 1).max(1) as f64);
            for i in 0..dim {
                for j in 0..dim {
                    a.data[i * dim + j] += (d as f32) * q.at(i, k) * q.at(j, k);
                }
            }
        }
        Quadratic { a, l_smooth: l_max }
    }

    pub fn loss(&self, w: &[f32]) -> f64 {
        let aw = matvec(&self.a, w);
        0.5 * w.iter().zip(&aw).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>()
    }

    pub fn grad(&self, w: &[f32]) -> Vec<f32> {
        matvec(&self.a, w)
    }
}

/// One projected gradient step `w ← w − α P Pᵀ g`; returns the realized
/// path-efficiency ρ = ‖Pᵀĝ‖ (for unit-normalized g).
pub fn projected_step(q: &Quadratic, w: &mut [f32], p: &Matrix, alpha: f32) -> f64 {
    let g = q.grad(w);
    let gnorm = (g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()).sqrt();
    // low = Pᵀ g
    let low = crate::linalg::matmul::matvec_t(p, &g);
    let rho = (low.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()).sqrt() / gnorm.max(1e-30);
    // lifted = P low
    let lifted = matvec(p, &low);
    for (wi, d) in w.iter_mut().zip(&lifted) {
        *wi -= alpha * d;
    }
    rho
}

/// Verify Lemma 3.1's bound for one step. Returns (lhs, rhs) of
/// `L(w') ≤ L(w) − αρ²‖g‖² + ½α²L‖g‖²`.
pub fn lemma31_sides(q: &Quadratic, w: &[f32], p: &Matrix, alpha: f32) -> (f64, f64) {
    let mut w2 = w.to_vec();
    let g = q.grad(w);
    let gnorm_sq: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
    let rho = projected_step(q, &mut w2, p, alpha);
    let lhs = q.loss(&w2);
    let rhs = q.loss(w) - (alpha as f64) * rho * rho * gnorm_sq
        + 0.5 * (alpha as f64).powi(2) * q.l_smooth * gnorm_sq;
    (lhs, rhs)
}

/// Steps for a policy to drive Σ‖g‖² below `tol·dim`, switching the
/// subspace per `refresh`: fixed every k steps, or adaptively when the
/// projected gradient stalls (displacement criterion on unit gradients).
pub fn steps_to_tolerance(
    q: &Quadratic,
    w0: &[f32],
    rank: usize,
    alpha: f32,
    tol: f64,
    adaptive: bool,
    fixed_interval: u64,
    max_steps: u64,
    rng: &mut Rng,
) -> u64 {
    let dim = w0.len();
    let mut w = w0.to_vec();
    let fit = |g: &[f32], rng: &mut Rng| -> Matrix {
        // top-rank projector from the gradient direction + random fill
        // (rank-1 gradient info, like GaLore's per-matrix U on a vector)
        let mut cols = Matrix::zeros(dim, rank);
        let gn = (g.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()).sqrt() as f32;
        for i in 0..dim {
            *cols.at_mut(i, 0) = g[i] / gn.max(1e-30);
        }
        for k in 1..rank {
            for i in 0..dim {
                *cols.at_mut(i, k) = rng.normal_f32(0.0, 1.0);
            }
        }
        crate::linalg::qr::orthonormalize(&cols)
    };

    let mut g = q.grad(&w);
    let mut p = fit(&g, rng);
    let mut last_switch = 0u64;
    let mut d_init: Option<Vec<f32>> = None;
    for step in 1..=max_steps {
        g = q.grad(&w);
        let gsq: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
        if gsq < tol * dim as f64 {
            return step;
        }
        let low = crate::linalg::matmul::matvec_t(&p, &g);
        let ln = (low.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()).sqrt() as f32;
        let d_cur: Vec<f32> = low.iter().map(|x| x / ln.max(1e-30)).collect();
        let must_switch = if adaptive {
            match &d_init {
                None => {
                    d_init = Some(d_cur.clone());
                    false
                }
                Some(d0) => {
                    let t = (step - last_switch).max(1) as f64;
                    let disp = d_cur
                        .iter()
                        .zip(d0)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                        / t;
                    disp < 0.02 && step - last_switch >= 3
                }
            }
        } else {
            step - last_switch >= fixed_interval
        };
        if must_switch {
            p = fit(&g, rng);
            last_switch = step;
            d_init = None;
        }
        projected_step(q, &mut w, &p, alpha);
    }
    max_steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma31_bound_holds_on_quadratics() {
        let mut rng = Rng::new(314);
        let q = Quadratic::random(24, 4.0, &mut rng);
        let alpha = 0.05f32; // < 2ρ²/L for ρ ~ O(1)
        for trial in 0..20 {
            let w: Vec<f32> = (0..24).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let p = crate::linalg::qr::orthonormalize(&Matrix::randn(24, 6, 1.0, &mut rng));
            let (lhs, rhs) = lemma31_sides(&q, &w, &p, alpha);
            assert!(
                lhs <= rhs + 1e-6 * rhs.abs().max(1.0),
                "trial {trial}: L(w')={lhs} > bound {rhs}"
            );
        }
    }

    #[test]
    fn lemma31_bound_is_tight_for_full_rank() {
        // P = I ⇒ ρ = 1 ⇒ the bound becomes the standard descent lemma,
        // exact for quadratics when rhs uses L = λ applied along g.
        let mut rng = Rng::new(315);
        let q = Quadratic::random(12, 2.0, &mut rng);
        let w: Vec<f32> = (0..12).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let p = Matrix::eye(12);
        let (lhs, rhs) = lemma31_sides(&q, &w, &p, 0.1);
        assert!(lhs <= rhs);
        // and the step actually decreases the loss
        let mut w2 = w.clone();
        projected_step(&q, &mut w2, &p, 0.1);
        assert!(q.loss(&w2) < q.loss(&w));
    }

    #[test]
    fn theorem32_adaptive_no_slower_than_fixed() {
        // Theorem 3.2: N_ada ≤ (c_fix/c_ada)(k/T) N_fix < N_fix. We check
        // the consequence: the adaptive policy reaches tolerance in no
        // more steps than a mis-tuned fixed interval (averaged over
        // problems), because it refreshes exactly when the subspace
        // stalls rather than on a clock.
        let mut rng = Rng::new(316);
        let mut ada_total = 0u64;
        let mut fix_total = 0u64;
        for trial in 0..6 {
            let q = Quadratic::random(20, 3.0, &mut rng);
            let w0: Vec<f32> = (0..20).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let mut rng_a = Rng::new(1000 + trial);
            let mut rng_f = Rng::new(1000 + trial);
            let ada =
                steps_to_tolerance(&q, &w0, 4, 0.1, 1e-4, true, 0, 4000, &mut rng_a);
            // fixed interval deliberately long (stale subspaces), as in
            // Fig 1's "fixed switching wastes steps" scenario
            let fix =
                steps_to_tolerance(&q, &w0, 4, 0.1, 1e-4, false, 200, 4000, &mut rng_f);
            ada_total += ada;
            fix_total += fix;
        }
        assert!(
            ada_total <= fix_total,
            "adaptive {ada_total} steps vs fixed {fix_total}"
        );
    }
}
