//! Mini property-testing framework (offline stand-in for `proptest`).
//!
//! Provides seeded input generators, a runner that reports the failing
//! case and its seed, and greedy shrinking for integer-tuple inputs.
//! Used by `rust/tests/properties.rs` for the coordinator invariants
//! (routing of gradients through projections, policy trigger logic,
//! state management under switches).

use crate::util::Rng;

/// Number of cases per property (kept moderate; the heavy numerics make
/// each case non-trivial).
pub const DEFAULT_CASES: usize = 32;

/// A generator of random test inputs.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Outcome of a property over one input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random inputs from `gen`; panics with the
/// seed + rendered input of the first failure (after shrinking when a
/// shrinker is provided through [`check_shrink`]).
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let mut rng = Rng::new(0x10705);
    for case in 0..cases {
        let seed_probe = rng.next_u64();
        let mut case_rng = Rng::new(seed_probe);
        let input = gen.generate(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed_probe:#x}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Like [`check`], with greedy shrinking: `shrink` proposes smaller
/// candidates for a failing input; the smallest still-failing input is
/// reported.
pub fn check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: usize,
    gen: impl Gen<T>,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> PropResult,
) {
    let mut rng = Rng::new(0x10705);
    for case in 0..cases {
        let seed_probe = rng.next_u64();
        let mut case_rng = Rng::new(seed_probe);
        let input = gen.generate(&mut case_rng);
        if let Err(first_msg) = prop(&input) {
            // greedy shrink loop
            let mut best = input.clone();
            let mut best_msg = first_msg;
            let mut improved = true;
            let mut budget = 200;
            while improved && budget > 0 {
                improved = false;
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed on case {case} (seed {seed_probe:#x}):\n  shrunk input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Common generators.
pub mod gens {
    use crate::tensor::Matrix;
    use crate::util::Rng;

    /// Random matrix dims in [lo, hi).
    pub fn dims(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> (usize, usize) {
        move |rng| (rng.range(lo, hi), rng.range(lo, hi))
    }

    /// Random matrix with dims in [lo, hi) and N(0, scale²) entries.
    pub fn matrix(lo: usize, hi: usize, scale: f32) -> impl Fn(&mut Rng) -> Matrix {
        move |rng| {
            let (m, n) = (rng.range(lo, hi), rng.range(lo, hi));
            Matrix::randn(m, n, scale, rng)
        }
    }

    /// Shrinker for (usize, usize) toward (1,1).
    pub fn shrink_dims(d: &(usize, usize)) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        if d.0 > 1 {
            out.push((d.0 / 2, d.1));
            out.push((d.0 - 1, d.1));
        }
        if d.1 > 1 {
            out.push((d.0, d.1 / 2));
            out.push((d.0, d.1 - 1));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng: &mut Rng| (rng.below(100), rng.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_reports() {
        check("always-fails", 5, |rng: &mut Rng| rng.below(10), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk input: 10")]
    fn shrinking_finds_minimal() {
        // property: n < 10. fails for n >= 10; minimal failing = 10.
        check_shrink(
            "lt-ten",
            50,
            |rng: &mut Rng| rng.below(1000),
            |&n| {
                let mut v = Vec::new();
                if n > 0 {
                    v.push(n / 2);
                    v.push(n - 1);
                }
                v
            },
            |&n| if n < 10 { Ok(()) } else { Err(format!("{n} >= 10")) },
        );
    }
}
