//! Analytic memory accounting — reproduces the memory columns of
//! Tables 1 and 2 and the paper's headline "40 % less gradient +
//! optimizer memory than GaLore".
//!
//! For each method we count, per weight matrix (m×n) at rank r and
//! element size `b` bytes:
//!
//! * trainable-parameter bytes (for adapter methods),
//! * gradient bytes retained between fwd/bwd and update,
//! * persistent optimizer-state bytes (Adam moments, projector bases),
//! * *transient peak* bytes during the projector refresh — this is where
//!   GaLore (full SVD workspace: U, Σ, Vᵀ plus the LAPACK work array)
//!   differs sharply from Lotus (sketch Y, small QR workspace).
//!
//! The model is validated against the measured `state_bytes()` of the
//! Rust-native optimizers in the tests below and sweeps the paper's
//! exact model sizes in `benches/table1.rs`.

use crate::models::ModelShape;

/// Training method, as named in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    FullRank,
    GaLore,
    LowRank,
    LoRA,
    ReLoRA,
    AdaRankGrad,
    Apollo,
    Lotus,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::FullRank => "Full Rank",
            Method::GaLore => "GaLore",
            Method::LowRank => "Low Rank",
            Method::LoRA => "LoRA",
            Method::ReLoRA => "ReLoRA",
            Method::AdaRankGrad => "AdaRankGrad",
            Method::Apollo => "Apollo",
            Method::Lotus => "Lotus",
        }
    }

    pub fn all() -> [Method; 8] {
        [
            Method::FullRank,
            Method::GaLore,
            Method::LowRank,
            Method::LoRA,
            Method::ReLoRA,
            Method::AdaRankGrad,
            Method::Apollo,
            Method::Lotus,
        ]
    }
}

/// Byte accounting for one layer or one model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemBreakdown {
    pub weights: u64,
    pub grads: u64,
    pub opt_state: u64,
    /// Transient peak during projector refresh / merge operations.
    pub transient_peak: u64,
}

impl MemBreakdown {
    /// Persistent total (the paper's parenthetical GB figures count
    /// gradient + optimizer state; weights are common to all methods).
    pub fn grad_plus_opt(&self) -> u64 {
        self.grads + self.opt_state
    }

    /// Peak including transients.
    pub fn peak(&self) -> u64 {
        self.weights + self.grads + self.opt_state + self.transient_peak
    }

    pub fn add(&mut self, other: &MemBreakdown) {
        self.weights += other.weights;
        self.grads += other.grads;
        self.opt_state += other.opt_state;
        // transients don't overlap across layers under layer-wise updates
        self.transient_peak = self.transient_peak.max(other.transient_peak);
    }
}

/// Memory for one m×n weight trained by `method` at rank `r` with
/// element size `b` bytes (bf16 = 2, f32 = 4).
pub fn layer_mem(method: Method, m: u64, n: u64, r: u64, b: u64) -> MemBreakdown {
    let full = m * n * b;
    let short = m.min(n);
    let long = m.max(n);
    let low = r * long * b; // low-rank gradient/moment size (side rule)
    let basis = short * r * b;
    match method {
        Method::FullRank => MemBreakdown {
            weights: full,
            grads: full,
            opt_state: 2 * full,
            transient_peak: 0,
        },
        Method::GaLore => MemBreakdown {
            weights: full,
            grads: full, // full-rank grad exists between bwd and projection
            opt_state: 2 * low + basis,
            // exact SVD workspace: U (short×short), Vᵀ (short×long), Σ,
            // plus a gesdd-style work array ≈ 4·short² + 4·short
            transient_peak: (short * short + short * long + short + 4 * short * short + 4 * short)
                * b,
        },
        Method::Lotus => MemBreakdown {
            weights: full,
            grads: full,
            opt_state: 2 * low + basis,
            // rSVD sketch: Y (short×l), Ω (long×l), small QR tau — with
            // l = r + oversample(≈r/4 capped) — tiny next to SVD's.
            transient_peak: {
                let l = r + (r / 4).clamp(4, 16);
                (short * l + long * l + l * l + l) * b
            },
        },
        Method::AdaRankGrad => {
            // like GaLore but with decayed average rank ≈ 0.75r and an
            // incremental-update scheme that avoids the full SVD workspace
            let r_eff = (3 * r) / 4;
            let low_e = r_eff * long * b;
            let basis_e = short * r_eff * b;
            MemBreakdown {
                weights: full,
                grads: full,
                opt_state: 2 * low_e + basis_e,
                transient_peak: (short * r_eff + long * r_eff + r_eff * r_eff) * b,
            }
        }
        Method::Apollo => MemBreakdown {
            weights: full,
            grads: full,
            opt_state: 2 * low + basis, // rank-r moments + random basis
            transient_peak: 0,          // no decomposition at all
        },
        Method::LowRank => {
            // weight itself factorized: params r(m+n), grads r(m+n),
            // Adam states 2r(m+n)
            let fac = r * (m + n) * b;
            MemBreakdown { weights: fac, grads: fac, opt_state: 2 * fac, transient_peak: 0 }
        }
        Method::LoRA | Method::ReLoRA => {
            // frozen W (no grad) + adapters r(m+n) trainable
            let fac = r * (m + n) * b;
            MemBreakdown {
                weights: full + fac,
                grads: fac,
                opt_state: 2 * fac,
                // ReLoRA merge materializes BA (m×n) transiently
                transient_peak: if method == Method::ReLoRA { full } else { 0 },
            }
        }
    }
}

/// Sum the model's projected layers + non-matrix params (norms, biases —
/// always full-rank Adam).
pub fn model_mem(method: Method, shape: &ModelShape, r: u64, b: u64) -> MemBreakdown {
    let mut total = MemBreakdown::default();
    for layer in shape.matrices() {
        let lm = if layer.project {
            layer_mem(method, layer.rows as u64, layer.cols as u64, r, b)
        } else {
            layer_mem(Method::FullRank, layer.rows as u64, layer.cols as u64, r, b)
        };
        total.add(&lm);
    }
    let vec_bytes = shape.vector_params() as u64 * b;
    total.weights += vec_bytes;
    total.grads += vec_bytes;
    total.opt_state += 2 * vec_bytes;
    total
}

// ---------------------------------------------------------------------
// data-parallel communication model (the analytic twin of the measured
// byte accounting in `crate::dist::comm::CommStats`)
// ---------------------------------------------------------------------

/// Per-step all-reduce payload for one m×n gradient under `method` at
/// rank `r` (element size `b` bytes): projection methods exchange only
/// the r×max(m,n) projected gradient, factorized methods their factor
/// gradients, everything else the dense gradient. This is the payload of
/// a single reduction — multiply by the topology's cross-edge count (×2
/// for the broadcast leg) for wire bytes, as the dist engine does.
pub fn allreduce_layer_bytes(method: Method, m: u64, n: u64, r: u64, b: u64) -> u64 {
    match method {
        Method::GaLore | Method::Lotus | Method::Apollo => r * m.max(n) * b,
        Method::AdaRankGrad => (3 * r / 4) * m.max(n) * b,
        Method::LowRank | Method::LoRA | Method::ReLoRA => r * (m + n) * b,
        Method::FullRank => m * n * b,
    }
}

/// Analytic per-step data-parallel comm volume for a whole model:
/// payload bytes of one reduction round over every gradient tensor.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommBreakdown {
    /// Payload actually exchanged for the projected matrices.
    pub projected: u64,
    /// What a dense-gradient baseline would exchange for those matrices.
    pub projected_dense_equiv: u64,
    /// Tensors dense under every method (embeddings, norm vectors).
    pub other_dense: u64,
}

impl CommBreakdown {
    /// Structural all-reduce saving on the projected matrices
    /// (≈ min(m,n)/r per matrix; the paper-facing "(m/r)× less traffic").
    pub fn reduction_vs_dense(&self) -> f64 {
        if self.projected == 0 {
            return f64::NAN;
        }
        self.projected_dense_equiv as f64 / self.projected as f64
    }
}

/// Sum [`allreduce_layer_bytes`] over a model shape.
pub fn model_allreduce_bytes(method: Method, shape: &ModelShape, r: u64, b: u64) -> CommBreakdown {
    let mut out = CommBreakdown::default();
    for layer in shape.matrices() {
        let (m, n) = (layer.rows as u64, layer.cols as u64);
        if layer.project {
            out.projected += allreduce_layer_bytes(method, m, n, r, b);
            out.projected_dense_equiv += m * n * b;
        } else {
            out.other_dense += m * n * b;
        }
    }
    out.other_dense += shape.vector_params() as u64 * b;
    out
}

/// Headline ratio #1 — grad+opt memory vs **full-rank** training (the
/// paper's "40 % decrease in memory consumption for gradient and
/// optimizer states"; cf. Table 1: Lotus 0.23G vs Full 0.36G at 60M).
pub fn lotus_vs_full_ratio(shape: &ModelShape, r: u64, b: u64) -> f64 {
    let full = model_mem(Method::FullRank, shape, r, b);
    let lotus = model_mem(Method::Lotus, shape, r, b);
    lotus.grad_plus_opt() as f64 / full.grad_plus_opt() as f64
}

/// Headline ratio #2 — optimizer state + projector-refresh transient vs
/// **GaLore** (the component Lotus actually changes; the full-rank
/// gradient buffer is identical in both methods).
pub fn lotus_vs_galore_ratio(shape: &ModelShape, r: u64, b: u64) -> f64 {
    let galore = model_mem(Method::GaLore, shape, r, b);
    let lotus = model_mem(Method::Lotus, shape, r, b);
    let g = (galore.opt_state + galore.transient_peak) as f64;
    let l = (lotus.opt_state + lotus.transient_peak) as f64;
    l / g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets;

    #[test]
    fn full_rank_is_3x_weights() {
        let m = layer_mem(Method::FullRank, 1024, 1024, 0, 2);
        assert_eq!(m.grads, m.weights);
        assert_eq!(m.opt_state, 2 * m.weights);
    }

    #[test]
    fn galore_state_below_full() {
        let full = layer_mem(Method::FullRank, 2048, 2048, 512, 2);
        let galore = layer_mem(Method::GaLore, 2048, 2048, 512, 2);
        assert!(galore.opt_state < full.opt_state);
    }

    #[test]
    fn lotus_transient_far_below_galore() {
        let g = layer_mem(Method::GaLore, 2048, 2048, 512, 2);
        let l = layer_mem(Method::Lotus, 2048, 2048, 512, 2);
        assert_eq!(l.opt_state, g.opt_state, "persistent states match");
        assert!(
            l.transient_peak * 3 < g.transient_peak,
            "lotus {} vs galore {}",
            l.transient_peak,
            g.transient_peak
        );
    }

    #[test]
    fn headline_memory_saving_band() {
        // Paper headline: ~40% grad+opt saving vs full-rank (Table 1:
        // 0.23G vs 0.36G at 60M ⇒ ratio ≈ 0.64).
        let shape = presets::llama_paper_60m();
        let vs_full = lotus_vs_full_ratio(&shape, 128, 2);
        assert!((0.45..0.80).contains(&vs_full), "vs_full={vs_full}");
        // And the SVD-workspace transient must shrink sharply vs GaLore
        // (persistent moments are identical, so the total moves less).
        let shape1b = presets::llama_paper_1b();
        let vs_galore = lotus_vs_galore_ratio(&shape1b, 512, 2);
        assert!(vs_galore < 0.99, "vs_galore={vs_galore}");
        let g = model_mem(Method::GaLore, &shape1b, 512, 2);
        let l = model_mem(Method::Lotus, &shape1b, 512, 2);
        assert!(
            (l.transient_peak as f64) < 0.25 * g.transient_peak as f64,
            "refresh transient: lotus {} vs galore {}",
            l.transient_peak,
            g.transient_peak
        );
    }

    #[test]
    fn allreduce_saving_is_short_dim_over_rank() {
        // square d×d at rank r: dense/lowrank = d/r exactly
        let low = allreduce_layer_bytes(Method::Lotus, 1024, 1024, 128, 2);
        let dense = allreduce_layer_bytes(Method::FullRank, 1024, 1024, 128, 2);
        assert_eq!(dense / low, 1024 / 128);
        // rectangular: payload is r×max(m,n) → saving = min(m,n)/r
        let low = allreduce_layer_bytes(Method::Lotus, 512, 2048, 128, 2);
        assert_eq!(low, 128 * 2048 * 2);
        let dense = allreduce_layer_bytes(Method::FullRank, 512, 2048, 128, 2);
        assert_eq!(dense / low, 512 / 128);
    }

    #[test]
    fn model_comm_breakdown_is_consistent() {
        let shape = presets::llama_paper_60m();
        let lotus = model_allreduce_bytes(Method::Lotus, &shape, 128, 4);
        let dense = model_allreduce_bytes(Method::FullRank, &shape, 128, 4);
        // the dense baseline exchanges exactly the dense-equivalent
        assert_eq!(dense.projected, lotus.projected_dense_equiv);
        assert_eq!(dense.other_dense, lotus.other_dense);
        assert!(lotus.reduction_vs_dense() > 1.0, "{}", lotus.reduction_vs_dense());
    }

    #[test]
    fn matches_measured_optimizer_state() {
        use crate::optim::{presets_state_bytes_probe, Hyper};
        // measured LowRankAdam state (moments + basis) must equal the
        // analytic opt_state for the same shape
        let (m, n, r) = (64usize, 256usize, 8usize);
        let measured = presets_state_bytes_probe(m, n, r, &Hyper::default());
        let analytic = layer_mem(Method::GaLore, m as u64, n as u64, r as u64, 4).opt_state;
        assert_eq!(measured as u64, analytic);
    }

    #[test]
    fn table1_order_of_magnitude() {
        // Paper Table 1, 1B model: GaLore 4.38G vs Full 7.80G (bf16).
        // Our analytic model should land in the same ballpark (±40%) —
        // exact agreement isn't expected (activations etc. excluded).
        let shape = presets::llama_paper_1b();
        let full = model_mem(Method::FullRank, &shape, 512, 2);
        let galore = model_mem(Method::GaLore, &shape, 512, 2);
        let gib = |x: u64| x as f64 / (1u64 << 30) as f64;
        let full_gb = gib(full.weights + full.grad_plus_opt());
        let galore_gb = gib(galore.weights + galore.grad_plus_opt());
        assert!((4.0..12.0).contains(&full_gb), "full={full_gb}");
        assert!(galore_gb < full_gb, "galore={galore_gb} < full={full_gb}");
    }
}
