//! Gradient projectors: the maps between full-rank gradient space
//! ℝ^{m×n} and the rank-r optimizer subspace.
//!
//! GaLore computes `P = U[:, :r]` from an exact SVD of G; Lotus computes
//! the same object with the randomized range finder ([`RandSvdProjector`]);
//! Flora/Apollo-style methods use a data-independent Gaussian `P`
//! ([`GaussianProjector`]). All satisfy the same contract ([`Projector`]):
//! orthonormal columns (Gaussian approximately so), project/lift pair, and
//! a side rule matching GaLore's: project the *shorter* side of G so the
//! low-rank state is r×max(m,n).

use crate::linalg::matmul::{matmul, matmul_into, matmul_nt_into, matmul_tn, matmul_tn_into};
use crate::linalg::par::{matmul_axpy_into_pooled, matmul_nt_axpy_into_pooled};
use crate::linalg::rsvd::{rsvd_range_into, RsvdOpts, RsvdScratch};
use crate::linalg::svd::svd_jacobi;
use crate::runtime::pool;
use crate::tensor::{init, Matrix};
use crate::util::Rng;

/// Which side of G the projector contracts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// P is m×r, low-rank gradient is Pᵀ G (r×n). Used when m <= n.
    Left,
    /// P is n×r, low-rank gradient is G P (m×r). Used when m > n.
    Right,
}

/// GaLore's rule: contract the shorter dimension so the retained state
/// (low-rank gradient + Adam moments) is as small as possible.
pub fn side_for(m: usize, n: usize) -> Side {
    if m <= n {
        Side::Left
    } else {
        Side::Right
    }
}

/// A fitted projector: an orthonormal basis for a rank-r gradient
/// subspace plus the side it acts on.
#[derive(Clone, Debug)]
pub struct Projection {
    pub basis: Matrix,
    pub side: Side,
}

impl Projection {
    /// Down-project the full-rank gradient into the subspace.
    /// Left: R = Pᵀ G (r×n); Right: R = G P (m×r).
    pub fn down(&self, g: &Matrix) -> Matrix {
        match self.side {
            Side::Left => matmul_tn(&self.basis, g),
            Side::Right => matmul(g, &self.basis),
        }
    }

    /// Allocation-free [`Projection::down`]: writes into a caller-owned
    /// buffer (reshaped in place as needed).
    pub fn down_into(&self, g: &Matrix, out: &mut Matrix) {
        match self.side {
            Side::Left => {
                out.ensure_shape(self.basis.cols, g.cols);
                matmul_tn_into(&self.basis, g, out);
            }
            Side::Right => {
                out.ensure_shape(g.rows, self.basis.cols);
                matmul_into(g, &self.basis, out);
            }
        }
    }

    /// Lift a low-rank update back to full-rank space.
    /// Left: G̃ = P R; Right: G̃ = R Pᵀ.
    pub fn up(&self, r: &Matrix) -> Matrix {
        match self.side {
            Side::Left => matmul(&self.basis, r),
            Side::Right => crate::linalg::matmul::matmul_nt(r, &self.basis),
        }
    }

    /// Allocation-free [`Projection::up`]: writes into a caller-owned
    /// buffer (reshaped in place as needed).
    pub fn up_into(&self, r: &Matrix, out: &mut Matrix) {
        match self.side {
            Side::Left => {
                out.ensure_shape(self.basis.rows, r.cols);
                matmul_into(&self.basis, r, out);
            }
            Side::Right => {
                out.ensure_shape(r.rows, self.basis.rows);
                matmul_nt_into(r, &self.basis, out);
            }
        }
    }

    /// Fused lift-and-apply: `w += α · up(r)` without materializing the
    /// lifted full-rank matrix — the optimizer's steady-state update is
    /// a single accumulating GEMM into the weight. Large shapes fan out
    /// over the effective pool (small ones fall back to the serial band
    /// kernel below the `MIN_PAR_MACS` cutoff, so the steady-state path
    /// stays allocation-free); results are bit-identical either way.
    pub fn up_axpy(&self, r: &Matrix, alpha: f32, w: &mut Matrix) {
        let p = pool::effective();
        match self.side {
            Side::Left => matmul_axpy_into_pooled(&p, &self.basis, r, alpha, w),
            Side::Right => matmul_nt_axpy_into_pooled(&p, r, &self.basis, alpha, w),
        }
    }

    /// Rank of the subspace.
    pub fn rank(&self) -> usize {
        self.basis.cols
    }

    /// Shape of the low-rank gradient for a full gradient of shape (m,n).
    pub fn low_shape(&self, m: usize, n: usize) -> (usize, usize) {
        match self.side {
            Side::Left => (self.rank(), n),
            Side::Right => (m, self.rank()),
        }
    }
}

/// Strategy for fitting a [`Projection`] from a gradient matrix.
pub trait Projector: Send {
    /// Fit a new subspace from the current full-rank gradient.
    fn fit(&mut self, g: &Matrix, rank: usize) -> Projection;
    /// Human-readable name (for logs/benches).
    fn name(&self) -> &'static str;
    /// FLOPs for one fit at the given shape (analytic cost model).
    fn fit_flops(&self, m: usize, n: usize, rank: usize) -> u64;
    /// RNG stream position, for checkpointing a mid-training projector
    /// (randomized projectors must resume their stream exactly, or the
    /// first refresh after a resume diverges from the uninterrupted
    /// run). `None` for deterministic projectors.
    fn rng_state(&self) -> Option<(u64, u64)> {
        None
    }
    /// Restore an [`Projector::rng_state`] snapshot (no-op for
    /// deterministic projectors).
    fn set_rng_state(&mut self, _state: (u64, u64)) {}
}

/// Exact-SVD projector (GaLore): P = U[:, :r] of svd(G) (or V for Right).
pub struct SvdProjector;

impl Projector for SvdProjector {
    fn fit(&mut self, g: &Matrix, rank: usize) -> Projection {
        let side = side_for(g.rows, g.cols);
        let basis = match side {
            Side::Left => svd_jacobi(g).left_vectors(rank),
            Side::Right => {
                // right singular vectors: rows of Vt, transposed to n×r
                let svd = svd_jacobi(g);
                let r = rank.min(svd.s.len());
                let mut b = Matrix::zeros(g.cols, r);
                for k in 0..r {
                    for j in 0..g.cols {
                        *b.at_mut(j, k) = svd.vt.at(k, j);
                    }
                }
                b
            }
        };
        Projection { basis, side }
    }

    fn name(&self) -> &'static str {
        "svd"
    }

    fn fit_flops(&self, m: usize, n: usize, _rank: usize) -> u64 {
        crate::linalg::rsvd::svd_flops(m, n)
    }
}

/// Randomized-SVD projector (Lotus): power-iteration range finder.
///
/// Carries its own [`RsvdScratch`] so repeated fits at a stable layer
/// shape allocate only the returned basis; the range-finder GEMMs fan
/// out over the global worker pool.
pub struct RandSvdProjector {
    pub oversample: usize,
    pub power_iters: usize,
    rng: Rng,
    scratch: RsvdScratch,
    /// Transpose buffer for Right-side fits.
    gt: Matrix,
}

impl RandSvdProjector {
    pub fn new(seed: u64) -> Self {
        RandSvdProjector::with_opts(seed, 4, 1)
    }

    pub fn with_opts(seed: u64, oversample: usize, power_iters: usize) -> Self {
        RandSvdProjector {
            oversample,
            power_iters,
            rng: Rng::new(seed),
            scratch: RsvdScratch::new(),
            gt: Matrix::zeros(0, 0),
        }
    }
}

impl Projector for RandSvdProjector {
    fn fit(&mut self, g: &Matrix, rank: usize) -> Projection {
        let side = side_for(g.rows, g.cols);
        let opts =
            RsvdOpts { rank, oversample: self.oversample, power_iters: self.power_iters };
        let mut basis = Matrix::zeros(0, 0);
        match side {
            Side::Left => rsvd_range_into(
                g,
                opts,
                &mut self.rng,
                &pool::effective(),
                &mut self.scratch,
                &mut basis,
            ),
            Side::Right => {
                g.transpose_into(&mut self.gt);
                rsvd_range_into(
                    &self.gt,
                    opts,
                    &mut self.rng,
                    &pool::effective(),
                    &mut self.scratch,
                    &mut basis,
                );
            }
        }
        Projection { basis, side }
    }

    fn name(&self) -> &'static str {
        "rsvd"
    }

    fn fit_flops(&self, m: usize, n: usize, rank: usize) -> u64 {
        crate::linalg::rsvd::rsvd_flops(m, n, rank, self.oversample, self.power_iters)
    }

    fn rng_state(&self) -> Option<(u64, u64)> {
        Some(self.rng.state())
    }

    fn set_rng_state(&mut self, state: (u64, u64)) {
        self.rng = Rng::from_state(state.0, state.1);
    }
}

/// Data-independent Gaussian projector (Flora/Apollo family). Not
/// orthonormal but JL-isometric in expectation; cheapest possible fit.
pub struct GaussianProjector {
    rng: Rng,
}

impl GaussianProjector {
    pub fn new(seed: u64) -> Self {
        GaussianProjector { rng: Rng::new(seed) }
    }
}

impl Projector for GaussianProjector {
    fn fit(&mut self, g: &Matrix, rank: usize) -> Projection {
        let side = side_for(g.rows, g.cols);
        let dim = match side {
            Side::Left => g.rows,
            Side::Right => g.cols,
        };
        let basis = init::gaussian_projection(dim, rank, rank, &mut self.rng);
        Projection { basis, side }
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn fit_flops(&self, m: usize, n: usize, rank: usize) -> u64 {
        // just sampling; linear in the basis size
        (m.min(n) * rank) as u64
    }

    fn rng_state(&self) -> Option<(u64, u64)> {
        Some(self.rng.state())
    }

    fn set_rng_state(&mut self, state: (u64, u64)) {
        self.rng = Rng::from_state(state.0, state.1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{captured_energy, orthonormality_error};

    #[test]
    fn side_rule_matches_galore() {
        assert_eq!(side_for(256, 1024), Side::Left);
        assert_eq!(side_for(1024, 256), Side::Right);
        assert_eq!(side_for(64, 64), Side::Left);
    }

    #[test]
    fn down_up_shapes() {
        let mut rng = Rng::new(71);
        let g = Matrix::randn(32, 96, 1.0, &mut rng);
        let mut proj = RandSvdProjector::new(1);
        let p = proj.fit(&g, 8);
        assert_eq!(p.side, Side::Left);
        let low = p.down(&g);
        assert_eq!(low.shape(), (8, 96));
        assert_eq!(p.up(&low).shape(), (32, 96));

        let gt = g.transpose(); // 96×32 → Right
        let p2 = proj.fit(&gt, 8);
        assert_eq!(p2.side, Side::Right);
        let low2 = p2.down(&gt);
        assert_eq!(low2.shape(), (96, 8));
        assert_eq!(p2.up(&low2).shape(), (96, 32));
    }

    #[test]
    fn up_down_is_projection_operator() {
        // down∘up = identity on the low-rank space for orthonormal bases
        let mut rng = Rng::new(72);
        let g = Matrix::randn(40, 60, 1.0, &mut rng);
        let mut proj = SvdProjector;
        let p = proj.fit(&g, 6);
        let low = p.down(&g);
        let lifted = p.up(&low);
        let low2 = p.down(&lifted);
        let err = low2.sub(&low).fro_norm() / low.fro_norm();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn svd_and_rsvd_capture_similar_energy() {
        let mut rng = Rng::new(73);
        let g = Matrix::randn(64, 128, 1.0, &mut rng);
        let e_svd = {
            let p = SvdProjector.fit(&g, 8);
            captured_energy(&p.basis, &g)
        };
        let e_rsvd = {
            let mut pr = RandSvdProjector::with_opts(2, 8, 2);
            let p = pr.fit(&g, 8);
            captured_energy(&p.basis, &g)
        };
        assert!(e_svd >= e_rsvd - 1e-6, "svd is optimal");
        // On a flat Gaussian spectrum rSVD trails exact SVD the most;
        // on real (decaying) gradient spectra it is far closer — see
        // rsvd::tests::captures_dominant_subspace_of_lowrank_plus_noise.
        assert!(e_rsvd > e_svd * 0.8, "rsvd close: {e_rsvd} vs {e_svd}");
    }

    #[test]
    fn orthonormal_bases() {
        let mut rng = Rng::new(74);
        let g = Matrix::randn(48, 80, 1.0, &mut rng);
        assert!(orthonormality_error(&SvdProjector.fit(&g, 8).basis) < 1e-4);
        assert!(orthonormality_error(&RandSvdProjector::new(3).fit(&g, 8).basis) < 1e-4);
    }

    #[test]
    fn gaussian_projector_preserves_norm_in_expectation() {
        let mut rng = Rng::new(75);
        let g = Matrix::randn(64, 256, 1.0, &mut rng);
        let mut pr = GaussianProjector::new(4);
        // average ratio over several draws should be near 1
        let mut total = 0.0;
        let n_draws = 20;
        for _ in 0..n_draws {
            let p = pr.fit(&g, 16);
            let low = p.down(&g);
            total += low.fro_norm_sq() / g.fro_norm_sq();
        }
        let avg = total / n_draws as f64;
        assert!((avg - 1.0).abs() < 0.25, "avg JL ratio {avg}");
    }

    #[test]
    fn fit_flops_favor_rsvd() {
        let pr = RandSvdProjector::new(5);
        assert!(pr.fit_flops(2048, 2048, 128) < SvdProjector.fit_flops(2048, 2048, 128) / 4);
    }

    #[test]
    fn into_variants_match_allocating_on_both_sides() {
        let mut rng = Rng::new(76);
        for (m, n) in [(24, 60), (60, 24)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let mut proj = RandSvdProjector::new(9);
            let p = proj.fit(&g, 6);
            let low_ref = p.down(&g);
            let mut low = Matrix::zeros(0, 0);
            p.down_into(&g, &mut low);
            assert_eq!(low.data, low_ref.data);
            let up_ref = p.up(&low_ref);
            let mut up = Matrix::zeros(0, 0);
            p.up_into(&low, &mut up);
            assert_eq!(up.data, up_ref.data);
        }
    }

    #[test]
    fn up_axpy_matches_materialized_lift() {
        let mut rng = Rng::new(77);
        for (m, n) in [(16, 40), (40, 16)] {
            let g = Matrix::randn(m, n, 1.0, &mut rng);
            let mut proj = RandSvdProjector::new(10);
            let p = proj.fit(&g, 4);
            let low = p.down(&g);
            let w0 = Matrix::randn(m, n, 1.0, &mut rng);
            let mut w = w0.clone();
            p.up_axpy(&low, -0.25, &mut w);
            let mut expect = w0.clone();
            expect.axpy(-0.25, &p.up(&low));
            let err = w.sub(&expect).fro_norm() / expect.fro_norm().max(1.0);
            assert!(err < 1e-5, "({m},{n}) err={err}");
        }
    }
}
