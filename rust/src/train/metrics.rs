//! Run metrics: JSONL event log + a background writer thread so disk I/O
//! never blocks the training loop.

use crate::util::json::JsonValue;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

/// A metrics logger writing one JSON object per line.
pub struct MetricsLogger {
    tx: Option<mpsc::Sender<String>>,
    handle: Option<thread::JoinHandle<()>>,
    pub path: PathBuf,
}

impl MetricsLogger {
    /// Create `<out_dir>/<run_name>.jsonl` (creating the directory).
    pub fn new(out_dir: impl AsRef<Path>, run_name: &str) -> std::io::Result<MetricsLogger> {
        std::fs::create_dir_all(out_dir.as_ref())?;
        let path = out_dir.as_ref().join(format!("{run_name}.jsonl"));
        let file = std::fs::File::create(&path)?;
        let (tx, rx) = mpsc::channel::<String>();
        let handle = thread::spawn(move || {
            let mut w = std::io::BufWriter::new(file);
            for line in rx {
                let _ = writeln!(w, "{line}");
            }
            let _ = w.flush();
        });
        Ok(MetricsLogger { tx: Some(tx), handle: Some(handle), path })
    }

    /// Log one event.
    pub fn log(&self, event: JsonValue) {
        if let Some(tx) = &self.tx {
            let _ = tx.send(event.to_string());
        }
    }

    /// Convenience: a training-step record.
    pub fn log_step(&self, step: u64, loss: f64, extra: Vec<(&str, JsonValue)>) {
        let mut fields = vec![
            ("event", JsonValue::str("step")),
            ("step", JsonValue::num(step as f64)),
            ("loss", JsonValue::num(loss)),
        ];
        fields.extend(extra);
        self.log(JsonValue::obj(fields));
    }

    /// Flush and close (also done on drop).
    pub fn close(&mut self) {
        self.tx.take(); // closes the channel
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsLogger {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn writes_parseable_jsonl() {
        let dir = std::env::temp_dir().join("lotus_metrics_test");
        let mut logger = MetricsLogger::new(&dir, "test-run").unwrap();
        logger.log_step(1, 4.2, vec![("ppl", JsonValue::num(66.7))]);
        logger.log_step(2, 4.0, vec![]);
        logger.log(JsonValue::obj(vec![
            ("event", JsonValue::str("switch")),
            ("layer", JsonValue::num(3.0)),
        ]));
        let path = logger.path.clone();
        logger.close();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = parse(lines[0]).unwrap();
        assert_eq!(first.get("step").as_f64(), Some(1.0));
        assert_eq!(first.get("ppl").as_f64(), Some(66.7));
        let last = parse(lines[2]).unwrap();
        assert_eq!(last.get("event").as_str(), Some("switch"));
        let _ = std::fs::remove_file(path);
    }
}
