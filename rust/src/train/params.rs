//! Host-side parameter store in the flat layout shared with aot.py:
//! `[embed, (wq wk wv wo w1 w3 w2 norm1 norm2) × L, final_norm]`.
//!
//! Initialization reuses [`crate::sim::SimModel`]'s init so the PJRT and
//! simulator paths start from *identical* weights — the cross-path
//! equivalence tests depend on this.

use crate::models::LlamaConfig;
use crate::sim::SimModel;
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Flat parameter store: (name, Matrix). Vectors are 1×d matrices.
pub struct HostParams {
    pub cfg: LlamaConfig,
    pub entries: Vec<(String, Matrix)>,
}

impl HostParams {
    /// Initialize from the simulator's init (identical across paths).
    pub fn init(cfg: LlamaConfig, seed: u64) -> HostParams {
        let sim = SimModel::new(cfg, seed);
        HostParams::from_sim(&sim)
    }

    /// Flatten a simulator model's params.
    pub fn from_sim(sim: &SimModel) -> HostParams {
        let mut entries = Vec::new();
        entries.push(("embed".to_string(), sim.params.embed.clone()));
        for (l, lp) in sim.params.layers.iter().enumerate() {
            entries.push((format!("layer{l}.wq"), lp.wq.clone()));
            entries.push((format!("layer{l}.wk"), lp.wk.clone()));
            entries.push((format!("layer{l}.wv"), lp.wv.clone()));
            entries.push((format!("layer{l}.wo"), lp.wo.clone()));
            entries.push((format!("layer{l}.w1"), lp.w1.clone()));
            entries.push((format!("layer{l}.w3"), lp.w3.clone()));
            entries.push((format!("layer{l}.w2"), lp.w2.clone()));
            entries.push((
                format!("layer{l}.norm1"),
                Matrix::from_vec(1, lp.norm1.len(), lp.norm1.clone()),
            ));
            entries.push((
                format!("layer{l}.norm2"),
                Matrix::from_vec(1, lp.norm2.len(), lp.norm2.clone()),
            ));
        }
        entries.push((
            "final_norm".to_string(),
            Matrix::from_vec(1, sim.params.final_norm.len(), sim.params.final_norm.clone()),
        ));
        HostParams { cfg: sim.cfg, entries }
    }

    /// Indices of the projected (2-D matmul) weights — everything except
    /// embed and the norm vectors, matching GaLore's rule.
    pub fn projected_indices(&self) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, (name, _))| {
                !name.contains("norm") && name != "embed"
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Validate against a manifest param list (names + shapes).
    pub fn check_against(&self, manifest_params: &[(String, Vec<usize>)]) -> Result<()> {
        if manifest_params.len() != self.entries.len() {
            bail!(
                "param count mismatch: host {} vs manifest {}",
                self.entries.len(),
                manifest_params.len()
            );
        }
        for ((hname, hm), (mname, mshape)) in self.entries.iter().zip(manifest_params) {
            if hname != mname {
                bail!("param order mismatch: host '{hname}' vs manifest '{mname}'");
            }
            let hshape: Vec<usize> = if mshape.len() == 1 {
                vec![hm.cols] // vectors stored 1×d host-side
            } else {
                vec![hm.rows, hm.cols]
            };
            if &hshape != mshape {
                bail!("shape mismatch for {hname}: host {hshape:?} vs manifest {mshape:?}");
            }
        }
        Ok(())
    }

    /// Upload all params as literals in manifest order (vectors as rank-1).
    #[cfg(feature = "pjrt")]
    pub fn to_literals(&self) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(self.entries.len());
        for (name, m) in &self.entries {
            if name.contains("norm") {
                out.push(xla::Literal::vec1(&m.data)); // rank-1 d
            } else {
                out.push(crate::runtime::convert::matrix_to_literal(m)?);
            }
        }
        Ok(out)
    }

    /// Total parameter count.
    pub fn param_count(&self) -> u64 {
        self.entries.iter().map(|(_, m)| m.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_tiny_cfg;

    #[test]
    fn layout_matches_model_shapes() {
        let hp = HostParams::init(llama_tiny_cfg(), 1);
        // embed + 9/layer + final_norm
        assert_eq!(hp.entries.len(), 1 + 9 * 2 + 1);
        assert_eq!(hp.entries[0].0, "embed");
        assert_eq!(hp.entries.last().unwrap().0, "final_norm");
        // projected = 7 matrices per layer
        assert_eq!(hp.projected_indices().len(), 7 * 2);
    }

    #[test]
    fn init_is_deterministic_and_matches_sim() {
        let cfg = llama_tiny_cfg();
        let a = HostParams::init(cfg, 7);
        let b = HostParams::init(cfg, 7);
        for ((_, ma), (_, mb)) in a.entries.iter().zip(&b.entries) {
            assert_eq!(ma, mb);
        }
        let sim = crate::sim::SimModel::new(cfg, 7);
        assert_eq!(a.entries[0].1, sim.params.embed);
    }

    #[test]
    fn check_against_catches_mismatches() {
        let hp = HostParams::init(llama_tiny_cfg(), 1);
        let mut manifest: Vec<(String, Vec<usize>)> = hp
            .entries
            .iter()
            .map(|(n, m)| {
                let shape = if n.contains("norm") { vec![m.cols] } else { vec![m.rows, m.cols] };
                (n.clone(), shape)
            })
            .collect();
        hp.check_against(&manifest).unwrap();
        manifest[3].1 = vec![1, 1];
        assert!(hp.check_against(&manifest).is_err());
    }
}
