//! The PJRT training loop: Rust coordinator driving AOT artifacts.
//!
//! Per step:
//! 1. prefetch a batch (background thread, [`crate::data::LmBatcher`]);
//! 2. `fwdbwd_<cfg>` → loss + full-rank grads (one PJRT call);
//! 3. per projected matrix: (maybe) refresh the projector
//!    ([`SubspaceManager`]), then `lowrank_adam_<cfg>_<shape>` applies
//!    the projected Adam step and returns the displacement statistic
//!    the Lotus policy thresholds;
//! 4. embedding via `adam_full_<cfg>_embed`; norm vectors via the Rust
//!    Adam (tiny tensors; identical math, cross-checked in tests);
//! 5. metrics/checkpoints per config.

use super::checkpoint;
use super::metrics::MetricsLogger;
use super::params::HostParams;
use super::subspace_mgr::SubspaceManager;
use crate::config::RunConfig;
use crate::data::batch::{Batch, LmBatcher};
use crate::data::corpus::CorpusGen;
use crate::optim::{Adam, Hyper, Method, Optimizer};
use crate::runtime::convert::{literal_to_matrix, matrix_to_literal, tokens_to_literal};
use crate::runtime::Engine;
use crate::subspace::SubspaceStats;
use crate::tensor::Matrix;
use crate::util::json::JsonValue;
use crate::util::timer::PhaseTimer;
use anyhow::{bail, Context, Result};

/// Report from a PJRT training run.
#[derive(Clone, Debug)]
pub struct PjrtTrainReport {
    pub steps: u64,
    pub final_loss: f64,
    pub final_ppl: f64,
    pub loss_curve: Vec<(u64, f64)>,
    pub stats: SubspaceStats,
    pub time_fwdbwd_s: f64,
    pub time_update_s: f64,
    pub time_refresh_s: f64,
    pub compile_s: f64,
    pub total_s: f64,
}

/// PJRT-path trainer for one model config.
pub struct PjrtTrainer {
    pub run: RunConfig,
    pub cfg_name: String,
    engine: Engine,
    params: HostParams,
    mgr: SubspaceManager,
    emb_m: Matrix,
    emb_v: Matrix,
    norm_opts: Vec<Adam>,
    batcher: LmBatcher,
    logger: Option<MetricsLogger>,
    step: u64,
}

impl PjrtTrainer {
    /// Build a trainer: resolves the manifest config whose shape matches
    /// `run.model`, validates layouts, and warms up the executables.
    /// `method` must be PJRT-capable
    /// ([`crate::optim::registry::pjrt_supported`]).
    pub fn new(run: RunConfig, method: Method) -> Result<PjrtTrainer> {
        let engine = Engine::new(&run.artifacts)?;
        // find the manifest config matching the run's model shape
        let cfg_name = engine
            .manifest
            .configs
            .values()
            .find(|mm| {
                let c = &mm.config;
                c.vocab == run.model.vocab
                    && c.d_model == run.model.d_model
                    && c.n_layers == run.model.n_layers
                    && c.seq_len == run.model.seq_len
            })
            .map(|mm| mm.name.clone())
            .with_context(|| {
                format!(
                    "no artifact config matches model (d={}, L={}, V={}); rebuild with aot.py",
                    run.model.d_model, run.model.n_layers, run.model.vocab
                )
            })?;
        let mm = engine.manifest.config(&cfg_name)?.clone();
        if mm.batch != run.batch {
            bail!(
                "artifact batch {} != run batch {} (aot.py bakes shapes; adjust config)",
                mm.batch,
                run.batch
            );
        }
        let params = HostParams::init(run.model, run.seed);
        params.check_against(&mm.params)?;

        // distinct projected shapes in layer order
        let proj_idx = params.projected_indices();
        let shapes: Vec<(usize, usize)> =
            proj_idx.iter().map(|&i| params.entries[i].1.shape()).collect();
        let mgr = SubspaceManager::new(method, &cfg_name, &shapes, mm.rank);

        let emb_shape = params.entries[0].1.shape();
        let emb_m = Matrix::zeros(emb_shape.0, emb_shape.1);
        let emb_v = Matrix::zeros(emb_shape.0, emb_shape.1);
        let norm_opts = (0..(2 * run.model.n_layers + 1))
            .map(|_| Adam::new(1, run.model.d_model))
            .collect();

        let batcher = LmBatcher::new(
            CorpusGen::new(run.model.vocab, run.seed, run.coherence),
            run.batch,
            run.model.seq_len,
        );
        let logger = MetricsLogger::new(&run.out_dir, &run.name).ok();

        // warm up the hot-path executables
        let mut names: Vec<String> = vec![format!("fwdbwd_{cfg_name}")];
        for &(m, n) in shapes.iter().collect::<std::collections::BTreeSet<_>>() {
            names.push(engine.manifest.lowrank_adam_for(&cfg_name, m, n)?.name.clone());
        }
        names.push(format!("adam_full_{cfg_name}_embed"));
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        engine.warmup(&name_refs)?;

        Ok(PjrtTrainer {
            run,
            cfg_name,
            engine,
            params,
            mgr,
            emb_m,
            emb_v,
            norm_opts,
            batcher,
            logger,
            step: 0,
        })
    }

    /// Read access for tests.
    pub fn params(&self) -> &HostParams {
        &self.params
    }

    /// One full training step on a provided batch; returns the loss.
    pub fn step_on(&mut self, batch: &Batch, timer: &mut PhaseTimer) -> Result<f64> {
        self.step += 1;
        let t = self.step;
        let hyper = self.run.hyper;

        // ---- fwd/bwd through PJRT ----
        let mut inputs = self.params.to_literals()?;
        inputs.push(tokens_to_literal(&batch.tokens, batch.batch, batch.seq)?);
        inputs.push(tokens_to_literal(&batch.targets, batch.batch, batch.seq)?);
        let fwdbwd = format!("fwdbwd_{}", self.cfg_name);
        let t0 = std::time::Instant::now();
        let outs = self.engine.run(&fwdbwd, &inputs)?;
        timer.add("fwdbwd", t0.elapsed());
        let loss = outs[0].get_first_element::<f32>()? as f64;
        if !loss.is_finite() {
            bail!("non-finite loss at step {t}");
        }

        // grads follow param order after the loss
        let t0 = std::time::Instant::now();
        let proj_idx = self.params.projected_indices();
        for (mi, &pi) in proj_idx.iter().enumerate() {
            let (rows, cols) = self.params.entries[pi].1.shape();
            let g = literal_to_matrix(&outs[1 + pi], rows, cols)?;

            // pre-step refresh (init / GaLore interval)
            if let Some(reason) = self.mgr.needs_refresh_pre(mi, t) {
                let tr = std::time::Instant::now();
                self.mgr.refresh(&self.engine, mi, &g, t, reason)?;
                timer.add("refresh", tr.elapsed());
            }

            // projected Adam step via artifact
            let spec = self.engine.manifest.lowrank_adam_for(&self.cfg_name, rows, cols)?;
            let name = spec.name.clone();
            let lay = &self.mgr.layers[mi];
            let step_inputs = [
                matrix_to_literal(&self.params.entries[pi].1)?,
                matrix_to_literal(&g)?,
                matrix_to_literal(lay.p.as_ref().unwrap())?,
                matrix_to_literal(&lay.mom_m)?,
                matrix_to_literal(&lay.mom_v)?,
                matrix_to_literal(&lay.d_init)?,
                xla::Literal::scalar((lay.t_proj + 1) as f32),
                xla::Literal::scalar(hyper.lr),
                xla::Literal::scalar(hyper.galore_scale),
            ];
            let step_outs = self.engine.run(&name, &step_inputs)?;
            self.params.entries[pi].1 = literal_to_matrix(&step_outs[0], rows, cols)?;
            let (lr_, lc_) = self.mgr.layers[mi].mom_m.shape();
            self.mgr.layers[mi].mom_m = literal_to_matrix(&step_outs[1], lr_, lc_)?;
            self.mgr.layers[mi].mom_v = literal_to_matrix(&step_outs[2], lr_, lc_)?;
            let disp = step_outs[3].get_first_element::<f32>()? as f64;

            // post-step adaptive decision (Lotus)
            if let Some(reason) = self.mgr.observe_disp(mi, disp, t) {
                let tr = std::time::Instant::now();
                self.mgr.refresh(&self.engine, mi, &g, t, reason)?;
                timer.add("refresh", tr.elapsed());
                if let Some(log) = &self.logger {
                    log.log(JsonValue::obj(vec![
                        ("event", JsonValue::str("switch")),
                        ("step", JsonValue::num(t as f64)),
                        ("matrix", JsonValue::num(mi as f64)),
                        ("disp", JsonValue::num(disp)),
                    ]));
                }
            }
        }

        // ---- embedding via adam_full artifact ----
        let emb_name = format!("adam_full_{}_embed", self.cfg_name);
        let (er, ec) = self.params.entries[0].1.shape();
        let g_emb = literal_to_matrix(&outs[1], er, ec)?;
        let emb_outs = self.engine.run(
            &emb_name,
            &[
                matrix_to_literal(&self.params.entries[0].1)?,
                matrix_to_literal(&g_emb)?,
                matrix_to_literal(&self.emb_m)?,
                matrix_to_literal(&self.emb_v)?,
                xla::Literal::scalar(t as f32),
                xla::Literal::scalar(hyper.lr),
            ],
        )?;
        self.params.entries[0].1 = literal_to_matrix(&emb_outs[0], er, ec)?;
        self.emb_m = literal_to_matrix(&emb_outs[1], er, ec)?;
        self.emb_v = literal_to_matrix(&emb_outs[2], er, ec)?;

        // ---- norm vectors via Rust Adam ----
        let mut norm_i = 0;
        for pi in 0..self.params.entries.len() {
            let name = self.params.entries[pi].0.clone();
            if !name.contains("norm") {
                continue;
            }
            let (rows, cols) = self.params.entries[pi].1.shape();
            let g = literal_to_matrix(&outs[1 + pi], rows, cols)?;
            self.norm_opts[norm_i].step(&mut self.params.entries[pi].1, &g, &hyper, t);
            norm_i += 1;
        }
        timer.add("update", t0.elapsed());

        if let Some(log) = &self.logger {
            log.log_step(t, loss, vec![("method", JsonValue::str(self.mgr.method.name()))]);
        }
        Ok(loss)
    }

    /// Run `steps` training steps; checkpoints per the run config.
    pub fn train(&mut self, steps: u64) -> Result<PjrtTrainReport> {
        let mut timer = PhaseTimer::new();
        let t_total = std::time::Instant::now();
        let mut loss_curve = Vec::new();
        let mut final_loss = f64::NAN;
        for i in 1..=steps {
            let batch = self.batcher.next();
            let loss = self.step_on(&batch, &mut timer)?;
            final_loss = loss;
            if i % 5 == 0 || i == 1 {
                loss_curve.push((self.step, loss));
            }
            if self.run.ckpt_every > 0 && i % self.run.ckpt_every == 0 {
                let path = format!("{}/{}-step{}.ckpt", self.run.out_dir, self.run.name, self.step);
                checkpoint::save(&path, self.step, &self.params, &[])?;
                crate::log_info!("checkpoint saved: {path}");
            }
        }
        Ok(PjrtTrainReport {
            steps,
            final_loss,
            final_ppl: final_loss.exp(),
            loss_curve,
            stats: self.mgr.stats.clone(),
            time_fwdbwd_s: timer.total("fwdbwd").as_secs_f64(),
            time_update_s: timer.total("update").as_secs_f64(),
            time_refresh_s: timer.total("refresh").as_secs_f64(),
            compile_s: self.engine.total_compile_s(),
            total_s: t_total.elapsed().as_secs_f64(),
        })
    }

    /// Save a checkpoint now: parameters plus the optimizer state the
    /// resume needs (embedding moments, per-matrix subspace moments,
    /// projector bases and policy counters).
    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let metas: Vec<Matrix> = self
            .mgr
            .layers
            .iter()
            .map(|lay| {
                // [t_proj(4), last_switch(4), rng state(4), rng inc(4)]
                // as exact 16-bit limbs: counters stay exact past 2²⁴
                // and the host-refresh rSVD stream resumes exactly
                let mut data = Vec::with_capacity(16);
                checkpoint::push_u64(&mut data, lay.t_proj);
                checkpoint::push_u64(&mut data, lay.last_switch);
                let (s0, s1) = lay.rng_state();
                checkpoint::push_u64(&mut data, s0);
                checkpoint::push_u64(&mut data, s1);
                Matrix::from_vec(1, 16, data)
            })
            .collect();
        let mut extra: Vec<(String, &Matrix)> = vec![
            ("opt/emb/m".to_string(), &self.emb_m),
            ("opt/emb/v".to_string(), &self.emb_v),
        ];
        for (mi, lay) in self.mgr.layers.iter().enumerate() {
            extra.push((format!("opt/m{mi}/mom_m"), &lay.mom_m));
            extra.push((format!("opt/m{mi}/mom_v"), &lay.mom_v));
            extra.push((format!("opt/m{mi}/meta"), &metas[mi]));
            if let Some(p) = lay.p.as_ref() {
                extra.push((format!("opt/m{mi}/basis"), p));
            }
        }
        checkpoint::save(path, self.step, &self.params, &extra)
    }

    /// Restore parameters (and, when present, optimizer/subspace state —
    /// params-only checkpoints from older runs still load).
    pub fn load_checkpoint(&mut self, path: &str) -> Result<u64> {
        let (step, tensors) = checkpoint::load(path)?;
        checkpoint::restore_params(&mut self.params, &tensors)?;
        for (name, m) in &tensors {
            if name == "opt/emb/m" {
                self.emb_m = m.clone();
            } else if name == "opt/emb/v" {
                self.emb_v = m.clone();
            } else if let Some(rest) = name.strip_prefix("opt/m") {
                if let Some((idx, leaf)) = rest.split_once('/') {
                    if let Ok(mi) = idx.parse::<usize>() {
                        if mi < self.mgr.layers.len() {
                            let lay = &mut self.mgr.layers[mi];
                            match leaf {
                                "mom_m" => lay.mom_m = m.clone(),
                                "mom_v" => lay.mom_v = m.clone(),
                                "basis" => lay.p = Some(m.clone()),
                                "meta" if m.data.len() >= 16 => {
                                    lay.t_proj = checkpoint::read_u64_limbs(&m.data, 0);
                                    lay.last_switch = checkpoint::read_u64_limbs(&m.data, 4);
                                    lay.set_rng_state((
                                        checkpoint::read_u64_limbs(&m.data, 8),
                                        checkpoint::read_u64_limbs(&m.data, 12),
                                    ));
                                }
                                _ => {}
                            }
                        }
                    }
                }
            }
        }
        self.step = step;
        Ok(step)
    }
}

// Integration tests live in rust/tests/train_e2e.rs (need artifacts).
