//! Per-layer subspace state for the PJRT path.
//!
//! The artifacts compute the math (projected Adam step + the
//! displacement statistic `disp = ‖d_cur − d_init‖`); this module owns
//! the *decision*: Lotus's Algorithm 1 (check `disp/T < γ` every η
//! projections, honour `T_min`) or GaLore's fixed interval. Projector
//! refreshes go back through the `rsvd_*` artifact (Lotus) or a host
//! exact SVD (GaLore baseline — deliberately, so the ETA benches measure
//! real SVD cost on the coordinator, matching how GaLore's torch
//! implementation calls LAPACK).

use crate::projection::{side_for, Projector, Side, SvdProjector};
use crate::runtime::convert::{literal_to_matrix, matrix_to_literal};
use crate::runtime::Engine;
use crate::subspace::{SubspaceStats, SwitchReason};
use crate::tensor::Matrix;
use anyhow::Result;

/// Method variants supported on the PJRT path. (Adapter baselines are
/// simulator-only; see DESIGN.md.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PjrtMethod {
    /// Lotus: rSVD artifact refresh + adaptive displacement switching.
    Lotus { gamma: f64, eta: u64, t_min: u64 },
    /// GaLore: host exact-SVD refresh + fixed interval.
    GaLoreFixed { interval: u64 },
}

impl PjrtMethod {
    pub fn name(&self) -> &'static str {
        match self {
            PjrtMethod::Lotus { .. } => "lotus",
            PjrtMethod::GaLoreFixed { .. } => "galore",
        }
    }
}

/// State for one projected weight matrix.
pub struct LayerSubspace {
    /// Layer-shape metadata.
    pub m: usize,
    pub n: usize,
    pub rank: usize,
    pub side: Side,
    /// Projector basis (host copy; uploaded per step).
    pub p: Option<Matrix>,
    /// Subspace Adam moments.
    pub mom_m: Matrix,
    pub mom_v: Matrix,
    /// Unit gradient at subspace birth (Algorithm 1's d_init).
    pub d_init: Matrix,
    /// Projections since birth (Algorithm 1's T).
    pub t_proj: u64,
    /// Step of last switch.
    pub last_switch: u64,
    /// Per-layer rsvd seed counter (distinct Ω per refresh).
    seed: i32,
}

impl LayerSubspace {
    pub fn new(m: usize, n: usize, rank: usize, seed: i32) -> Self {
        let side = side_for(m, n);
        let (lr, lc) = match side {
            Side::Left => (rank, n),
            Side::Right => (m, rank),
        };
        LayerSubspace {
            m,
            n,
            rank,
            side,
            p: None,
            mom_m: Matrix::zeros(lr, lc),
            mom_v: Matrix::zeros(lr, lc),
            d_init: Matrix::zeros(lr, lc),
            t_proj: 0,
            last_switch: 0,
            seed,
        }
    }

    fn low_shape(&self) -> (usize, usize) {
        match self.side {
            Side::Left => (self.rank, self.n),
            Side::Right => (self.m, self.rank),
        }
    }
}

/// Manages all projected layers for one model config.
pub struct SubspaceManager {
    pub method: PjrtMethod,
    pub layers: Vec<LayerSubspace>,
    pub stats: SubspaceStats,
    cfg_name: String,
}

impl SubspaceManager {
    pub fn new(method: PjrtMethod, cfg_name: &str, shapes: &[(usize, usize)], rank: usize) -> Self {
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| LayerSubspace::new(m, n, rank, i as i32 * 7919 + 13))
            .collect();
        SubspaceManager {
            method,
            layers,
            stats: SubspaceStats::default(),
            cfg_name: cfg_name.to_string(),
        }
    }

    /// Refresh layer `li`'s projector from the gradient, via the rsvd
    /// artifact (Lotus) or host SVD (GaLore).
    pub fn refresh(
        &mut self,
        engine: &Engine,
        li: usize,
        g: &Matrix,
        step: u64,
        reason: SwitchReason,
    ) -> Result<()> {
        let lay = &mut self.layers[li];
        let lifetime = step.saturating_sub(lay.last_switch);
        match self.method {
            PjrtMethod::Lotus { .. } => {
                let spec = engine.manifest.rsvd_for(&self.cfg_name, lay.m, lay.n)?;
                lay.seed += 1;
                let out = engine.run(
                    &spec.name.clone(),
                    &[matrix_to_literal(g)?, xla::Literal::scalar(lay.seed)],
                )?;
                let pshape = &spec.outputs[0].shape;
                lay.p = Some(literal_to_matrix(&out[0], pshape[0], pshape[1])?);
                let (lr, lc) = lay.low_shape();
                lay.d_init = literal_to_matrix(&out[1], lr, lc)?;
            }
            PjrtMethod::GaLoreFixed { .. } => {
                // host exact SVD (LAPACK-equivalent cost on the coordinator)
                let proj = SvdProjector.fit(g, lay.rank);
                let low = proj.down(g);
                lay.d_init = low.normalized();
                lay.p = Some(proj.basis);
            }
        }
        let (lr, lc) = lay.low_shape();
        lay.mom_m = Matrix::zeros(lr, lc);
        lay.mom_v = Matrix::zeros(lr, lc);
        lay.t_proj = 0;
        lay.last_switch = step;
        self.stats.record_switch(reason, lifetime);
        Ok(())
    }

    /// Decide whether layer `li` must refresh *before* this step's
    /// update (fixed interval / first use).
    pub fn needs_refresh_pre(&self, li: usize, step: u64) -> Option<SwitchReason> {
        let lay = &self.layers[li];
        if lay.p.is_none() {
            return Some(SwitchReason::Init);
        }
        if let PjrtMethod::GaLoreFixed { interval } = self.method {
            if step.saturating_sub(lay.last_switch) >= interval {
                return Some(SwitchReason::Interval);
            }
        }
        None
    }

    /// Feed the artifact's displacement output; decide post-step switch
    /// (Lotus Algorithm 1). Returns the switch reason if triggered.
    pub fn observe_disp(&mut self, li: usize, disp: f64, step: u64) -> Option<SwitchReason> {
        self.stats.record_observation();
        let lay = &mut self.layers[li];
        lay.t_proj += 1;
        if let PjrtMethod::Lotus { gamma, eta, t_min } = self.method {
            if lay.t_proj % eta == 0 {
                let avg = disp / lay.t_proj as f64;
                let elapsed = step.saturating_sub(lay.last_switch);
                if avg < gamma && elapsed >= t_min {
                    return Some(SwitchReason::Displacement);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_shapes_follow_side_rule() {
        let lay = LayerSubspace::new(128, 344, 16, 0);
        assert_eq!(lay.side, Side::Left);
        assert_eq!(lay.mom_m.shape(), (16, 344));
        let lay = LayerSubspace::new(344, 128, 16, 0);
        assert_eq!(lay.side, Side::Right);
        assert_eq!(lay.mom_m.shape(), (344, 16));
    }

    #[test]
    fn pre_refresh_logic() {
        let mgr = SubspaceManager::new(
            PjrtMethod::GaLoreFixed { interval: 10 },
            "tiny",
            &[(128, 128)],
            16,
        );
        // no projector yet → Init
        assert_eq!(mgr.needs_refresh_pre(0, 5), Some(SwitchReason::Init));
    }

    #[test]
    fn lotus_observe_triggers_on_low_disp() {
        let mut mgr = SubspaceManager::new(
            PjrtMethod::Lotus { gamma: 0.01, eta: 5, t_min: 0 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        let mut switched = None;
        for step in 1..=20 {
            // constant tiny displacement: avg = 0.001/T < γ at T=5
            switched = mgr.observe_disp(0, 0.001, step);
            if switched.is_some() {
                assert_eq!(step, 5);
                break;
            }
        }
        assert_eq!(switched, Some(SwitchReason::Displacement));
    }

    #[test]
    fn lotus_observe_keeps_on_high_disp() {
        let mut mgr = SubspaceManager::new(
            PjrtMethod::Lotus { gamma: 0.01, eta: 5, t_min: 0 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        for step in 1..=50 {
            // large displacement: avg stays above γ for all T ≤ 50
            assert_eq!(mgr.observe_disp(0, 1.4, step), None);
        }
    }

    #[test]
    fn t_min_suppresses_switch() {
        let mut mgr = SubspaceManager::new(
            PjrtMethod::Lotus { gamma: 0.5, eta: 2, t_min: 1000 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        for step in 1..=100 {
            assert_eq!(mgr.observe_disp(0, 0.0001, step), None, "step {step}");
        }
    }
}
