//! Per-layer subspace state for the coordinator.
//!
//! The artifacts (or the host linalg engine) compute the math — the
//! projected Adam step plus the displacement statistic
//! `disp = ‖d_cur − d_init‖`; this module owns the *decision*: Lotus's
//! Algorithm 1 (check `disp/T < γ` every η projections, honour `T_min`)
//! or GaLore's fixed interval.
//!
//! Projector refreshes come in two flavours:
//! * **host path** ([`SubspaceManager::refresh_host`] /
//!   [`SubspaceManager::refresh_all_host`]) — always available. Lotus
//!   refreshes run the in-crate pooled rSVD range finder with a
//!   per-layer RNG stream and per-layer scratch, so
//!   `refresh_all_host` can fan independent layers across the worker
//!   pool while staying bit-deterministic at any thread count; the
//!   GaLore baseline deliberately pays for a host exact SVD (matching
//!   how GaLore's torch implementation calls LAPACK).
//! * **artifact path** ([`SubspaceManager::refresh`], `pjrt` feature) —
//!   refresh through the `rsvd_*` PJRT artifact, as the E2E driver does.

use crate::linalg::rsvd::{rsvd_range_into, RsvdOpts, RsvdScratch};
use crate::optim::{registry, Method};
use crate::projection::{side_for, Projection, Projector, Side, SvdProjector};
use crate::runtime::pool::{self, Pool};
use crate::subspace::{SubspaceStats, SwitchReason};
use crate::tensor::Matrix;
use crate::util::Rng;

#[cfg(feature = "pjrt")]
use crate::runtime::convert::{literal_to_matrix, matrix_to_literal};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
#[cfg(feature = "pjrt")]
use anyhow::Result;

/// State for one projected weight matrix.
pub struct LayerSubspace {
    /// Layer-shape metadata.
    pub m: usize,
    pub n: usize,
    pub rank: usize,
    pub side: Side,
    /// Projector basis (host copy; uploaded per step on the PJRT path).
    pub p: Option<Matrix>,
    /// Subspace Adam moments.
    pub mom_m: Matrix,
    pub mom_v: Matrix,
    /// Unit gradient at subspace birth (Algorithm 1's d_init).
    pub d_init: Matrix,
    /// Projections since birth (Algorithm 1's T).
    pub t_proj: u64,
    /// Step of last switch.
    pub last_switch: u64,
    /// Per-layer rsvd seed counter (distinct Ω per artifact refresh).
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    seed: i32,
    /// Per-layer RNG stream for host refreshes: layers own their stream,
    /// so a parallel fan-out is deterministic at any thread count.
    rng: Rng,
    /// Per-layer rSVD scratch — steady-state refreshes allocate nothing.
    scratch: RsvdScratch,
    /// Transpose buffer for Right-side host refreshes.
    gt: Matrix,
}

impl LayerSubspace {
    pub fn new(m: usize, n: usize, rank: usize, seed: i32) -> Self {
        let side = side_for(m, n);
        let (lr, lc) = match side {
            Side::Left => (rank, n),
            Side::Right => (m, rank),
        };
        LayerSubspace {
            m,
            n,
            rank,
            side,
            p: None,
            mom_m: Matrix::zeros(lr, lc),
            mom_v: Matrix::zeros(lr, lc),
            d_init: Matrix::zeros(lr, lc),
            t_proj: 0,
            last_switch: 0,
            seed,
            rng: Rng::new(0x6C6F_7475_735F_7373 ^ (seed as u64)),
            scratch: RsvdScratch::new(),
            gt: Matrix::zeros(0, 0),
        }
    }

    fn low_shape(&self) -> (usize, usize) {
        match self.side {
            Side::Left => (self.rank, self.n),
            Side::Right => (self.m, self.rank),
        }
    }

    /// Host-refresh RNG stream position, for checkpointing: a resumed
    /// run must continue the stream exactly, or its first post-resume
    /// rSVD refresh fits a different basis than the uninterrupted run.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state()
    }

    /// Restore a [`LayerSubspace::rng_state`] snapshot.
    pub fn set_rng_state(&mut self, state: (u64, u64)) {
        self.rng = Rng::from_state(state.0, state.1);
    }
}

/// Refresh one layer's projector from the gradient on the host: pooled
/// rSVD for Lotus, exact SVD for the GaLore baseline. Touches only
/// layer-local state, so callers may fan layers across threads.
fn refresh_layer_host(
    method: &Method,
    lay: &mut LayerSubspace,
    g: &Matrix,
    step: u64,
    pool: &Pool,
) {
    assert_eq!((g.rows, g.cols), (lay.m, lay.n), "gradient shape mismatch");
    let proj = match method {
        Method::Lotus { .. } | Method::RsvdFixed { .. } => {
            let opts = RsvdOpts { rank: lay.rank, oversample: 4, power_iters: 1 };
            // reuse the retired basis buffer when present
            let mut basis = lay.p.take().unwrap_or_else(|| Matrix::zeros(0, 0));
            match lay.side {
                Side::Left => {
                    rsvd_range_into(g, opts, &mut lay.rng, pool, &mut lay.scratch, &mut basis)
                }
                Side::Right => {
                    g.transpose_into(&mut lay.gt);
                    rsvd_range_into(
                        &lay.gt,
                        opts,
                        &mut lay.rng,
                        pool,
                        &mut lay.scratch,
                        &mut basis,
                    );
                }
            }
            Projection { basis, side: lay.side }
        }
        Method::GaLore { .. } => {
            // host exact SVD (LAPACK-equivalent cost on the coordinator)
            SvdProjector.fit(g, lay.rank)
        }
        other => unreachable!("SubspaceManager rejects {other:?} at construction"),
    };
    // d_init ← NORMALIZE(down(G)) (Algorithm 1's birth gradient)
    proj.down_into(g, &mut lay.d_init);
    let nrm = lay.d_init.fro_norm();
    if nrm > f32::EPSILON {
        lay.d_init.scale(1.0 / nrm);
    }
    lay.p = Some(proj.basis);
    let (lr, lc) = lay.low_shape();
    lay.mom_m.reset_to(lr, lc);
    lay.mom_v.reset_to(lr, lc);
    lay.t_proj = 0;
    lay.last_switch = step;
}

/// Manages all projected layers for one model config.
pub struct SubspaceManager {
    pub method: Method,
    pub layers: Vec<LayerSubspace>,
    pub stats: SubspaceStats,
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    cfg_name: String,
}

impl SubspaceManager {
    pub fn new(method: Method, cfg_name: &str, shapes: &[(usize, usize)], rank: usize) -> Self {
        assert!(
            registry::pjrt_supported(method),
            "PJRT path supports lotus/galore/rsvd-fixed (got {method:?}); \
             use `lotus sim` for the other baselines"
        );
        let layers = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n))| LayerSubspace::new(m, n, rank, i as i32 * 7919 + 13))
            .collect();
        SubspaceManager {
            method,
            layers,
            stats: SubspaceStats::default(),
            cfg_name: cfg_name.to_string(),
        }
    }

    /// Refresh layer `li`'s projector from the gradient on the host
    /// (no artifacts required). The rSVD GEMMs use the effective pool
    /// (full pool from the main thread, serial inside a fan-out).
    pub fn refresh_host(&mut self, li: usize, g: &Matrix, step: u64, reason: SwitchReason) {
        let lifetime = step.saturating_sub(self.layers[li].last_switch);
        refresh_layer_host(&self.method, &mut self.layers[li], g, step, &pool::effective());
        self.stats.record_switch(reason, lifetime);
    }

    /// Refresh many layers at once, fanning the independent per-layer
    /// rSVDs across the worker pool. `grads[i]` is `Some(G_i)` for every
    /// layer to refresh (indices align with `self.layers`).
    ///
    /// Determinism: each layer consumes only its own RNG stream and
    /// scratch, so the result is identical to calling
    /// [`SubspaceManager::refresh_host`] per layer in order, at any
    /// thread count.
    pub fn refresh_all_host(&mut self, grads: &[Option<&Matrix>], step: u64, reason: SwitchReason) {
        assert_eq!(grads.len(), self.layers.len(), "one gradient slot per layer");
        let lifetimes: Vec<u64> = self
            .layers
            .iter()
            .map(|lay| step.saturating_sub(lay.last_switch))
            .collect();
        let method = self.method;
        // inner GEMMs stay serial: the layer fan-out already owns the pool
        let inner = Pool::serial();
        {
            let mut jobs: Vec<(&mut LayerSubspace, &Matrix)> = self
                .layers
                .iter_mut()
                .zip(grads.iter().copied())
                .filter_map(|(lay, g)| g.map(|g| (lay, g)))
                .collect();
            pool::global().par_items_mut(&mut jobs, |_, job| {
                let (lay, g) = job;
                refresh_layer_host(&method, lay, g, step, &inner);
            });
        }
        for (i, g) in grads.iter().enumerate() {
            if g.is_some() {
                self.stats.record_switch(reason, lifetimes[i]);
            }
        }
    }

    /// Refresh layer `li`'s projector from the gradient, via the rsvd
    /// artifact (Lotus) or host SVD (GaLore).
    #[cfg(feature = "pjrt")]
    pub fn refresh(
        &mut self,
        engine: &Engine,
        li: usize,
        g: &Matrix,
        step: u64,
        reason: SwitchReason,
    ) -> Result<()> {
        let lay = &mut self.layers[li];
        let lifetime = step.saturating_sub(lay.last_switch);
        match self.method {
            Method::Lotus { .. } | Method::RsvdFixed { .. } => {
                let spec = engine.manifest.rsvd_for(&self.cfg_name, lay.m, lay.n)?;
                lay.seed += 1;
                let out = engine.run(
                    &spec.name.clone(),
                    &[matrix_to_literal(g)?, xla::Literal::scalar(lay.seed)],
                )?;
                let pshape = &spec.outputs[0].shape;
                lay.p = Some(literal_to_matrix(&out[0], pshape[0], pshape[1])?);
                let (lr, lc) = lay.low_shape();
                lay.d_init = literal_to_matrix(&out[1], lr, lc)?;
            }
            Method::GaLore { .. } => {
                // host exact SVD (LAPACK-equivalent cost on the coordinator)
                let proj = SvdProjector.fit(g, lay.rank);
                let low = proj.down(g);
                lay.d_init = low.normalized();
                lay.p = Some(proj.basis);
            }
            other => unreachable!("SubspaceManager rejects {other:?} at construction"),
        }
        let (lr, lc) = lay.low_shape();
        lay.mom_m = Matrix::zeros(lr, lc);
        lay.mom_v = Matrix::zeros(lr, lc);
        lay.t_proj = 0;
        lay.last_switch = step;
        self.stats.record_switch(reason, lifetime);
        Ok(())
    }

    /// Decide whether layer `li` must refresh *before* this step's
    /// update (fixed interval / first use).
    pub fn needs_refresh_pre(&self, li: usize, step: u64) -> Option<SwitchReason> {
        let lay = &self.layers[li];
        if lay.p.is_none() {
            return Some(SwitchReason::Init);
        }
        if let Method::GaLore { interval } | Method::RsvdFixed { interval } = self.method {
            if step.saturating_sub(lay.last_switch) >= interval {
                return Some(SwitchReason::Interval);
            }
        }
        None
    }

    /// Feed the artifact's displacement output; decide post-step switch
    /// (Lotus Algorithm 1). Returns the switch reason if triggered.
    pub fn observe_disp(&mut self, li: usize, disp: f64, step: u64) -> Option<SwitchReason> {
        self.stats.record_observation();
        let lay = &mut self.layers[li];
        lay.t_proj += 1;
        if let Method::Lotus { gamma, eta, t_min } = self.method {
            if lay.t_proj % eta == 0 {
                let avg = disp / lay.t_proj as f64;
                let elapsed = step.saturating_sub(lay.last_switch);
                if avg < gamma && elapsed >= t_min {
                    return Some(SwitchReason::Displacement);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_shapes_follow_side_rule() {
        let lay = LayerSubspace::new(128, 344, 16, 0);
        assert_eq!(lay.side, Side::Left);
        assert_eq!(lay.mom_m.shape(), (16, 344));
        let lay = LayerSubspace::new(344, 128, 16, 0);
        assert_eq!(lay.side, Side::Right);
        assert_eq!(lay.mom_m.shape(), (344, 16));
    }

    #[test]
    fn pre_refresh_logic() {
        let mgr = SubspaceManager::new(
            Method::GaLore { interval: 10 },
            "tiny",
            &[(128, 128)],
            16,
        );
        // no projector yet → Init
        assert_eq!(mgr.needs_refresh_pre(0, 5), Some(SwitchReason::Init));
    }

    #[test]
    fn lotus_observe_triggers_on_low_disp() {
        let mut mgr = SubspaceManager::new(
            Method::Lotus { gamma: 0.01, eta: 5, t_min: 0 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        let mut switched = None;
        for step in 1..=20 {
            // constant tiny displacement: avg = 0.001/T < γ at T=5
            switched = mgr.observe_disp(0, 0.001, step);
            if switched.is_some() {
                assert_eq!(step, 5);
                break;
            }
        }
        assert_eq!(switched, Some(SwitchReason::Displacement));
    }

    #[test]
    fn lotus_observe_keeps_on_high_disp() {
        let mut mgr = SubspaceManager::new(
            Method::Lotus { gamma: 0.01, eta: 5, t_min: 0 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        for step in 1..=50 {
            // large displacement: avg stays above γ for all T ≤ 50
            assert_eq!(mgr.observe_disp(0, 1.4, step), None);
        }
    }

    #[test]
    fn t_min_suppresses_switch() {
        let mut mgr = SubspaceManager::new(
            Method::Lotus { gamma: 0.5, eta: 2, t_min: 1000 },
            "tiny",
            &[(64, 64)],
            8,
        );
        mgr.layers[0].p = Some(Matrix::eye(64));
        for step in 1..=100 {
            assert_eq!(mgr.observe_disp(0, 0.0001, step), None, "step {step}");
        }
    }

    #[test]
    fn host_refresh_produces_consistent_state() {
        use crate::linalg::norms::orthonormality_error;
        let mut mgr = SubspaceManager::new(
            Method::Lotus { gamma: 0.01, eta: 5, t_min: 0 },
            "tiny",
            &[(32, 96), (96, 32)],
            8,
        );
        let mut rng = Rng::new(41);
        let g0 = Matrix::randn(32, 96, 1.0, &mut rng);
        let g1 = Matrix::randn(96, 32, 1.0, &mut rng);
        mgr.refresh_host(0, &g0, 3, SwitchReason::Init);
        mgr.refresh_host(1, &g1, 3, SwitchReason::Init);
        assert_eq!(mgr.stats.subspace_count, 2);
        for (lay, g) in mgr.layers.iter().zip([&g0, &g1]) {
            let p = lay.p.as_ref().expect("basis fitted");
            assert!(orthonormality_error(p) < 1e-3);
            assert_eq!(lay.d_init.shape(), lay.low_shape());
            assert!((lay.d_init.fro_norm() - 1.0).abs() < 1e-4);
            assert_eq!(lay.mom_m.fro_norm(), 0.0);
            assert_eq!(lay.last_switch, 3);
            assert_eq!((g.rows, g.cols), (lay.m, lay.n));
        }
    }

    #[test]
    fn parallel_refresh_matches_sequential_bit_for_bit() {
        let shapes = [(24, 80), (80, 24), (40, 40), (16, 64), (64, 16)];
        let mut rng = Rng::new(42);
        let grads: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 1.0, &mut rng)).collect();
        let method = Method::Lotus { gamma: 0.01, eta: 5, t_min: 0 };

        let mut seq = SubspaceManager::new(method, "tiny", &shapes, 8);
        for (li, g) in grads.iter().enumerate() {
            seq.refresh_host(li, g, 7, SwitchReason::Init);
        }

        let mut par = SubspaceManager::new(method, "tiny", &shapes, 8);
        let slots: Vec<Option<&Matrix>> = grads.iter().map(Some).collect();
        par.refresh_all_host(&slots, 7, SwitchReason::Init);

        assert_eq!(par.stats.subspace_count, seq.stats.subspace_count);
        for (a, b) in par.layers.iter().zip(&seq.layers) {
            assert_eq!(a.p.as_ref().unwrap().data, b.p.as_ref().unwrap().data);
            assert_eq!(a.d_init.data, b.d_init.data);
            assert_eq!(a.last_switch, b.last_switch);
        }
    }

    #[test]
    fn refresh_all_host_skips_none_slots() {
        let shapes = [(16, 32), (32, 16)];
        let mut rng = Rng::new(43);
        let g = Matrix::randn(16, 32, 1.0, &mut rng);
        let method = Method::Lotus { gamma: 0.01, eta: 5, t_min: 0 };
        let mut mgr = SubspaceManager::new(method, "tiny", &shapes, 4);
        mgr.refresh_all_host(&[Some(&g), None], 1, SwitchReason::Init);
        assert!(mgr.layers[0].p.is_some());
        assert!(mgr.layers[1].p.is_none());
        assert_eq!(mgr.stats.subspace_count, 1);
    }
}
