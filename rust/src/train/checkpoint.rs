//! Binary checkpointing for [`HostParams`] + optimizer/subspace state.
//!
//! Format (little-endian):
//! ```text
//! magic  "LOTUSCKP"            8 bytes
//! version u32                  (1)
//! step    u64
//! count   u32                  number of tensors
//! per tensor: name_len u32, name bytes, rows u32, cols u32, f32 data
//! ```

use super::params::HostParams;
use crate::models::LlamaConfig;
use crate::sim::model::Params as SimParams;
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LOTUSCKP";
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Shared writer: the container is just `step` + named f32 tensors, so
/// every producer (PJRT params, dist replica + optimizer shards) uses
/// the same format and [`load`].
fn write_tensors<'a, I>(path: impl AsRef<Path>, step: u64, count: usize, tensors: I) -> Result<()>
where
    I: Iterator<Item = (&'a str, &'a Matrix)>,
{
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u64(&mut w, step)?;
    write_u32(&mut w, count as u32)?;
    let mut written = 0usize;
    for (name, m) in tensors {
        write_u32(&mut w, name.len() as u32)?;
        w.write_all(name.as_bytes())?;
        write_u32(&mut w, m.rows as u32)?;
        write_u32(&mut w, m.cols as u32)?;
        // f32 slice → bytes
        let bytes: Vec<u8> = m.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        w.write_all(&bytes)?;
        written += 1;
    }
    if written != count {
        bail!("checkpoint writer: declared {count} tensors, wrote {written}");
    }
    w.flush()?;
    Ok(())
}

/// Save params (+ any extra named tensors, e.g. optimizer moments).
pub fn save(
    path: impl AsRef<Path>,
    step: u64,
    params: &HostParams,
    extra: &[(String, &Matrix)],
) -> Result<()> {
    let all = params
        .entries
        .iter()
        .map(|(n, m)| (n.as_str(), m))
        .chain(extra.iter().map(|(n, m)| (n.as_str(), *m)));
    write_tensors(path, step, params.entries.len() + extra.len(), all)
}

/// Save an arbitrary named-tensor set (owned variant). Loadable with
/// [`load`].
pub fn save_named(path: impl AsRef<Path>, step: u64, tensors: &[(String, Matrix)]) -> Result<()> {
    write_tensors(path, step, tensors.len(), tensors.iter().map(|(n, m)| (n.as_str(), m)))
}

/// Save referenced tensors without copying — the dist engine borrows
/// its model/optimizer tensors directly (only small synthesized meta
/// rows are owned by the caller), so checkpointing never doubles peak
/// memory. Loadable with [`load`].
pub fn save_refs(path: impl AsRef<Path>, step: u64, tensors: &[(String, &Matrix)]) -> Result<()> {
    write_tensors(path, step, tensors.len(), tensors.iter().map(|(n, m)| (n.as_str(), *m)))
}

/// Save just the model weights (no optimizer state) — the deploy
/// artifact the serving engine ([`crate::serve`]) loads. Same container
/// format as every other writer; the large matrices are borrowed, so
/// saving never doubles peak weight memory.
pub fn save_weights(path: impl AsRef<Path>, step: u64, params: &SimParams) -> Result<()> {
    let (synth, refs) = params.export_tensors();
    let mut tensors: Vec<(String, &Matrix)> = refs;
    tensors.extend(synth.iter().map(|(n, m)| (n.clone(), m)));
    save_refs(path, step, &tensors)
}

/// Load model weights from any lotus checkpoint — a weights-only file
/// from [`save_weights`] or a full trainer container (the `model/*`
/// tensors are named identically either way) — validating every tensor
/// shape against `cfg`. Returns `(saved step, params)`.
pub fn load_weights(path: impl AsRef<Path>, cfg: LlamaConfig) -> Result<(u64, SimParams)> {
    let (step, tensors) = load(path)?;
    // layers are named contiguously, so one probe catches a deeper model
    // (restore-by-name would silently serve a truncated network)
    let beyond = format!("model/L{}/wq", cfg.n_layers);
    if tensors.iter().any(|(n, _)| *n == beyond) {
        bail!(
            "checkpoint has more than the configured {} layers — wrong --preset/--config?",
            cfg.n_layers
        );
    }
    let mut params = SimParams::zeros(&cfg);
    params.restore_from_tensors(&tensors).map_err(|e| anyhow!("{e}"))?;
    validate_weight_shapes(&cfg, &params)?;
    Ok((step, params))
}

/// Reject checkpoints whose tensors don't match the configured model
/// shape (restore-by-name would otherwise silently adopt foreign
/// shapes, and the serving forward would panic deep in a kernel).
fn validate_weight_shapes(cfg: &LlamaConfig, p: &SimParams) -> Result<()> {
    let d = cfg.d_model;
    let f = cfg.d_ff;
    if p.embed.shape() != (cfg.vocab, d) {
        bail!(
            "checkpoint model/embed is {:?}, config wants ({}, {d}) — wrong --preset/--config?",
            p.embed.shape(),
            cfg.vocab
        );
    }
    for (li, lp) in p.layers.iter().enumerate() {
        for (name, m, want) in [
            ("wq", &lp.wq, (d, d)),
            ("wk", &lp.wk, (d, d)),
            ("wv", &lp.wv, (d, d)),
            ("wo", &lp.wo, (d, d)),
            ("w1", &lp.w1, (d, f)),
            ("w3", &lp.w3, (d, f)),
            ("w2", &lp.w2, (f, d)),
        ] {
            if m.shape() != want {
                bail!(
                    "checkpoint model/L{li}/{name} is {:?}, config wants {:?}",
                    m.shape(),
                    want
                );
            }
        }
        if lp.norm1.len() != d || lp.norm2.len() != d {
            bail!("checkpoint model/L{li} norm length != d_model {d}");
        }
    }
    if p.final_norm.len() != d {
        bail!("checkpoint model/final_norm length {} != d_model {d}", p.final_norm.len());
    }
    Ok(())
}

// The 16-bit-limb integer codec lives in `util::codec` (it is shared
// with the optimizer state codec, `crate::optim::state`); re-exported
// here because checkpoint writers are its main consumer.
pub use crate::util::codec::{f32x4_to_u64, push_u64, read_u64_limbs, u64_to_f32x4};

/// Load a checkpoint: (step, named tensors).
pub fn load(path: impl AsRef<Path>) -> Result<(u64, Vec<(String, Matrix)>)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a lotus checkpoint (bad magic)");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = read_u64(&mut r)?;
    let count = read_u32(&mut r)? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let rows = read_u32(&mut r)? as usize;
        let cols = read_u32(&mut r)? as usize;
        let mut bytes = vec![0u8; rows * cols * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push((String::from_utf8(name)?, Matrix::from_vec(rows, cols, data)));
    }
    Ok((step, tensors))
}

/// Restore params in place from a loaded tensor list (by name).
pub fn restore_params(params: &mut HostParams, tensors: &[(String, Matrix)]) -> Result<()> {
    for (name, m) in &mut params.entries {
        let found = tensors
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))?;
        if found.1.shape() != m.shape() {
            bail!("shape mismatch restoring {name}");
        }
        *m = found.1.clone();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_tiny_cfg;

    #[test]
    fn roundtrip_exact() {
        let params = HostParams::init(llama_tiny_cfg(), 3);
        let dir = std::env::temp_dir().join("lotus_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let extra_m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        save(&path, 123, &params, &[("opt.m".into(), &extra_m)]).unwrap();

        let (step, tensors) = load(&path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(tensors.len(), params.entries.len() + 1);
        let mut restored = HostParams::init(llama_tiny_cfg(), 999); // different seed
        restore_params(&mut restored, &tensors).unwrap();
        for ((_, a), (_, b)) in params.entries.iter().zip(&restored.entries) {
            assert_eq!(a, b, "bit-exact restore");
        }
        let extra_back = tensors.iter().find(|(n, _)| n == "opt.m").unwrap();
        assert_eq!(extra_back.1, extra_m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_named_roundtrips() {
        let dir = std::env::temp_dir().join("lotus_ckpt_named");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("named.ckpt");
        let tensors = vec![
            ("opt/w0/m0/mom_m".to_string(), Matrix::from_vec(2, 3, vec![1.0; 6])),
            ("policy/s1/m0/meta".to_string(), Matrix::from_vec(1, 2, vec![0.0, 7.0])),
        ];
        save_named(&path, 55, &tensors).unwrap();
        let (step, back) = load(&path).unwrap();
        assert_eq!(step, 55);
        assert_eq!(back.len(), 2);
        for ((n0, m0), (n1, m1)) in tensors.iter().zip(&back) {
            assert_eq!(n0, n1);
            assert_eq!(m0, m1);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn weights_only_roundtrips_and_validates_shapes() {
        use crate::sim::SimModel;
        let cfg = llama_tiny_cfg();
        let m = SimModel::new(cfg, 17);
        let dir = std::env::temp_dir().join("lotus_ckpt_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights.ckpt");
        save_weights(&path, 42, &m.params).unwrap();
        let (step, p) = load_weights(&path, cfg).unwrap();
        assert_eq!(step, 42);
        assert_eq!(p.embed, m.params.embed, "bit-exact weights restore");
        assert_eq!(p.layers[0].wq, m.params.layers[0].wq);
        assert_eq!(p.final_norm, m.params.final_norm);
        // a different model shape must be rejected, not silently adopted
        let mini = crate::models::presets::llama_mini_cfg();
        assert!(load_weights(&path, mini).is_err());
        // ...including a config with FEWER layers than the checkpoint
        // (restore-by-name would otherwise serve a truncated network)
        let mut shallow = cfg;
        shallow.n_layers = 1;
        assert!(load_weights(&path, shallow).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lotus_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
