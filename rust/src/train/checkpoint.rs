//! Binary checkpointing for [`HostParams`] + optimizer/subspace state.
//!
//! Format v2 (little-endian) — hardened in PR 6 with length framing and
//! a container CRC so that *any* single corrupted or missing byte turns
//! into a typed [`CkptError`], never a panic or a silently wrong tensor:
//! ```text
//! magic    "LOTUSCKP"           8 bytes
//! version  u32                  (2)
//! body_len u64                  exact byte length of `body`
//! crc32    u32                  CRC-32 (IEEE) over `body`
//! body:
//!   step   u64
//!   count  u32                  number of tensors
//!   per tensor: name_len u32, name bytes, rows u32, cols u32, f32 data
//! ```
//!
//! The loader verifies magic → version → exact length → CRC before it
//! parses a single tensor, and every body read is bounds-checked with
//! overflow-checked shape arithmetic (`rust/tests/properties.rs` and the
//! fuzz tests below mangle every byte offset and every truncation
//! length and assert `Err`).

use super::params::HostParams;
use crate::models::LlamaConfig;
use crate::sim::model::Params as SimParams;
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LOTUSCKP";
const VERSION: u32 = 2;
/// Sanity bound on tensor-name length; real names are < 64 bytes.
const MAX_NAME_LEN: usize = 4096;

/// Typed corruption diagnoses for the checkpoint container. Wrapped in
/// `anyhow` by [`load`] so call sites keep their `Result<_>` plumbing,
/// but matchable via `err.downcast_ref::<CkptError>()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CkptError {
    /// The first 8 bytes are not `LOTUSCKP`.
    BadMagic,
    /// Magic matched but the version word is not the supported one.
    BadVersion(u32),
    /// The file ended before a declared field, or `body_len` disagrees
    /// with the actual byte count on disk.
    Truncated,
    /// The container CRC does not match the body bytes.
    CrcMismatch { stored: u32, computed: u32 },
    /// A tensor name length exceeds the sanity bound.
    NameTooLong(usize),
    /// A tensor name is not valid UTF-8.
    BadName,
    /// rows×cols×4 overflows or disagrees with the remaining bytes.
    BadShape { rows: usize, cols: usize },
    /// The body parsed cleanly but bytes remain after the last tensor.
    TrailingBytes(usize),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::BadMagic => write!(f, "not a lotus checkpoint (bad magic)"),
            CkptError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CkptError::Truncated => write!(f, "corrupt checkpoint: truncated"),
            CkptError::CrcMismatch { stored, computed } => write!(
                f,
                "corrupt checkpoint: CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            CkptError::NameTooLong(n) => write!(f, "corrupt checkpoint: name length {n}"),
            CkptError::BadName => write!(f, "corrupt checkpoint: tensor name is not UTF-8"),
            CkptError::BadShape { rows, cols } => {
                write!(f, "corrupt checkpoint: impossible tensor shape {rows}x{cols}")
            }
            CkptError::TrailingBytes(n) => {
                write!(f, "corrupt checkpoint: {n} trailing bytes after last tensor")
            }
        }
    }
}

impl std::error::Error for CkptError {}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3 polynomial) — the container checksum.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

fn push_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn push_u64_le(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Shared writer: the container is just `step` + named f32 tensors, so
/// every producer (PJRT params, dist replica + optimizer shards) uses
/// the same format and [`load`]. The body is assembled in memory so the
/// header can carry its exact length and CRC.
fn write_tensors<'a, I>(path: impl AsRef<Path>, step: u64, count: usize, tensors: I) -> Result<()>
where
    I: Iterator<Item = (&'a str, &'a Matrix)>,
{
    let mut body = Vec::new();
    push_u64_le(&mut body, step);
    push_u32(&mut body, count as u32);
    let mut written = 0usize;
    for (name, m) in tensors {
        push_u32(&mut body, name.len() as u32);
        body.extend_from_slice(name.as_bytes());
        push_u32(&mut body, m.rows as u32);
        push_u32(&mut body, m.cols as u32);
        for x in &m.data {
            body.extend_from_slice(&x.to_le_bytes());
        }
        written += 1;
    }
    if written != count {
        bail!("checkpoint writer: declared {count} tensors, wrote {written}");
    }
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(body.len() as u64).to_le_bytes())?;
    w.write_all(&crc32(&body).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Save params (+ any extra named tensors, e.g. optimizer moments).
pub fn save(
    path: impl AsRef<Path>,
    step: u64,
    params: &HostParams,
    extra: &[(String, &Matrix)],
) -> Result<()> {
    let all = params
        .entries
        .iter()
        .map(|(n, m)| (n.as_str(), m))
        .chain(extra.iter().map(|(n, m)| (n.as_str(), *m)));
    write_tensors(path, step, params.entries.len() + extra.len(), all)
}

/// Save an arbitrary named-tensor set (owned variant). Loadable with
/// [`load`].
pub fn save_named(path: impl AsRef<Path>, step: u64, tensors: &[(String, Matrix)]) -> Result<()> {
    write_tensors(path, step, tensors.len(), tensors.iter().map(|(n, m)| (n.as_str(), m)))
}

/// Save referenced tensors without copying — the dist engine borrows
/// its model/optimizer tensors directly (only small synthesized meta
/// rows are owned by the caller), so checkpointing never doubles peak
/// memory. Loadable with [`load`].
pub fn save_refs(path: impl AsRef<Path>, step: u64, tensors: &[(String, &Matrix)]) -> Result<()> {
    write_tensors(path, step, tensors.len(), tensors.iter().map(|(n, m)| (n.as_str(), *m)))
}

/// Save just the model weights (no optimizer state) — the deploy
/// artifact the serving engine ([`crate::serve`]) loads. Same container
/// format as every other writer; the large matrices are borrowed, so
/// saving never doubles peak weight memory.
pub fn save_weights(path: impl AsRef<Path>, step: u64, params: &SimParams) -> Result<()> {
    let (synth, refs) = params.export_tensors();
    let mut tensors: Vec<(String, &Matrix)> = refs;
    tensors.extend(synth.iter().map(|(n, m)| (n.clone(), m)));
    save_refs(path, step, &tensors)
}

/// Load model weights from any lotus checkpoint — a weights-only file
/// from [`save_weights`] or a full trainer container (the `model/*`
/// tensors are named identically either way) — validating every tensor
/// shape against `cfg`. Returns `(saved step, params)`.
pub fn load_weights(path: impl AsRef<Path>, cfg: LlamaConfig) -> Result<(u64, SimParams)> {
    let buf = std::fs::read(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
    load_weights_bytes(&buf, cfg)
        .with_context(|| format!("loading checkpoint {:?}", path.as_ref()))
}

/// [`load_weights`] over an in-memory container image. The serving
/// engine's reload path goes through here so a corrupt container —
/// whatever mangled it between save and reload — surfaces as a typed
/// [`CkptError`] the caller can fall back from, never a panic.
pub fn load_weights_bytes(buf: &[u8], cfg: LlamaConfig) -> Result<(u64, SimParams)> {
    let (step, tensors) = parse(buf).map_err(anyhow::Error::new)?;
    // layers are named contiguously, so one probe catches a deeper model
    // (restore-by-name would silently serve a truncated network)
    let beyond = format!("model/L{}/wq", cfg.n_layers);
    if tensors.iter().any(|(n, _)| *n == beyond) {
        bail!(
            "checkpoint has more than the configured {} layers — wrong --preset/--config?",
            cfg.n_layers
        );
    }
    let mut params = SimParams::zeros(&cfg);
    params.restore_from_tensors(&tensors).map_err(|e| anyhow!("{e}"))?;
    validate_weight_shapes(&cfg, &params)?;
    Ok((step, params))
}

/// Reject checkpoints whose tensors don't match the configured model
/// shape (restore-by-name would otherwise silently adopt foreign
/// shapes, and the serving forward would panic deep in a kernel).
fn validate_weight_shapes(cfg: &LlamaConfig, p: &SimParams) -> Result<()> {
    let d = cfg.d_model;
    let f = cfg.d_ff;
    if p.embed.shape() != (cfg.vocab, d) {
        bail!(
            "checkpoint model/embed is {:?}, config wants ({}, {d}) — wrong --preset/--config?",
            p.embed.shape(),
            cfg.vocab
        );
    }
    for (li, lp) in p.layers.iter().enumerate() {
        for (name, m, want) in [
            ("wq", &lp.wq, (d, d)),
            ("wk", &lp.wk, (d, d)),
            ("wv", &lp.wv, (d, d)),
            ("wo", &lp.wo, (d, d)),
            ("w1", &lp.w1, (d, f)),
            ("w3", &lp.w3, (d, f)),
            ("w2", &lp.w2, (f, d)),
        ] {
            if m.shape() != want {
                bail!(
                    "checkpoint model/L{li}/{name} is {:?}, config wants {:?}",
                    m.shape(),
                    want
                );
            }
        }
        if lp.norm1.len() != d || lp.norm2.len() != d {
            bail!("checkpoint model/L{li} norm length != d_model {d}");
        }
    }
    if p.final_norm.len() != d {
        bail!("checkpoint model/final_norm length {} != d_model {d}", p.final_norm.len());
    }
    Ok(())
}

// The 16-bit-limb integer codec lives in `util::codec` (it is shared
// with the optimizer state codec, `crate::optim::state`); re-exported
// here because checkpoint writers are its main consumer.
pub use crate::util::codec::{f32x4_to_u64, push_u64, read_u64_limbs, u64_to_f32x4};

/// Bounds-checked cursor over the raw container bytes — every read that
/// would run past the end is a typed [`CkptError::Truncated`], never a
/// slice panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], CkptError> {
        let end = self.pos.checked_add(n).ok_or(CkptError::Truncated)?;
        if end > self.buf.len() {
            return Err(CkptError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> std::result::Result<u32, CkptError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> std::result::Result<u64, CkptError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Parse a full container image. Header first (magic → version → exact
/// length → CRC), so a flipped bit anywhere in the file is diagnosed
/// before any tensor bytes are trusted.
fn parse(buf: &[u8]) -> std::result::Result<(u64, Vec<(String, Matrix)>), CkptError> {
    let mut cur = Cursor { buf, pos: 0 };
    if cur.take(8)? != MAGIC {
        return Err(CkptError::BadMagic);
    }
    let version = cur.u32()?;
    if version != VERSION {
        return Err(CkptError::BadVersion(version));
    }
    let body_len = cur.u64()?;
    let stored = cur.u32()?;
    if cur.remaining() as u64 != body_len {
        return Err(CkptError::Truncated);
    }
    let computed = crc32(&buf[cur.pos..]);
    if computed != stored {
        return Err(CkptError::CrcMismatch { stored, computed });
    }
    let step = cur.u64()?;
    let count = cur.u32()? as usize;
    let mut tensors = Vec::new();
    for _ in 0..count {
        let name_len = cur.u32()? as usize;
        if name_len > MAX_NAME_LEN {
            return Err(CkptError::NameTooLong(name_len));
        }
        let name = std::str::from_utf8(cur.take(name_len)?)
            .map_err(|_| CkptError::BadName)?
            .to_string();
        let rows = cur.u32()? as usize;
        let cols = cur.u32()? as usize;
        let nbytes = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(4))
            .ok_or(CkptError::BadShape { rows, cols })?;
        let data: Vec<f32> = cur
            .take(nbytes)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push((name, Matrix::from_vec(rows, cols, data)));
    }
    if cur.remaining() != 0 {
        return Err(CkptError::TrailingBytes(cur.remaining()));
    }
    Ok((step, tensors))
}

/// Load a checkpoint: (step, named tensors). Corruption anywhere in the
/// file — a flipped bit, a truncation, trailing garbage — is a typed
/// [`CkptError`] inside the returned `anyhow` error, never a panic.
pub fn load(path: impl AsRef<Path>) -> Result<(u64, Vec<(String, Matrix)>)> {
    let buf = std::fs::read(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
    let (step, tensors) =
        parse(&buf).with_context(|| format!("loading checkpoint {:?}", path.as_ref()))?;
    Ok((step, tensors))
}

/// Restore params in place from a loaded tensor list (by name).
pub fn restore_params(params: &mut HostParams, tensors: &[(String, Matrix)]) -> Result<()> {
    for (name, m) in &mut params.entries {
        let found = tensors
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))?;
        if found.1.shape() != m.shape() {
            bail!("shape mismatch restoring {name}");
        }
        *m = found.1.clone();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_tiny_cfg;

    #[test]
    fn roundtrip_exact() {
        let params = HostParams::init(llama_tiny_cfg(), 3);
        let dir = std::env::temp_dir().join("lotus_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let extra_m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        save(&path, 123, &params, &[("opt.m".into(), &extra_m)]).unwrap();

        let (step, tensors) = load(&path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(tensors.len(), params.entries.len() + 1);
        let mut restored = HostParams::init(llama_tiny_cfg(), 999); // different seed
        restore_params(&mut restored, &tensors).unwrap();
        for ((_, a), (_, b)) in params.entries.iter().zip(&restored.entries) {
            assert_eq!(a, b, "bit-exact restore");
        }
        let extra_back = tensors.iter().find(|(n, _)| n == "opt.m").unwrap();
        assert_eq!(extra_back.1, extra_m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_named_roundtrips() {
        let dir = std::env::temp_dir().join("lotus_ckpt_named");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("named.ckpt");
        let tensors = vec![
            ("opt/w0/m0/mom_m".to_string(), Matrix::from_vec(2, 3, vec![1.0; 6])),
            ("policy/s1/m0/meta".to_string(), Matrix::from_vec(1, 2, vec![0.0, 7.0])),
        ];
        save_named(&path, 55, &tensors).unwrap();
        let (step, back) = load(&path).unwrap();
        assert_eq!(step, 55);
        assert_eq!(back.len(), 2);
        for ((n0, m0), (n1, m1)) in tensors.iter().zip(&back) {
            assert_eq!(n0, n1);
            assert_eq!(m0, m1);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn weights_only_roundtrips_and_validates_shapes() {
        use crate::sim::SimModel;
        let cfg = llama_tiny_cfg();
        let m = SimModel::new(cfg, 17);
        let dir = std::env::temp_dir().join("lotus_ckpt_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("weights.ckpt");
        save_weights(&path, 42, &m.params).unwrap();
        let (step, p) = load_weights(&path, cfg).unwrap();
        assert_eq!(step, 42);
        assert_eq!(p.embed, m.params.embed, "bit-exact weights restore");
        assert_eq!(p.layers[0].wq, m.params.layers[0].wq);
        assert_eq!(p.final_norm, m.params.final_norm);
        // a different model shape must be rejected, not silently adopted
        let mini = crate::models::presets::llama_mini_cfg();
        assert!(load_weights(&path, mini).is_err());
        // ...including a config with FEWER layers than the checkpoint
        // (restore-by-name would otherwise serve a truncated network)
        let mut shallow = cfg;
        shallow.n_layers = 1;
        assert!(load_weights(&path, shallow).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lotus_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The canonical IEEE check value: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    /// The satellite-1 fuzz contract: flipping ANY byte of a valid
    /// container, or truncating it at ANY length, yields `Err` — never a
    /// panic, never a silently-wrong load.
    #[test]
    fn every_single_byte_corruption_is_detected() {
        let dir = std::env::temp_dir().join("lotus_ckpt_fuzz");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fuzz.ckpt");
        let tensors = vec![
            ("model/L0/wq".to_string(), Matrix::from_vec(3, 4, (0..12).map(|i| i as f32).collect())),
            ("opt/w0/m0/mom_m".to_string(), Matrix::from_vec(2, 2, vec![0.5, -1.5, 2.5, -3.5])),
        ];
        save_named(&path, 9, &tensors).unwrap();
        let pristine = std::fs::read(&path).unwrap();
        assert!(parse(&pristine).is_ok());

        for off in 0..pristine.len() {
            for flip in [0x01u8, 0xFF] {
                let mut mangled = pristine.clone();
                mangled[off] ^= flip;
                assert!(
                    parse(&mangled).is_err(),
                    "byte {off} xor {flip:#04x} loaded despite corruption"
                );
            }
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn every_truncation_length_is_detected() {
        let dir = std::env::temp_dir().join("lotus_ckpt_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.ckpt");
        let tensors =
            vec![("model/final_norm".to_string(), Matrix::from_vec(1, 8, vec![1.0; 8]))];
        save_named(&path, 4, &tensors).unwrap();
        let pristine = std::fs::read(&path).unwrap();
        for len in 0..pristine.len() {
            assert!(parse(&pristine[..len]).is_err(), "prefix of {len} bytes loaded");
        }
        // appended garbage must fail too (length framing)
        let mut padded = pristine.clone();
        padded.extend_from_slice(&[0xAB; 7]);
        assert!(parse(&padded).is_err(), "trailing garbage accepted");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corruption_errors_are_typed() {
        let dir = std::env::temp_dir().join("lotus_ckpt_typed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("typed.ckpt");
        let tensors = vec![("t".to_string(), Matrix::from_vec(1, 1, vec![1.0]))];
        save_named(&path, 1, &tensors).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        let mut bad_magic = pristine.clone();
        bad_magic[0] ^= 0xFF;
        assert_eq!(parse(&bad_magic).unwrap_err(), CkptError::BadMagic);

        let mut bad_version = pristine.clone();
        bad_version[8] ^= 0xFF;
        assert!(matches!(parse(&bad_version).unwrap_err(), CkptError::BadVersion(_)));

        let mut bad_len = pristine.clone();
        bad_len[12] ^= 0xFF;
        assert_eq!(parse(&bad_len).unwrap_err(), CkptError::Truncated);

        let mut bad_body = pristine.clone();
        let last = bad_body.len() - 1;
        bad_body[last] ^= 0x01;
        assert!(matches!(parse(&bad_body).unwrap_err(), CkptError::CrcMismatch { .. }));

        // and the anyhow wrapper preserves the type for downcasting
        std::fs::write(&path, &bad_body).unwrap();
        let err = load(&path).unwrap_err();
        assert!(matches!(err.downcast_ref::<CkptError>(), Some(CkptError::CrcMismatch { .. })));
        let _ = std::fs::remove_file(path);
    }
}
