//! Binary checkpointing for [`HostParams`] + optimizer/subspace state.
//!
//! Format (little-endian):
//! ```text
//! magic  "LOTUSCKP"            8 bytes
//! version u32                  (1)
//! step    u64
//! count   u32                  number of tensors
//! per tensor: name_len u32, name bytes, rows u32, cols u32, f32 data
//! ```

use super::params::HostParams;
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"LOTUSCKP";
const VERSION: u32 = 1;

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Save params (+ any extra named tensors, e.g. optimizer moments).
pub fn save(
    path: impl AsRef<Path>,
    step: u64,
    params: &HostParams,
    extra: &[(String, &Matrix)],
) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating checkpoint {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_u32(&mut w, VERSION)?;
    write_u64(&mut w, step)?;
    write_u32(&mut w, (params.entries.len() + extra.len()) as u32)?;
    let all = params
        .entries
        .iter()
        .map(|(n, m)| (n.clone(), m))
        .chain(extra.iter().map(|(n, m)| (n.clone(), *m)));
    for (name, m) in all {
        write_u32(&mut w, name.len() as u32)?;
        w.write_all(name.as_bytes())?;
        write_u32(&mut w, m.rows as u32)?;
        write_u32(&mut w, m.cols as u32)?;
        // f32 slice → bytes
        let bytes: Vec<u8> = m.data.iter().flat_map(|x| x.to_le_bytes()).collect();
        w.write_all(&bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a checkpoint: (step, named tensors).
pub fn load(path: impl AsRef<Path>) -> Result<(u64, Vec<(String, Matrix)>)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a lotus checkpoint (bad magic)");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = read_u64(&mut r)?;
    let count = read_u32(&mut r)? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let rows = read_u32(&mut r)? as usize;
        let cols = read_u32(&mut r)? as usize;
        let mut bytes = vec![0u8; rows * cols * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push((String::from_utf8(name)?, Matrix::from_vec(rows, cols, data)));
    }
    Ok((step, tensors))
}

/// Restore params in place from a loaded tensor list (by name).
pub fn restore_params(params: &mut HostParams, tensors: &[(String, Matrix)]) -> Result<()> {
    for (name, m) in &mut params.entries {
        let found = tensors
            .iter()
            .find(|(n, _)| n == name)
            .with_context(|| format!("checkpoint missing tensor '{name}'"))?;
        if found.1.shape() != m.shape() {
            bail!("shape mismatch restoring {name}");
        }
        *m = found.1.clone();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_tiny_cfg;

    #[test]
    fn roundtrip_exact() {
        let params = HostParams::init(llama_tiny_cfg(), 3);
        let dir = std::env::temp_dir().join("lotus_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.ckpt");
        let extra_m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        save(&path, 123, &params, &[("opt.m".into(), &extra_m)]).unwrap();

        let (step, tensors) = load(&path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(tensors.len(), params.entries.len() + 1);
        let mut restored = HostParams::init(llama_tiny_cfg(), 999); // different seed
        restore_params(&mut restored, &tensors).unwrap();
        for ((_, a), (_, b)) in params.entries.iter().zip(&restored.entries) {
            assert_eq!(a, b, "bit-exact restore");
        }
        let extra_back = tensors.iter().find(|(n, _)| n == "opt.m").unwrap();
        assert_eq!(extra_back.1, extra_m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("lotus_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
