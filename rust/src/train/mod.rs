//! L3 training coordinator (PJRT path).
//!
//! The Rust side owns: the training loop, per-layer subspace state and
//! the *adaptive switching decision* (the paper's contribution runs here
//! as a first-class runtime feature — [`subspace_mgr::SubspaceManager`]),
//! data pipeline, metrics, checkpoints and ETA accounting. XLA owns the
//! math: fwd/bwd, projected Adam, rSVD refresh — all AOT artifacts
//! executed through [`crate::runtime::Engine`].

pub mod params;
pub mod subspace_mgr;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod checkpoint;
pub mod metrics;
pub mod eta;

pub use checkpoint::CkptError;
pub use params::HostParams;
pub use subspace_mgr::SubspaceManager;
#[cfg(feature = "pjrt")]
pub use trainer::{PjrtTrainer, PjrtTrainReport};
