//! Analytic FLOP/time model + ETA extrapolation — the engine behind the
//! Fig. 2 reproduction ("ETA of pre-training LLaMA-type 3B models").
//!
//! Per training step, every method pays the same fwd/bwd cost
//! (≈ 6·params·tokens FLOPs); they differ in the *update* cost:
//! full-rank Adam (elementwise), projected Adam (2 thin GEMMs +
//! elementwise), and the amortized projector-refresh cost — exact SVD
//! every T steps (GaLore), rSVD at the measured adaptive frequency
//! (Lotus), nothing (Apollo). ETAs are produced by calibrating
//! seconds-per-FLOP once on this machine (a measured GEMM) and scaling.

use crate::linalg::rsvd::{rsvd_flops, svd_flops};
use crate::models::ModelShape;

/// Wall-clock penalty for exact SVD relative to GEMM FLOPs: dense SVD is
/// sequential/low-parallelism and achieves a small fraction of GEMM
/// throughput on every backend. Measured on this testbed by
/// `benches/rsvd_speed.rs`: Jacobi SVD at d=384 runs ~2% of the GEMM
/// rate (9.3 s for ~0.8 GFLOP vs ~6 GFLOP/s), i.e. ~50× the naive FLOP
/// time; LAPACK gesdd on GPU shows the same order (this is exactly why
/// GaLore's refresh is expensive out of proportion to its FLOPs).
pub const SVD_WALL_PENALTY: f64 = 50.0;

/// Per-method update-cost model.
#[derive(Clone, Copy, Debug)]
pub enum EtaMethod {
    FullRank,
    /// refresh_every steps between exact-SVD refreshes
    GaLore { refresh_every: f64 },
    /// effective steps between rSVD refreshes (measured; adaptive)
    Lotus { refresh_every: f64, oversample: usize, power_iters: usize },
    AdaRankGrad { refresh_every: f64 },
    Apollo,
}

impl EtaMethod {
    pub fn name(&self) -> &'static str {
        match self {
            EtaMethod::FullRank => "Full Rank",
            EtaMethod::GaLore { .. } => "GaLore",
            EtaMethod::Lotus { .. } => "Lotus",
            EtaMethod::AdaRankGrad { .. } => "AdaRankGrad",
            EtaMethod::Apollo => "Apollo",
        }
    }
}

/// fwd+bwd FLOPs per step: the standard 6·N·B·T estimate.
pub fn fwdbwd_flops(params: u64, tokens_per_step: u64) -> u64 {
    6 * params * tokens_per_step
}

/// Per-step *update* FLOPs for a method over a model shape at rank r,
/// with the refresh cost amortized at its frequency.
pub fn update_flops(method: EtaMethod, shape: &ModelShape, r: usize) -> f64 {
    let mut total = 0.0f64;
    for mat in shape.matrices() {
        let (m, n) = (mat.rows, mat.cols);
        let elems = (m * n) as f64;
        if !mat.project {
            total += 10.0 * elems; // full Adam elementwise
            continue;
        }
        let long = m.max(n) as f64;
        let low_elems = r as f64 * long;
        match method {
            EtaMethod::FullRank => total += 10.0 * elems,
            EtaMethod::GaLore { refresh_every } => {
                // project down + up: 2·m·n·r MACs = 4·m·n·r FLOPs
                total += 4.0 * elems * r as f64 + 10.0 * low_elems;
                total += SVD_WALL_PENALTY * svd_flops(m, n) as f64 / refresh_every;
            }
            EtaMethod::Lotus { refresh_every, oversample, power_iters } => {
                total += 4.0 * elems * r as f64 + 10.0 * low_elems;
                total += rsvd_flops(m, n, r, oversample, power_iters) as f64 / refresh_every;
            }
            EtaMethod::AdaRankGrad { refresh_every } => {
                // rSVD refresh + shrinking average rank ≈ 0.75 r
                let r_eff = 0.75 * r as f64;
                total += 4.0 * elems * r_eff + 10.0 * (r_eff * long);
                total += rsvd_flops(m, n, (r_eff as usize).max(1), 4, 1) as f64 / refresh_every;
            }
            EtaMethod::Apollo => {
                // random projection (down only) + channel-wise scaling
                total += 2.0 * elems * r as f64 + 10.0 * low_elems + 2.0 * elems;
            }
        }
    }
    total
}

/// Calibrate seconds/FLOP with a real GEMM on this machine.
pub fn calibrate_secs_per_flop() -> f64 {
    use crate::linalg::matmul::matmul;
    use crate::tensor::Matrix;
    use crate::util::Rng;
    let mut rng = Rng::new(99);
    let n = 256;
    let a = Matrix::randn(n, n, 1.0, &mut rng);
    let b = Matrix::randn(n, n, 1.0, &mut rng);
    let t0 = std::time::Instant::now();
    let reps = 8;
    for _ in 0..reps {
        std::hint::black_box(matmul(&a, &b));
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = (reps * 2 * n * n * n) as f64;
    secs / flops
}

/// ETA in seconds to train `total_tokens` with the given per-step token
/// budget (the Fig. 2a scenario).
pub fn eta_seconds(
    method: EtaMethod,
    shape: &ModelShape,
    r: usize,
    tokens_per_step: u64,
    total_tokens: u64,
    secs_per_flop: f64,
) -> f64 {
    let steps = (total_tokens as f64 / tokens_per_step as f64).ceil();
    let per_step = fwdbwd_flops(shape.param_count(), tokens_per_step) as f64
        + update_flops(method, shape, r);
    steps * per_step * secs_per_flop
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_paper_3b;

    #[test]
    fn fig2_ordering_holds() {
        // Paper's Fig 2a: Lotus < Apollo ≈ AdaRankGrad < GaLore in ETA.
        // Update-cost ordering must reflect the SVD-vs-rSVD asymmetry.
        let shape = llama_paper_3b();
        let r = 512;
        let galore = update_flops(EtaMethod::GaLore { refresh_every: 200.0 }, &shape, r);
        let lotus = update_flops(
            EtaMethod::Lotus { refresh_every: 200.0, oversample: 8, power_iters: 1 },
            &shape,
            r,
        );
        let apollo = update_flops(EtaMethod::Apollo, &shape, r);
        assert!(lotus < galore, "lotus {lotus} < galore {galore}");
        assert!(apollo < galore, "apollo cheapest updates");
        // even when Lotus refreshes 4x more often it must stay cheaper
        let lotus_freq = update_flops(
            EtaMethod::Lotus { refresh_every: 50.0, oversample: 8, power_iters: 1 },
            &shape,
            r,
        );
        assert!(lotus_freq < galore, "{lotus_freq} vs {galore}");
    }

    #[test]
    fn eta_scales_linearly_in_tokens() {
        let shape = llama_paper_3b();
        let spf = 1e-11;
        let a = eta_seconds(EtaMethod::FullRank, &shape, 512, 1 << 16, 1 << 26, spf);
        let b = eta_seconds(EtaMethod::FullRank, &shape, 512, 1 << 16, 1 << 27, spf);
        assert!((b / a - 2.0).abs() < 0.01);
    }

    #[test]
    fn calibration_is_sane() {
        let spf = calibrate_secs_per_flop();
        // CPU GEMM lands between 0.1 and 100 GFLOP/s
        assert!(spf > 1e-12 && spf < 1e-8, "secs/flop = {spf}");
    }
}
