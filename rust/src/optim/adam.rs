//! Full-rank Adam / AdamW / SGD-with-momentum, plus an 8-bit-state Adam
//! that emulates the blockwise-quantized optimizer used in the paper's
//! Fig. 2a setup ("8-bit optimizer with layer-wise weight updates").

use super::{Hyper, OptState, Optimizer, StepEvent};
use crate::tensor::bf16::{quantize_int8_blockwise, quantize_slice};
use crate::tensor::Matrix;

/// Adam bias-correction factors at step t (1-based), f64 for accuracy.
#[inline]
pub fn bias_correction(beta1: f32, beta2: f32, t: u64) -> (f64, f64) {
    let c1 = 1.0 - (beta1 as f64).powi(t as i32);
    let c2 = 1.0 - (beta2 as f64).powi(t as i32);
    (c1, c2)
}

/// Classic Adam parameters + first/second moment state.
pub struct Adam {
    pub m: Matrix,
    pub v: Matrix,
    /// Decoupled weight decay (AdamW) if true; L2-coupled otherwise.
    pub decoupled_wd: bool,
    /// Step-direction scratch, reused every step so the update loop is
    /// allocation-free (not counted in `state_bytes`: it is scratch, not
    /// persistent optimizer state).
    dir: Matrix,
}

/// Convenience alias for constructing Adam with explicit moments.
pub struct AdamParams {
    pub rows: usize,
    pub cols: usize,
}

impl Adam {
    pub fn new(rows: usize, cols: usize) -> Self {
        Adam {
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            decoupled_wd: true,
            dir: Matrix::zeros(rows, cols),
        }
    }

    /// One fused Adam update on arbitrary buffers (shared by the
    /// low-rank optimizer which runs Adam in the projected space).
    /// Returns nothing; updates `m`, `v` and writes the *step direction*
    /// (already scaled by lr and bias corrections) into `out`.
    pub fn direction(
        m: &mut Matrix,
        v: &mut Matrix,
        g: &Matrix,
        hyper: &Hyper,
        t: u64,
        out: &mut Matrix,
    ) {
        debug_assert_eq!(m.shape(), g.shape());
        let (c1, c2) = bias_correction(hyper.beta1, hyper.beta2, t);
        let b1 = hyper.beta1;
        let b2 = hyper.beta2;
        for i in 0..g.data.len() {
            let gi = g.data[i];
            let mi = b1 * m.data[i] + (1.0 - b1) * gi;
            let vi = b2 * v.data[i] + (1.0 - b2) * gi * gi;
            m.data[i] = mi;
            v.data[i] = vi;
            let mhat = mi as f64 / c1;
            let vhat = (vi as f64 / c2).sqrt() + hyper.eps as f64;
            out.data[i] = (hyper.lr as f64 * mhat / vhat) as f32;
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        if self.decoupled_wd && hyper.weight_decay > 0.0 {
            // AdamW: w ← w(1 − lr·λ) before the Adam step
            w.scale(1.0 - hyper.lr * hyper.weight_decay);
        }
        self.dir.ensure_shape(g.rows, g.cols);
        Adam::direction(&mut self.m, &mut self.v, g, hyper, step, &mut self.dir);
        w.axpy(-1.0, &self.dir);
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn export_state(&self) -> OptState {
        OptState::Dense { m: self.m.clone(), v: self.v.clone() }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::Dense { m, v } => {
                if m.shape() != self.m.shape() || v.shape() != self.v.shape() {
                    return Err(format!(
                        "adam moment shape mismatch: have {:?}, restoring {:?}",
                        self.m.shape(),
                        m.shape()
                    ));
                }
                self.m = m;
                self.v = v;
                Ok(())
            }
            other => Err(format!("adam cannot restore '{}' state", other.kind())),
        }
    }
}

/// SGD with classical momentum (baseline / sanity optimizer).
pub struct Sgd {
    pub momentum: f32,
    buf: Matrix,
}

impl Sgd {
    pub fn new(momentum: f32, rows: usize, cols: usize) -> Self {
        Sgd { momentum, buf: Matrix::zeros(rows, cols) }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, _step: u64) -> StepEvent {
        for i in 0..g.data.len() {
            let b = self.momentum * self.buf.data[i] + g.data[i];
            self.buf.data[i] = b;
            w.data[i] -= hyper.lr * b;
        }
        if hyper.weight_decay > 0.0 {
            w.scale(1.0 - hyper.lr * hyper.weight_decay);
        }
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * std::mem::size_of::<f32>()
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn export_state(&self) -> OptState {
        OptState::Momentum { buf: self.buf.clone() }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::Momentum { buf } => {
                if buf.shape() != self.buf.shape() {
                    return Err("sgd momentum shape mismatch".into());
                }
                self.buf = buf;
                Ok(())
            }
            other => Err(format!("sgd cannot restore '{}' state", other.kind())),
        }
    }
}

/// Adam whose moments are stored blockwise-int8 (bitsandbytes-style):
/// after every update the moment buffers are quantized in place, so the
/// *numerics* seen by subsequent steps match an 8-bit store. The
/// held-state accounting reports 1 byte/element + per-block scales.
pub struct Adam8bit {
    inner: Adam,
    pub block: usize,
}

impl Adam8bit {
    pub fn new(rows: usize, cols: usize, block: usize) -> Self {
        Adam8bit { inner: Adam::new(rows, cols), block }
    }
}

impl Optimizer for Adam8bit {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        self.inner.step(w, g, hyper, step);
        quantize_int8_blockwise(&mut self.inner.m.data, self.block);
        quantize_int8_blockwise(&mut self.inner.v.data, self.block);
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        // int8 payload + f32 absmax per block, for both moments
        let n = self.inner.m.len();
        let blocks = n.div_ceil(self.block);
        2 * (n + blocks * 4)
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }

    fn export_state(&self) -> OptState {
        // moments are re-quantized in place after every step, so the
        // dequantized values stored here reproduce the 8-bit numerics
        self.inner.export_state()
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        self.inner.restore_state(state)
    }
}

/// Adam whose moments are stored bf16 (`--state-dtype bf16`): after
/// every update the moment buffers are rounded to the bf16 grid in
/// place, so subsequent steps see exactly the numerics a 2-byte store
/// would produce. Held-state accounting reports 2 bytes/element.
pub struct AdamBf16 {
    inner: Adam,
}

impl AdamBf16 {
    pub fn new(rows: usize, cols: usize) -> Self {
        AdamBf16 { inner: Adam::new(rows, cols) }
    }
}

impl Optimizer for AdamBf16 {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        self.inner.step(w, g, hyper, step);
        quantize_slice(&mut self.inner.m.data);
        quantize_slice(&mut self.inner.v.data);
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        (self.inner.m.len() + self.inner.v.len()) * 2
    }

    fn name(&self) -> &'static str {
        "adam-bf16"
    }

    fn export_state(&self) -> OptState {
        // moments are re-rounded in place after every step; bf16 values
        // round-trip through f32 exactly, so the dequantized mirror
        // stored here reproduces the 2-byte numerics bit for bit
        self.inner.export_state()
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        self.inner.restore_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_correction_limits() {
        let (c1, c2) = bias_correction(0.9, 0.999, 1);
        assert!((c1 - 0.1).abs() < 1e-6);
        assert!((c2 - 0.001).abs() < 1e-6);
        let (c1, _) = bias_correction(0.9, 0.999, 10_000);
        assert!((c1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_signed_gradient() {
        // With zero-init moments, step 1 gives ±lr (up to eps) per element.
        let mut adam = Adam::new(1, 3);
        let mut w = Matrix::zeros(1, 3);
        let g = Matrix::from_vec(1, 3, vec![0.5, -2.0, 0.0]);
        let hyper = Hyper { lr: 0.1, ..Default::default() };
        adam.step(&mut w, &g, &hyper, 1);
        assert!((w.data[0] + 0.1).abs() < 1e-3, "{}", w.data[0]);
        assert!((w.data[1] - 0.1).abs() < 1e-3);
        assert_eq!(w.data[2], 0.0);
    }

    #[test]
    fn adamw_decay_is_decoupled() {
        let mut adam = Adam::new(1, 1);
        let mut w = Matrix::from_vec(1, 1, vec![1.0]);
        let g = Matrix::zeros(1, 1);
        let hyper = Hyper { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        adam.step(&mut w, &g, &hyper, 1);
        // zero gradient → pure decay: w = 1 * (1 - 0.1*0.5)
        assert!((w.data[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn adam8bit_tracks_fp32_adam() {
        use crate::util::Rng;
        let mut rng = Rng::new(91);
        let target = Matrix::randn(8, 8, 1.0, &mut rng);
        let hyper = Hyper { lr: 0.05, ..Default::default() };
        let mut w32 = Matrix::zeros(8, 8);
        let mut w8 = Matrix::zeros(8, 8);
        let mut a32 = Adam::new(8, 8);
        let mut a8 = Adam8bit::new(8, 8, 64);
        for t in 1..=200 {
            let g32 = w32.sub(&target);
            let g8 = w8.sub(&target);
            a32.step(&mut w32, &g32, &hyper, t);
            a8.step(&mut w8, &g8, &hyper, t);
        }
        let d32 = w32.sub(&target).fro_norm();
        let d8 = w8.sub(&target).fro_norm();
        assert!(d8 < 0.2 * target.fro_norm(), "8-bit adam still converges, d8={d8}");
        assert!((d8 - d32).abs() < 0.1 * target.fro_norm());
    }

    #[test]
    fn state_bytes_accounting() {
        let a = Adam::new(10, 10);
        assert_eq!(a.state_bytes(), 2 * 100 * 4);
        let a8 = Adam8bit::new(10, 10, 64);
        assert!(a8.state_bytes() < a.state_bytes() / 2);
        let ab = AdamBf16::new(10, 10);
        assert_eq!(ab.state_bytes(), a.state_bytes() / 2);
    }

    #[test]
    fn adam_bf16_tracks_fp32_adam() {
        use crate::util::Rng;
        let mut rng = Rng::new(92);
        let target = Matrix::randn(8, 8, 1.0, &mut rng);
        let hyper = Hyper { lr: 0.05, ..Default::default() };
        let mut w32 = Matrix::zeros(8, 8);
        let mut wb = Matrix::zeros(8, 8);
        let mut a32 = Adam::new(8, 8);
        let mut ab = AdamBf16::new(8, 8);
        for t in 1..=200 {
            let g32 = w32.sub(&target);
            let gb = wb.sub(&target);
            a32.step(&mut w32, &g32, &hyper, t);
            ab.step(&mut wb, &gb, &hyper, t);
        }
        let d32 = w32.sub(&target).fro_norm();
        let db = wb.sub(&target).fro_norm();
        assert!(db < 0.2 * target.fro_norm(), "bf16-state adam converges, db={db}");
        assert!((db - d32).abs() < 0.05 * target.fro_norm());
    }
}
