//! The training-method specification — the paper's compared systems.
//!
//! One enum names every method in every trainer (sim pre-training,
//! GLUE-sim fine-tuning, the distributed engine and the PJRT
//! coordinator); the [`crate::optim::registry`] turns a `Method` into a
//! live [`crate::optim::Optimizer`]. Keeping the spec here — not in any
//! one trainer — is what lets the four entry points share a single
//! dispatch.

/// Training method specification (the paper's compared systems).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    FullRank,
    GaLore { interval: u64 },
    LowRank,
    LoRA,
    ReLoRA { merge_every: u64 },
    AdaRankGrad { interval: u64, decay: f64 },
    Apollo { refresh_every: u64 },
    Lotus { gamma: f64, eta: u64, t_min: u64 },
    /// Ablation (Table 4 row 2): rSVD projector + GaLore's fixed policy.
    RsvdFixed { interval: u64 },
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::FullRank => "Full Rank",
            Method::GaLore { .. } => "GaLore",
            Method::LowRank => "Low Rank",
            Method::LoRA => "LoRA",
            Method::ReLoRA { .. } => "ReLoRA",
            Method::AdaRankGrad { .. } => "AdaRankGrad",
            Method::Apollo { .. } => "Apollo",
            Method::Lotus { .. } => "Lotus",
            Method::RsvdFixed { .. } => "rSVD+Fixed",
        }
    }

    /// Paper-default Lotus policy.
    pub fn lotus_default() -> Method {
        Method::Lotus { gamma: 0.01, eta: 50, t_min: 50 }
    }

    /// Map to the analytic memory model's method enum — the single
    /// source of that mapping for every trainer and bench.
    pub fn memcount(&self) -> crate::memcount::Method {
        match self {
            Method::FullRank => crate::memcount::Method::FullRank,
            Method::GaLore { .. } => crate::memcount::Method::GaLore,
            Method::LowRank => crate::memcount::Method::LowRank,
            Method::LoRA => crate::memcount::Method::LoRA,
            Method::ReLoRA { .. } => crate::memcount::Method::ReLoRA,
            Method::AdaRankGrad { .. } => crate::memcount::Method::AdaRankGrad,
            Method::Apollo { .. } => crate::memcount::Method::Apollo,
            Method::Lotus { .. } | Method::RsvdFixed { .. } => crate::memcount::Method::Lotus,
        }
    }
}
