//! Typed optimizer checkpoint state.
//!
//! Every [`crate::optim::Optimizer`] exports its persistent state as one
//! [`OptState`] variant; the codec here flattens it into the named-f32
//! tensor container the checkpoint writer speaks
//! ([`crate::train::checkpoint`]), with integer counters and RNG stream
//! positions encoded as exact 16-bit limbs ([`crate::util::codec`]).
//! Restoring an exported state into a freshly constructed optimizer of
//! the same spec reproduces the original trajectory bit-for-bit — the
//! property `rust/tests/optim_matrix.rs` pins for every registered
//! method.

use crate::projection::Side;
use crate::subspace::PolicyState;
use crate::tensor::Matrix;
use crate::util::codec::{push_u64, read_u64_limbs};

/// Persistent state of one optimizer, typed per method family.
#[derive(Clone, Debug)]
pub enum OptState {
    /// No persistent state yet (stateless optimizer, or a projected
    /// optimizer before its first subspace fit).
    Empty,
    /// Dense Adam first/second moments (full-rank Adam, AdamW, 8-bit).
    Dense { m: Matrix, v: Matrix },
    /// SGD momentum buffer.
    Momentum { buf: Matrix },
    /// Projected Adam ([`crate::optim::LowRankAdam`]): basis + subspace
    /// moments + lifecycle counters + projector RNG + switching policy.
    LowRank {
        basis: Matrix,
        side: Side,
        m: Matrix,
        v: Matrix,
        rank: u64,
        life: u64,
        switches: u64,
        rng: Option<(u64, u64)>,
        policy: PolicyState,
    },
    /// AdaRankGrad ([`crate::optim::AdaRankAdam`]): the wrapped
    /// projected-Adam state plus the decay schedule's current rank. The
    /// projector RNG rides along separately because a snapshot can land
    /// between a rank retirement and the next fit, where the inner
    /// state is `Empty` but the stream has advanced.
    AdaRank { inner: Box<OptState>, current_rank: u64, rng: Option<(u64, u64)> },
    /// Plain low-rank factorization W = B·A with Adam on both factors.
    Factor { a: Matrix, b: Matrix, ma: Matrix, va: Matrix, mb: Matrix, vb: Matrix },
    /// LoRA adapters + Adam on both factors.
    Lora { a: Matrix, b: Matrix, ma: Matrix, va: Matrix, mb: Matrix, vb: Matrix },
    /// ReLoRA: LoRA plus the merge counter and the restart RNG stream.
    ReLora {
        a: Matrix,
        b: Matrix,
        ma: Matrix,
        va: Matrix,
        mb: Matrix,
        vb: Matrix,
        steps_since_merge: u64,
        rng: (u64, u64),
    },
    /// Apollo: random basis + projected moments + refresh counter + the
    /// projector's RNG stream.
    Apollo {
        basis: Matrix,
        side: Side,
        m: Matrix,
        v: Matrix,
        steps_in_proj: u64,
        rng: (u64, u64),
    },
}

fn side_flag(side: Side) -> f32 {
    match side {
        Side::Left => 0.0,
        Side::Right => 1.0,
    }
}

fn flag_side(x: f32) -> Side {
    if x == 0.0 {
        Side::Left
    } else {
        Side::Right
    }
}

/// Look up a named tensor in a loaded checkpoint list — shared by this
/// codec and the weight restorers
/// ([`crate::sim::model::Params::restore_from_tensors`]).
pub(crate) fn find_tensor<'a>(
    tensors: &'a [(String, Matrix)],
    name: &str,
) -> Result<&'a Matrix, String> {
    tensors
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, m)| m)
        .ok_or_else(|| format!("checkpoint missing tensor '{name}'"))
}

impl OptState {
    /// Short label for logs / the registry table.
    pub fn kind(&self) -> &'static str {
        match self {
            OptState::Empty => "empty",
            OptState::Dense { .. } => "dense-adam",
            OptState::Momentum { .. } => "momentum",
            OptState::LowRank { .. } => "lowrank-adam",
            OptState::AdaRank { .. } => "adarank",
            OptState::Factor { .. } => "factor",
            OptState::Lora { .. } => "lora",
            OptState::ReLora { .. } => "relora",
            OptState::Apollo { .. } => "apollo",
        }
    }

    /// Serialize as named f32 tensors under `prefix`: a `{prefix}/kind`
    /// meta row (variant id + counters/RNG as exact 16-bit limbs) plus
    /// one tensor per matrix-valued field.
    pub fn to_tensors(&self, prefix: &str, out: &mut Vec<(String, Matrix)>) {
        let meta_name = format!("{prefix}/kind");
        let mat = |leaf: &str| format!("{prefix}/{leaf}");
        match self {
            OptState::Empty => {
                out.push((meta_name, Matrix::from_vec(1, 1, vec![0.0])));
            }
            OptState::Dense { m, v } => {
                out.push((meta_name, Matrix::from_vec(1, 1, vec![1.0])));
                out.push((mat("m"), m.clone()));
                out.push((mat("v"), v.clone()));
            }
            OptState::Momentum { buf } => {
                out.push((meta_name, Matrix::from_vec(1, 1, vec![2.0])));
                out.push((mat("buf"), buf.clone()));
            }
            OptState::LowRank { basis, side, m, v, rank, life, switches, rng, policy } => {
                let mut meta = vec![3.0, side_flag(*side)];
                push_u64(&mut meta, *rank);
                push_u64(&mut meta, *life);
                push_u64(&mut meta, *switches);
                meta.push(if rng.is_some() { 1.0 } else { 0.0 });
                let (s0, s1) = rng.unwrap_or((0, 0));
                push_u64(&mut meta, s0);
                push_u64(&mut meta, s1);
                let cols = meta.len();
                out.push((meta_name, Matrix::from_vec(1, cols, meta)));
                out.push((mat("basis"), basis.clone()));
                out.push((mat("m"), m.clone()));
                out.push((mat("v"), v.clone()));
                policy.to_tensors(&mat("policy"), out);
            }
            OptState::AdaRank { inner, current_rank, rng } => {
                let mut meta = vec![4.0];
                push_u64(&mut meta, *current_rank);
                meta.push(if rng.is_some() { 1.0 } else { 0.0 });
                let (s0, s1) = rng.unwrap_or((0, 0));
                push_u64(&mut meta, s0);
                push_u64(&mut meta, s1);
                let cols = meta.len();
                out.push((meta_name, Matrix::from_vec(1, cols, meta)));
                inner.to_tensors(&mat("inner"), out);
            }
            OptState::Factor { a, b, ma, va, mb, vb }
            | OptState::Lora { a, b, ma, va, mb, vb } => {
                let id = if matches!(self, OptState::Factor { .. }) { 5.0 } else { 6.0 };
                out.push((meta_name, Matrix::from_vec(1, 1, vec![id])));
                out.push((mat("a"), a.clone()));
                out.push((mat("b"), b.clone()));
                out.push((mat("ma"), ma.clone()));
                out.push((mat("va"), va.clone()));
                out.push((mat("mb"), mb.clone()));
                out.push((mat("vb"), vb.clone()));
            }
            OptState::ReLora { a, b, ma, va, mb, vb, steps_since_merge, rng } => {
                let mut meta = vec![7.0];
                push_u64(&mut meta, *steps_since_merge);
                push_u64(&mut meta, rng.0);
                push_u64(&mut meta, rng.1);
                let cols = meta.len();
                out.push((meta_name, Matrix::from_vec(1, cols, meta)));
                out.push((mat("a"), a.clone()));
                out.push((mat("b"), b.clone()));
                out.push((mat("ma"), ma.clone()));
                out.push((mat("va"), va.clone()));
                out.push((mat("mb"), mb.clone()));
                out.push((mat("vb"), vb.clone()));
            }
            OptState::Apollo { basis, side, m, v, steps_in_proj, rng } => {
                let mut meta = vec![8.0, side_flag(*side)];
                push_u64(&mut meta, *steps_in_proj);
                push_u64(&mut meta, rng.0);
                push_u64(&mut meta, rng.1);
                let cols = meta.len();
                out.push((meta_name, Matrix::from_vec(1, cols, meta)));
                out.push((mat("basis"), basis.clone()));
                out.push((mat("m"), m.clone()));
                out.push((mat("v"), v.clone()));
            }
        }
    }

    /// Inverse of [`OptState::to_tensors`].
    pub fn from_tensors(
        prefix: &str,
        tensors: &[(String, Matrix)],
    ) -> Result<OptState, String> {
        let mat = |leaf: &str| find_tensor(tensors, &format!("{prefix}/{leaf}")).cloned();
        let meta = find_tensor(tensors, &format!("{prefix}/kind"))?;
        match meta.data[0] as i64 {
            0 => Ok(OptState::Empty),
            1 => Ok(OptState::Dense { m: mat("m")?, v: mat("v")? }),
            2 => Ok(OptState::Momentum { buf: mat("buf")? }),
            3 => {
                let rng = if meta.data[14] != 0.0 {
                    Some((read_u64_limbs(&meta.data, 15), read_u64_limbs(&meta.data, 19)))
                } else {
                    None
                };
                Ok(OptState::LowRank {
                    basis: mat("basis")?,
                    side: flag_side(meta.data[1]),
                    m: mat("m")?,
                    v: mat("v")?,
                    rank: read_u64_limbs(&meta.data, 2),
                    life: read_u64_limbs(&meta.data, 6),
                    switches: read_u64_limbs(&meta.data, 10),
                    rng,
                    policy: PolicyState::from_tensors(&format!("{prefix}/policy"), tensors)?,
                })
            }
            4 => {
                let rng = if meta.data[5] != 0.0 {
                    Some((read_u64_limbs(&meta.data, 6), read_u64_limbs(&meta.data, 10)))
                } else {
                    None
                };
                Ok(OptState::AdaRank {
                    inner: Box::new(OptState::from_tensors(
                        &format!("{prefix}/inner"),
                        tensors,
                    )?),
                    current_rank: read_u64_limbs(&meta.data, 1),
                    rng,
                })
            }
            5 => Ok(OptState::Factor {
                a: mat("a")?,
                b: mat("b")?,
                ma: mat("ma")?,
                va: mat("va")?,
                mb: mat("mb")?,
                vb: mat("vb")?,
            }),
            6 => Ok(OptState::Lora {
                a: mat("a")?,
                b: mat("b")?,
                ma: mat("ma")?,
                va: mat("va")?,
                mb: mat("mb")?,
                vb: mat("vb")?,
            }),
            7 => Ok(OptState::ReLora {
                a: mat("a")?,
                b: mat("b")?,
                ma: mat("ma")?,
                va: mat("va")?,
                mb: mat("mb")?,
                vb: mat("vb")?,
                steps_since_merge: read_u64_limbs(&meta.data, 1),
                rng: (read_u64_limbs(&meta.data, 5), read_u64_limbs(&meta.data, 9)),
            }),
            8 => Ok(OptState::Apollo {
                basis: mat("basis")?,
                side: flag_side(meta.data[1]),
                m: mat("m")?,
                v: mat("v")?,
                steps_in_proj: read_u64_limbs(&meta.data, 2),
                rng: (read_u64_limbs(&meta.data, 6), read_u64_limbs(&meta.data, 10)),
            }),
            k => Err(format!("unknown optimizer state kind {k} at '{prefix}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_state_tensor_roundtrip() {
        let s = OptState::Dense {
            m: Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]),
            v: Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]),
        };
        let mut out = Vec::new();
        s.to_tensors("opt/m0", &mut out);
        let back = OptState::from_tensors("opt/m0", &out).unwrap();
        match back {
            OptState::Dense { m, v } => {
                assert_eq!(m.data, vec![1.0, 2.0, 3.0, 4.0]);
                assert_eq!(v.data, vec![5.0, 6.0, 7.0, 8.0]);
            }
            other => panic!("wrong variant: {}", other.kind()),
        }
    }

    #[test]
    fn nested_adarank_state_roundtrips() {
        let inner = OptState::LowRank {
            basis: Matrix::from_vec(2, 1, vec![1.0, 0.0]),
            side: Side::Right,
            m: Matrix::from_vec(2, 1, vec![0.1, 0.2]),
            v: Matrix::from_vec(2, 1, vec![0.3, 0.4]),
            rank: 1,
            life: 70_000,
            switches: 3,
            rng: Some((u64::MAX - 5, 12345)),
            policy: crate::subspace::PolicyState::Fixed { last_switch: 99 },
        };
        let s = OptState::AdaRank {
            inner: Box::new(inner),
            current_rank: 12,
            rng: Some((7, 0xFFFF_0001)),
        };
        let mut out = Vec::new();
        s.to_tensors("p", &mut out);
        let back = OptState::from_tensors("p", &out).unwrap();
        match back {
            OptState::AdaRank { inner, current_rank, rng } => {
                assert_eq!(current_rank, 12);
                assert_eq!(rng, Some((7, 0xFFFF_0001)));
                match *inner {
                    OptState::LowRank { side, rank, life, switches, rng, .. } => {
                        assert_eq!(side, Side::Right);
                        assert_eq!(rank, 1);
                        assert_eq!(life, 70_000);
                        assert_eq!(switches, 3);
                        assert_eq!(rng, Some((u64::MAX - 5, 12345)));
                    }
                    other => panic!("wrong inner variant: {}", other.kind()),
                }
            }
            other => panic!("wrong variant: {}", other.kind()),
        }
    }

    #[test]
    fn missing_tensor_is_reported() {
        let s = OptState::Momentum { buf: Matrix::zeros(2, 2) };
        let mut out = Vec::new();
        s.to_tensors("x", &mut out);
        out.retain(|(n, _)| n != "x/buf");
        let err = OptState::from_tensors("x", &out).unwrap_err();
        assert!(err.contains("x/buf"), "{err}");
    }
}
