//! Adapter-based baselines from Tables 1–2: LoRA, ReLoRA and the plain
//! low-rank weight factorization ("Low Rank" row of Table 1).
//!
//! LoRA freezes W₀ and trains W = W₀ + (α/r)·B A with B ∈ ℝ^{m×r},
//! A ∈ ℝ^{r×n}. Given the full-rank gradient G = ∂L/∂W the chain rule
//! yields ∂L/∂B = G Aᵀ and ∂L/∂A = Bᵀ G, so the simulator can train
//! adapters from exactly the same gradient stream the other methods see.
//! ReLoRA additionally merges BA into W₀ every `merge_every` steps and
//! restarts the adapter (high-rank updates through low-rank pieces).

use super::adam::Adam;
use super::{Hyper, OptState, Optimizer, StepEvent};
use crate::linalg::matmul::{matmul, matmul_nt, matmul_tn};
use crate::tensor::Matrix;
use crate::util::Rng;

/// LoRA adapter pair with Adam state on both factors.
pub struct LoRALayer {
    pub a: Matrix, // r×n, gaussian init
    pub b: Matrix, // m×r, zero init (so W starts at W₀)
    pub alpha: f32,
    adam_a: Adam,
    adam_b: Adam,
}

impl LoRALayer {
    pub fn new(m: usize, n: usize, rank: usize, alpha: f32, rng: &mut Rng) -> Self {
        LoRALayer {
            a: Matrix::randn(rank, n, (1.0 / rank as f32).sqrt(), rng),
            b: Matrix::zeros(m, rank),
            alpha,
            adam_a: Adam::new(rank, n),
            adam_b: Adam::new(m, rank),
        }
    }

    pub fn rank(&self) -> usize {
        self.a.rows
    }

    /// Adapter contribution (α/r)·B A.
    pub fn delta(&self) -> Matrix {
        let mut d = matmul(&self.b, &self.a);
        d.scale(self.alpha / self.rank() as f32);
        d
    }

    /// Effective weight W₀ + ΔW.
    pub fn effective(&self, w0: &Matrix) -> Matrix {
        w0.add(&self.delta())
    }

    /// Train the adapters from the full-rank gradient G = ∂L/∂W.
    pub fn adapter_step(&mut self, g: &Matrix, hyper: &Hyper, step: u64) {
        let s = self.alpha / self.rank() as f32;
        // ∂L/∂B = s·G Aᵀ ; ∂L/∂A = s·Bᵀ G
        let mut gb = matmul_nt(g, &self.a);
        gb.scale(s);
        let mut ga = matmul_tn(&self.b, g);
        ga.scale(s);
        let mut dir_b = Matrix::zeros(gb.rows, gb.cols);
        let mut dir_a = Matrix::zeros(ga.rows, ga.cols);
        Adam::direction(&mut self.adam_b.m, &mut self.adam_b.v, &gb, hyper, step, &mut dir_b);
        Adam::direction(&mut self.adam_a.m, &mut self.adam_a.v, &ga, hyper, step, &mut dir_a);
        self.b.axpy(-1.0, &dir_b);
        self.a.axpy(-1.0, &dir_a);
    }
}

impl LoRALayer {
    /// Shared adapter state export (LoRA owns no extra counters; ReLoRA
    /// wraps this with its merge counter + restart RNG).
    fn factor_state(&self) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
        (
            self.a.clone(),
            self.b.clone(),
            self.adam_a.m.clone(),
            self.adam_a.v.clone(),
            self.adam_b.m.clone(),
            self.adam_b.v.clone(),
        )
    }

    fn restore_factors(
        &mut self,
        a: Matrix,
        b: Matrix,
        ma: Matrix,
        va: Matrix,
        mb: Matrix,
        vb: Matrix,
    ) -> Result<(), String> {
        if a.shape() != self.a.shape() || b.shape() != self.b.shape() {
            return Err(format!(
                "adapter shape mismatch: have A{:?}/B{:?}, restoring A{:?}/B{:?}",
                self.a.shape(),
                self.b.shape(),
                a.shape(),
                b.shape()
            ));
        }
        self.a = a;
        self.b = b;
        self.adam_a.m = ma;
        self.adam_a.v = va;
        self.adam_b.m = mb;
        self.adam_b.v = vb;
        Ok(())
    }
}

impl Optimizer for LoRALayer {
    /// `w` is treated as the *effective* weight: recomputed from the
    /// internally tracked base after each adapter step. The simulator
    /// passes the frozen base in at construction by splitting: here we
    /// reconstruct via w − delta(before) + delta(after) to avoid storing
    /// W₀ twice.
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        let before = self.delta();
        self.adapter_step(g, hyper, step);
        let after = self.delta();
        // w ← w − before + after
        w.axpy(-1.0, &before);
        w.axpy(1.0, &after);
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        // adapters are trainable params, but they also carry Adam state
        4 * (self.a.len() + self.b.len()) // moments m+v for both factors
            * 2
    }

    fn name(&self) -> &'static str {
        "lora"
    }

    fn export_state(&self) -> OptState {
        let (a, b, ma, va, mb, vb) = self.factor_state();
        OptState::Lora { a, b, ma, va, mb, vb }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::Lora { a, b, ma, va, mb, vb } => {
                self.restore_factors(a, b, ma, va, mb, vb)
            }
            other => Err(format!("lora cannot restore '{}' state", other.kind())),
        }
    }
}

/// ReLoRA: LoRA with periodic merge-and-restart.
pub struct ReLoRALayer {
    pub inner: LoRALayer,
    pub merge_every: u64,
    steps_since_merge: u64,
    rng: Rng,
}

impl ReLoRALayer {
    pub fn new(
        m: usize,
        n: usize,
        rank: usize,
        alpha: f32,
        merge_every: u64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        ReLoRALayer {
            inner: LoRALayer::new(m, n, rank, alpha, &mut rng),
            merge_every,
            steps_since_merge: 0,
            rng,
        }
    }

    /// Merge the adapter into the base (represented by the effective
    /// weight) and restart: B←0, A←fresh gaussian, reset Adam state.
    fn restart(&mut self) {
        let (m, r) = self.inner.b.shape();
        let (_, n) = self.inner.a.shape();
        self.inner.b = Matrix::zeros(m, r);
        self.inner.a = Matrix::randn(r, n, (1.0 / r as f32).sqrt(), &mut self.rng);
        self.inner.adam_a = Adam::new(r, n);
        self.inner.adam_b = Adam::new(m, r);
    }
}

impl Optimizer for ReLoRALayer {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        self.inner.step(w, g, hyper, step);
        self.steps_since_merge += 1;
        if self.steps_since_merge >= self.merge_every {
            // effective weight already contains the adapter contribution;
            // merging = resetting the adapter to zero-delta
            let lived = self.steps_since_merge;
            self.restart();
            self.steps_since_merge = 0;
            return StepEvent::Merged { lifetime: lived };
        }
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn name(&self) -> &'static str {
        "relora"
    }

    fn export_state(&self) -> OptState {
        let (a, b, ma, va, mb, vb) = self.inner.factor_state();
        OptState::ReLora {
            a,
            b,
            ma,
            va,
            mb,
            vb,
            steps_since_merge: self.steps_since_merge,
            rng: self.rng.state(),
        }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::ReLora { a, b, ma, va, mb, vb, steps_since_merge, rng } => {
                self.inner.restore_factors(a, b, ma, va, mb, vb)?;
                self.steps_since_merge = steps_since_merge;
                // the restart RNG must resume exactly, or the first
                // post-resume merge re-seeds A differently
                self.rng = Rng::from_state(rng.0, rng.1);
                Ok(())
            }
            other => Err(format!("relora cannot restore '{}' state", other.kind())),
        }
    }
}

/// The "Low Rank" row of Table 1: the weight itself is a product W = B A
/// (no frozen base), trained directly. Known to underperform badly at
/// scale — reproduced here as a baseline.
pub struct LowRankFactor {
    pub a: Matrix,
    pub b: Matrix,
    adam_a: Adam,
    adam_b: Adam,
}

impl LowRankFactor {
    pub fn new(m: usize, n: usize, rank: usize, rng: &mut Rng) -> Self {
        LowRankFactor {
            a: Matrix::randn(rank, n, (1.0 / rank as f32).sqrt(), rng),
            b: Matrix::randn(m, rank, (1.0 / m as f32).sqrt(), rng),
            adam_a: Adam::new(rank, n),
            adam_b: Adam::new(m, rank),
        }
    }

    pub fn effective(&self) -> Matrix {
        matmul(&self.b, &self.a)
    }
}

impl Optimizer for LowRankFactor {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        let gb = matmul_nt(g, &self.a);
        let ga = matmul_tn(&self.b, g);
        let mut dir_b = Matrix::zeros(gb.rows, gb.cols);
        let mut dir_a = Matrix::zeros(ga.rows, ga.cols);
        Adam::direction(&mut self.adam_b.m, &mut self.adam_b.v, &gb, hyper, step, &mut dir_b);
        Adam::direction(&mut self.adam_a.m, &mut self.adam_a.v, &ga, hyper, step, &mut dir_a);
        self.b.axpy(-1.0, &dir_b);
        self.a.axpy(-1.0, &dir_a);
        *w = self.effective();
        StepEvent::None
    }

    fn state_bytes(&self) -> usize {
        4 * (self.a.len() + self.b.len()) * 2
    }

    fn name(&self) -> &'static str {
        "lowrank-factor"
    }

    fn export_state(&self) -> OptState {
        OptState::Factor {
            a: self.a.clone(),
            b: self.b.clone(),
            ma: self.adam_a.m.clone(),
            va: self.adam_a.v.clone(),
            mb: self.adam_b.m.clone(),
            vb: self.adam_b.v.clone(),
        }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::Factor { a, b, ma, va, mb, vb } => {
                if a.shape() != self.a.shape() || b.shape() != self.b.shape() {
                    return Err("factor shape mismatch".into());
                }
                self.a = a;
                self.b = b;
                self.adam_a.m = ma;
                self.adam_a.v = va;
                self.adam_b.m = mb;
                self.adam_b.v = vb;
                Ok(())
            }
            other => Err(format!("lowrank-factor cannot restore '{}' state", other.kind())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lora_starts_at_base() {
        let mut rng = Rng::new(101);
        let l = LoRALayer::new(8, 12, 2, 8.0, &mut rng);
        // B = 0 ⇒ delta = 0
        assert_eq!(l.delta().fro_norm(), 0.0);
    }

    #[test]
    fn lora_reduces_quadratic_within_its_capacity() {
        let mut rng = Rng::new(102);
        // rank-2 target so the adapter has enough capacity
        let bt = Matrix::randn(10, 2, 1.0, &mut rng);
        let at = Matrix::randn(2, 14, 1.0, &mut rng);
        let target = matmul(&bt, &at);
        let w0 = Matrix::zeros(10, 14);
        let mut l = LoRALayer::new(10, 14, 4, 4.0, &mut rng);
        let hyper = Hyper { lr: 0.02, ..Default::default() };
        let mut w = w0.clone();
        for t in 1..=800 {
            let g = l.effective(&w0).sub(&target);
            l.step(&mut w, &g, &hyper, t);
        }
        let rel = l.effective(&w0).sub(&target).fro_norm() / target.fro_norm();
        assert!(rel < 0.1, "rel={rel}");
    }

    #[test]
    fn lora_step_keeps_w_equal_to_effective() {
        let mut rng = Rng::new(103);
        let w0 = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut l = LoRALayer::new(6, 9, 2, 2.0, &mut rng);
        let mut w = w0.clone();
        let hyper = Hyper::default();
        for t in 1..=10 {
            let g = Matrix::randn(6, 9, 1.0, &mut rng);
            l.step(&mut w, &g, &hyper, t);
            let expect = l.effective(&w0);
            let err = w.sub(&expect).fro_norm();
            assert!(err < 1e-4, "drift {err} at step {t}");
        }
    }

    #[test]
    fn relora_restarts_preserve_effective_weight() {
        let mut rl = ReLoRALayer::new(6, 9, 2, 2.0, 5, 104);
        let mut rng = Rng::new(105);
        let w0 = Matrix::randn(6, 9, 1.0, &mut rng);
        let mut w = w0.clone();
        let hyper = Hyper::default();
        for t in 1..=5 {
            let g = Matrix::randn(6, 9, 1.0, &mut rng);
            rl.step(&mut w, &g, &hyper, t);
        }
        // just after merge the adapter delta is zero again
        assert!(rl.inner.delta().fro_norm() < 1e-6);
        // and the accumulated update is retained in w (w ≠ w0)
        assert!(w.sub(&w0).fro_norm() > 1e-3);
    }

    #[test]
    fn lowrank_factor_tracks_effective() {
        let mut rng = Rng::new(106);
        let mut f = LowRankFactor::new(5, 7, 2, &mut rng);
        let mut w = f.effective();
        let hyper = Hyper::default();
        let g = Matrix::randn(5, 7, 1.0, &mut rng);
        f.step(&mut w, &g, &hyper, 1);
        assert!(w.sub(&f.effective()).fro_norm() < 1e-6);
    }
}
