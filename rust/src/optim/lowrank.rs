//! The projected low-rank Adam shared by GaLore and Lotus.
//!
//! Per layer: keep a [`Projection`] P and run Adam *in the subspace* —
//! moments are r×n (or m×r) instead of m×n. Each step:
//!
//! ```text
//! R      = down(G)                 (project the fresh full-rank gradient)
//! dir    = Adam(R)                 (moments live in the subspace)
//! ΔW     = −scale · up(dir)        (lift back; GaLore's α)
//! ```
//!
//! The *policy* decides when P is re-fit ([`crate::subspace`]); the
//! *projector* decides how (exact SVD = GaLore, rSVD = Lotus, Gaussian =
//! Flora-like). This struct is therefore the single code path for three
//! of the paper's methods — exactly how the paper frames Lotus ("simply
//! modifying the projection process").
//!
//! On every subspace switch the Adam moments are reset to zero in the
//! new subspace (GaLore's behaviour; the moment geometry is
//! basis-dependent and stale moments point nowhere meaningful).

use super::adam::Adam;
use super::{Hyper, OptState, Optimizer, ProjectedGradient, StepEvent};
use crate::projection::{Projection, Projector, Side};
use crate::quant::MomentQuant;
use crate::subspace::{Decision, Observation, SwitchPolicy, SwitchReason};
use crate::telemetry::{diag, span, ProbeSample, ProbeState, SpanKind};
use crate::tensor::Matrix;

/// Projected Adam with pluggable projector + switching policy.
///
/// The steady-state step is fused and allocation-free: the gradient is
/// down-projected **once** into a persistent scratch buffer (shared by
/// the policy observation and the moment update), the Adam direction is
/// written into a second persistent buffer, and the lifted update is
/// accumulated straight into the weight via [`Projection::up_axpy`] —
/// the low-rank gradient is never materialized twice and the full-rank
/// direction never materialized at all. The counting-allocator test in
/// `rust/tests/alloc_steady.rs` pins this down.
pub struct LowRankAdam {
    pub rank: usize,
    projector: Box<dyn Projector>,
    policy: Box<dyn SwitchPolicy>,
    proj: Option<Projection>,
    m: Matrix,
    v: Matrix,
    /// Persistent scratch: the current low-rank gradient.
    low: Matrix,
    /// Persistent scratch: the Adam step direction in the subspace.
    dir: Matrix,
    /// Steps the current subspace has lived.
    life: u64,
    /// Count of subspaces instantiated.
    pub switches: u64,
    /// Last diagnostic from the policy (‖d̄‖ or ρ).
    pub last_diag: Option<f64>,
    /// The projector's RNG position at construction — restoring a
    /// pre-fit ([`OptState::Empty`]) snapshot rewinds the stream here,
    /// so a rollback on an already-stepped optimizer is exact.
    rng0: Option<(u64, u64)>,
    /// `--state-dtype`: when set, the subspace moments are snapped to
    /// the bf16/int8 grid after every update, so the live state carries
    /// only the quantized information (bitsandbytes-style numerics).
    moment_quant: Option<MomentQuant>,
    /// Subspace-quality probe accumulator (`telemetry::diag`). Plain
    /// scalars observed on sampled steps only; not checkpointed —
    /// diagnostics, never part of the arithmetic contract.
    probe: ProbeState,
}

impl LowRankAdam {
    pub fn new(rank: usize, projector: Box<dyn Projector>, policy: Box<dyn SwitchPolicy>) -> Self {
        let rng0 = projector.rng_state();
        LowRankAdam {
            rank,
            projector,
            policy,
            proj: None,
            m: Matrix::zeros(0, 0),
            v: Matrix::zeros(0, 0),
            low: Matrix::zeros(0, 0),
            dir: Matrix::zeros(0, 0),
            life: 0,
            switches: 0,
            last_diag: None,
            rng0,
            moment_quant: None,
            probe: ProbeState::default(),
        }
    }

    /// Builder: store the subspace Adam moments on a quantized grid
    /// (None keeps the bit-exact f32 path).
    pub fn with_moment_quant(mut self, q: Option<MomentQuant>) -> Self {
        self.moment_quant = q;
        self
    }

    /// Snap the live moments to the configured grid (no-op at f32).
    #[inline]
    fn quantize_moments(&mut self) {
        if let Some(q) = self.moment_quant {
            q.apply(&mut self.m.data);
            q.apply(&mut self.v.data);
        }
    }

    /// The live projection (None before the first step).
    pub fn projection(&self) -> Option<&Projection> {
        self.proj.as_ref()
    }

    /// Retarget the optimizer to a new rank: the current subspace is
    /// retired (the next step or [`LowRankAdam::refit_from`] fits at the
    /// new rank) while the projector — including its RNG stream — is
    /// kept. AdaRankGrad's decay schedule drives this
    /// ([`super::AdaRankAdam`]).
    pub fn set_rank(&mut self, rank: usize) {
        assert!(rank > 0, "rank must be positive");
        self.rank = rank;
        self.proj = None;
    }

    /// Re-fit the subspace; leaves `self.low` holding the gradient
    /// projected into the *new* subspace (so the caller never projects
    /// twice in one step).
    fn refit(&mut self, g: &Matrix, step: u64) {
        let _sp = span(SpanKind::RsvdRefresh);
        let proj = self.projector.fit(g, self.rank);
        proj.down_into(g, &mut self.low);
        self.m.reset_to(self.low.rows, self.low.cols);
        self.v.reset_to(self.low.rows, self.low.cols);
        self.policy.reset(&self.low, step);
        self.proj = Some(proj);
        self.life = 0;
        self.switches += 1;
    }

    /// Re-fit the subspace from an externally supplied full-rank
    /// gradient — the distributed runtime's consensus refresh hands in
    /// the *all-reduced* gradient here so every replica fits the same
    /// basis ([`crate::dist`]). Moments are reset in the new subspace and
    /// the internal policy is re-seeded from the newly projected
    /// gradient.
    pub fn refit_from(&mut self, g: &Matrix, step: u64) {
        self.refit(g, step);
    }

    /// One step from an externally reduced *low-rank* gradient (the
    /// subspace must already be fitted): Adam in the subspace + fused
    /// lift, skipping both the down-projection and the internal
    /// switching policy — in data-parallel training those belong to the
    /// runtime (`crate::dist`), which reduces per-shard projections and
    /// decides switches by consensus.
    pub fn step_preprojected(&mut self, w: &mut Matrix, low: &Matrix, hyper: &Hyper, step: u64) {
        assert!(self.proj.is_some(), "step_preprojected before subspace fit");
        assert_eq!(
            low.shape(),
            self.m.shape(),
            "low-rank gradient shape does not match the fitted subspace"
        );
        self.dir.ensure_shape(low.rows, low.cols);
        {
            let _sp = span(SpanKind::OptStep);
            Adam::direction(&mut self.m, &mut self.v, low, hyper, step, &mut self.dir);
        }
        self.quantize_moments();
        let proj = self.proj.as_ref().unwrap();
        if hyper.weight_decay > 0.0 {
            w.scale(1.0 - hyper.lr * hyper.weight_decay);
        }
        let _sp = span(SpanKind::Lift);
        proj.up_axpy(&self.dir, -hyper.galore_scale, w);
        self.life += 1;
    }

    /// The projector's RNG stream position (None for deterministic
    /// projectors) — checkpointed so a resumed run's next refresh fits
    /// the same basis as the uninterrupted one.
    pub fn projector_rng_state(&self) -> Option<(u64, u64)> {
        self.projector.rng_state()
    }

    /// Restore a [`LowRankAdam::projector_rng_state`] snapshot.
    pub fn restore_projector_rng(&mut self, state: (u64, u64)) {
        self.projector.set_rng_state(state);
    }
}

impl Optimizer for LowRankAdam {
    /// One training step; reports whether the subspace was switched
    /// (the switch uses the *current* gradient, then the step proceeds
    /// in the new subspace — matching GaLore's reference implementation).
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        let mut event = StepEvent::None;

        if self.proj.is_none() {
            // refit projects g into self.low under the fresh subspace
            self.refit(g, step);
            event = StepEvent::Switched {
                reason: SwitchReason::Init,
                lifetime: 0,
                rank: self.rank,
            };
        } else {
            // Observe the projected gradient under the current subspace.
            let proj_sp = span(SpanKind::Project);
            let proj = self.proj.as_ref().unwrap();
            proj.down_into(g, &mut self.low);
            drop(proj_sp);
            match self.policy.observe(&Observation { low_grad: &self.low, step }) {
                Decision::Keep => {}
                Decision::Switch(reason) => {
                    let lived = self.life;
                    // re-projects g into self.low under the new subspace
                    self.refit(g, step);
                    event = StepEvent::Switched { reason, lifetime: lived, rank: self.rank };
                }
            }
            self.last_diag = self.policy.diagnostic();
        }

        // Subspace-quality probe: `self.low` holds PᵀG under the subspace
        // active after any switch above, and `g` is untouched — both norms
        // are read-only f64 reductions, so the probe is allocation-free
        // and never perturbs the update. Disabled cost: one relaxed load.
        if diag::probe_step(step) {
            let _sp = span(SpanKind::Probe);
            self.probe.observe(g.fro_norm_sq(), self.low.fro_norm_sq());
        }

        self.dir.ensure_shape(self.low.rows, self.low.cols);
        {
            let _sp = span(SpanKind::OptStep);
            Adam::direction(&mut self.m, &mut self.v, &self.low, hyper, step, &mut self.dir);
        }
        self.quantize_moments();
        let proj = self.proj.as_ref().unwrap();
        if hyper.weight_decay > 0.0 {
            w.scale(1.0 - hyper.lr * hyper.weight_decay);
        }
        // fused lift-and-apply: w += (−α) · up(dir), no full-rank temporary
        let _sp = span(SpanKind::Lift);
        proj.up_axpy(&self.dir, -hyper.galore_scale, w);
        self.life += 1;
        event
    }

    fn state_bytes(&self) -> usize {
        let moments = match self.moment_quant {
            None => (self.m.len() + self.v.len()) * 4,
            Some(q) => q.state_bytes(self.m.len()) + q.state_bytes(self.v.len()),
        };
        let basis = self.proj.as_ref().map(|p| p.basis.len() * 4).unwrap_or(0);
        moments + basis
    }

    fn name(&self) -> &'static str {
        "lowrank-adam"
    }

    fn diagnostic(&self) -> Option<f64> {
        self.last_diag
    }

    fn export_state(&self) -> OptState {
        match &self.proj {
            None => OptState::Empty,
            Some(p) => OptState::LowRank {
                basis: p.basis.clone(),
                side: p.side,
                m: self.m.clone(),
                v: self.v.clone(),
                rank: self.rank as u64,
                life: self.life,
                switches: self.switches,
                rng: self.projector.rng_state(),
                policy: self.policy.export_state(),
            },
        }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            // a pre-fit snapshot: rewind to the just-constructed state
            // (restoring is a rollback — the target may have stepped).
            // Stale policy internals are harmless: the policy is only
            // observed after a fit, and every fit resets it first.
            OptState::Empty => {
                self.proj = None;
                self.m = Matrix::zeros(0, 0);
                self.v = Matrix::zeros(0, 0);
                self.life = 0;
                self.switches = 0;
                self.last_diag = None;
                if let Some(s) = self.rng0 {
                    self.projector.set_rng_state(s);
                }
                Ok(())
            }
            OptState::LowRank { basis, side, m, v, rank, life, switches, rng, policy } => {
                if m.shape() != v.shape() {
                    return Err("moment shapes must match".into());
                }
                let r = rank as usize;
                if basis.cols != r {
                    return Err(format!(
                        "snapshot basis has {} columns but records rank {r}",
                        basis.cols
                    ));
                }
                let low_rank_dim = match side {
                    Side::Left => m.rows,
                    Side::Right => m.cols,
                };
                if low_rank_dim != r {
                    return Err(format!(
                        "snapshot moments ({}x{}) do not match rank {r} on side {side:?}",
                        m.rows, m.cols
                    ));
                }
                self.rank = r;
                self.proj = Some(Projection { basis, side });
                self.m = m;
                self.v = v;
                self.life = life;
                self.switches = switches;
                if let Some(s) = rng {
                    self.projector.set_rng_state(s);
                }
                self.policy.restore_state(policy)?;
                self.last_diag = None;
                Ok(())
            }
            other => Err(format!("lowrank-adam cannot restore '{}' state", other.kind())),
        }
    }

    fn projected(&mut self) -> Option<&mut dyn ProjectedGradient> {
        Some(self)
    }

    fn probe_sample(&self) -> Option<ProbeSample> {
        let margin = match (self.policy.diagnostic(), self.policy.threshold()) {
            (Some(d), Some(t)) => Some(d - t),
            _ => None,
        };
        self.probe.sample(self.life, self.rank, margin)
    }
}

impl ProjectedGradient for LowRankAdam {
    fn projection(&self) -> Option<&Projection> {
        self.proj.as_ref()
    }

    fn refit_from(&mut self, g: &Matrix, step: u64) {
        LowRankAdam::refit_from(self, g, step);
    }

    fn step_preprojected(&mut self, w: &mut Matrix, low: &Matrix, hyper: &Hyper, step: u64) {
        LowRankAdam::step_preprojected(self, w, low, hyper, step);
    }

    fn projector_rng_state(&self) -> Option<(u64, u64)> {
        LowRankAdam::projector_rng_state(self)
    }

    fn restore_projector_rng(&mut self, state: (u64, u64)) {
        LowRankAdam::restore_projector_rng(self, state);
    }
}

/// Convenience constructors for the paper's named methods.
pub mod presets {
    use super::*;
    use crate::projection::{GaussianProjector, RandSvdProjector, SvdProjector};
    use crate::subspace::{FixedInterval, LotusAdaSS};

    /// GaLore: exact SVD + fixed interval (paper default T=200 for
    /// pre-training, ~500 in the GLUE runs; pass what the experiment
    /// needs).
    pub fn galore(rank: usize, interval: u64) -> LowRankAdam {
        LowRankAdam::new(rank, Box::new(SvdProjector), Box::new(FixedInterval::new(interval)))
    }

    /// Lotus: rSVD + adaptive displacement switching.
    pub fn lotus(rank: usize, gamma: f64, eta: u64, t_min: u64, seed: u64) -> LowRankAdam {
        LowRankAdam::new(
            rank,
            Box::new(RandSvdProjector::new(seed)),
            Box::new(LotusAdaSS::new(gamma, eta, t_min)),
        )
    }

    /// Ablation row 2 of Table 4: rSVD but GaLore's fixed switching.
    pub fn rsvd_fixed(rank: usize, interval: u64, seed: u64) -> LowRankAdam {
        LowRankAdam::new(
            rank,
            Box::new(RandSvdProjector::new(seed)),
            Box::new(FixedInterval::new(interval)),
        )
    }

    /// Flora-like: Gaussian random projection + fixed interval.
    pub fn flora(rank: usize, interval: u64, seed: u64) -> LowRankAdam {
        LowRankAdam::new(
            rank,
            Box::new(GaussianProjector::new(seed)),
            Box::new(FixedInterval::new(interval)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;
    use crate::util::Rng;

    fn quadratic_run(mut opt: LowRankAdam, steps: usize) -> (f32, u64) {
        let mut rng = Rng::new(95);
        let target = Matrix::randn(24, 48, 1.0, &mut rng);
        let mut w = Matrix::zeros(24, 48);
        let hyper = Hyper { lr: 0.05, galore_scale: 1.0, ..Default::default() };
        for t in 1..=steps {
            let g = w.sub(&target);
            opt.step(&mut w, &g, &hyper, t as u64);
        }
        (w.sub(&target).fro_norm() / target.fro_norm(), opt.switches)
    }

    #[test]
    fn galore_reduces_quadratic() {
        // A full-rank quadratic can't be solved inside one rank-8 subspace;
        // with periodic switching the error must keep shrinking.
        let (rel, switches) = quadratic_run(presets::galore(8, 50), 600);
        assert!(rel < 0.35, "rel={rel}");
        assert!(switches >= 12, "switched {switches} times");
    }

    #[test]
    fn lotus_reduces_quadratic_with_fewer_constraints() {
        let (rel, switches) = quadratic_run(presets::lotus(8, 0.01, 10, 10, 7), 600);
        assert!(rel < 0.35, "rel={rel}");
        assert!(switches >= 2, "adaptive switching must engage, got {switches}");
    }

    #[test]
    fn first_step_initializes_subspace() {
        let mut opt = presets::lotus(4, 0.01, 10, 10, 8);
        let mut rng = Rng::new(96);
        let mut w = Matrix::randn(8, 16, 1.0, &mut rng);
        let g = Matrix::randn(8, 16, 1.0, &mut rng);
        let ev = opt.step(&mut w, &g, &Hyper::default(), 1);
        assert_eq!(
            ev,
            StepEvent::Switched { reason: SwitchReason::Init, lifetime: 0, rank: 4 }
        );
        assert!(opt.projection().is_some());
        assert_eq!(opt.projection().unwrap().rank(), 4);
    }

    #[test]
    fn moments_reset_on_switch() {
        let mut opt = presets::galore(4, 5);
        let mut rng = Rng::new(97);
        let mut w = Matrix::randn(8, 16, 1.0, &mut rng);
        let hyper = Hyper::default();
        for t in 1..=5 {
            let g = Matrix::randn(8, 16, 1.0, &mut rng);
            opt.step(&mut w, &g, &hyper, t);
        }
        // moments were populated pre-switch
        assert!(opt.m.fro_norm() > 0.0);
        let g = Matrix::randn(8, 16, 1.0, &mut rng);
        let ev = opt.step(&mut w, &g, &hyper, 6);
        assert_eq!(ev.switch_reason(), Some(SwitchReason::Interval));
        // the retired subspace lived 5 steps and the rank is unchanged
        assert_eq!(
            ev,
            StepEvent::Switched { reason: SwitchReason::Interval, lifetime: 5, rank: 4 }
        );
        // after the switch the moments contain exactly one step's worth:
        // m = (1-β1)·R implies ‖m‖ ≤ (1-β1)·‖R‖
        let low = opt.projection().unwrap().down(&g);
        assert!(opt.m.fro_norm() <= (1.0 - hyper.beta1) * low.fro_norm() + 1e-5);
    }

    #[test]
    fn state_is_low_rank_sized() {
        let mut opt = presets::galore(4, 100);
        let mut rng = Rng::new(98);
        let mut w = Matrix::randn(64, 256, 1.0, &mut rng);
        let g = Matrix::randn(64, 256, 1.0, &mut rng);
        opt.step(&mut w, &g, &Hyper::default(), 1);
        // moments: 2 × (4×256) f32; basis: 64×4 f32 — far below full 64×256×2
        let full_adam_bytes = 2 * 64 * 256 * 4;
        assert!(opt.state_bytes() < full_adam_bytes / 6);
    }

    #[test]
    fn preprojected_step_matches_internal_projection_bit_for_bit() {
        // The dist runtime projects/reduces externally and calls
        // step_preprojected; on a single shard that path must equal the
        // classic step exactly.
        let mut rng = Rng::new(100);
        let hyper = Hyper { lr: 0.01, galore_scale: 0.5, ..Default::default() };
        let mut a = presets::rsvd_fixed(4, 1_000_000, 5);
        let mut b = presets::rsvd_fixed(4, 1_000_000, 5);
        let mut wa = Matrix::randn(12, 30, 1.0, &mut rng);
        let mut wb = wa.clone();
        for t in 1..=6u64 {
            let g = Matrix::randn(12, 30, 1.0, &mut rng);
            a.step(&mut wa, &g, &hyper, t);
            if t == 1 {
                b.refit_from(&g, t);
            }
            let low = b.projection().unwrap().down(&g);
            b.step_preprojected(&mut wb, &low, &hyper, t);
            assert_eq!(wa.data, wb.data, "diverged at step {t}");
        }
        // exported state matches between the two paths
        match (a.export_state(), b.export_state()) {
            (
                OptState::LowRank { m: ma, v: va, switches: sa, .. },
                OptState::LowRank { m: mb, v: vb, switches: sb, .. },
            ) => {
                assert_eq!(ma.data, mb.data);
                assert_eq!(va.data, vb.data);
                assert_eq!(sa, sb);
            }
            _ => panic!("both optimizers must export LowRank state"),
        }
    }

    #[test]
    fn state_roundtrips_through_export_restore() {
        let mut rng = Rng::new(101);
        let hyper = Hyper::default();
        let mut opt = presets::rsvd_fixed(4, 1_000_000, 9);
        let mut w = Matrix::randn(8, 20, 1.0, &mut rng);
        for t in 1..=4u64 {
            let g = Matrix::randn(8, 20, 1.0, &mut rng);
            opt.step(&mut w, &g, &hyper, t);
        }
        let state = opt.export_state();
        let mut fresh = presets::rsvd_fixed(4, 1_000_000, 9);
        fresh.restore_state(state).unwrap();
        // both must now produce the identical next step
        let g = Matrix::randn(8, 20, 1.0, &mut rng);
        let mut w2 = w.clone();
        opt.step(&mut w, &g, &hyper, 5);
        fresh.step(&mut w2, &g, &hyper, 5);
        assert_eq!(w.data, w2.data);
    }

    #[test]
    fn set_rank_refits_at_new_rank_with_continuing_stream() {
        let mut opt = presets::rsvd_fixed(8, 1_000_000, 11);
        let mut rng = Rng::new(102);
        let mut w = Matrix::randn(8, 32, 1.0, &mut rng);
        let g = Matrix::randn(8, 32, 1.0, &mut rng);
        opt.step(&mut w, &g, &Hyper::default(), 1);
        let rng_after_fit = opt.projector_rng_state();
        opt.set_rank(4);
        assert!(opt.projection().is_none(), "set_rank retires the subspace");
        // the projector (and its RNG stream) is kept, not re-seeded
        assert_eq!(opt.projector_rng_state(), rng_after_fit);
        let g2 = Matrix::randn(8, 32, 1.0, &mut rng);
        let ev = opt.step(&mut w, &g2, &Hyper::default(), 2);
        assert_eq!(ev.switch_reason(), Some(SwitchReason::Init));
        assert_eq!(opt.projection().unwrap().rank(), 4);
        assert_eq!(opt.m.shape(), (4, 32));
    }

    #[test]
    fn probe_observes_capture_when_enabled_and_is_free_when_disabled() {
        let mut opt = presets::lotus(4, 0.5, 5, 5, 13);
        let mut rng = Rng::new(103);
        let mut w = Matrix::randn(8, 32, 1.0, &mut rng);
        let hyper = Hyper::default();
        // disabled: no sample accumulates
        let g = Matrix::randn(8, 32, 1.0, &mut rng);
        opt.step(&mut w, &g, &hyper, 1);
        assert!(opt.probe_sample().is_none());
        diag::set_probes_enabled(true);
        diag::set_probe_every(1);
        for t in 2..=8u64 {
            let g = Matrix::randn(8, 32, 1.0, &mut rng);
            opt.step(&mut w, &g, &hyper, t);
        }
        diag::set_probes_enabled(false);
        let s = opt.probe_sample().expect("probe observed");
        assert!(s.capture > 0.0 && s.capture <= 1.0 + 1e-9, "capture={}", s.capture);
        assert!((s.residual - (1.0 - s.capture * s.capture)).abs() < 1e-9);
        assert_eq!(s.rank, 4);
        // LotusAdaSS has a scalar threshold, so the margin is defined
        assert!(s.margin.is_some());
    }

    #[test]
    fn update_stays_in_subspace_span() {
        // One step from w0: ΔW must lie in span(P) (Left side).
        let mut opt = presets::galore(4, 1000);
        let mut rng = Rng::new(99);
        let w0 = Matrix::randn(8, 32, 1.0, &mut rng);
        let mut w = w0.clone();
        let g = Matrix::randn(8, 32, 1.0, &mut rng);
        opt.step(&mut w, &g, &Hyper { weight_decay: 0.0, ..Default::default() }, 1);
        let dw = w.sub(&w0);
        let p = &opt.projection().unwrap();
        // project ΔW onto span(P) and compare: P Pᵀ ΔW = ΔW
        let lifted = p.up(&p.down(&dw));
        let err = lifted.sub(&dw).fro_norm() / dw.fro_norm();
        assert!(err < 1e-3, "ΔW left the subspace: {err}");
    }
}
