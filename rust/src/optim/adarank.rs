//! AdaRankGrad-style optimizer: projected low-rank Adam whose rank
//! *decays geometrically* across subspace switches (the paper's
//! AdaRankGrad row — gradients' intrinsic rank falls during training, so
//! memory is harvested by lowering r).
//!
//! This wraps [`LowRankAdam`] with an [`AdaRank`] schedule: every real
//! (non-init) subspace switch advances the schedule; when the scheduled
//! rank drops below the live one, [`LowRankAdam::set_rank`] retires the
//! subspace so the next fit happens at the decayed rank — keeping the
//! projector's RNG stream intact. Before the unified [`Optimizer`]
//! trait, only the sim trainer carried this schedule (fine-tune silently
//! dropped it and ran at a fixed rank); now every trainer — sim,
//! fine-tune and the distributed engine — gets identical decay
//! behaviour through the registry.

use super::lowrank::{presets, LowRankAdam};
use super::{Hyper, OptState, Optimizer, ProjectedGradient, StepEvent};
use crate::subspace::{AdaRank, SwitchReason};
use crate::tensor::Matrix;

/// Projected Adam + geometric rank-decay schedule (AdaRankGrad).
pub struct AdaRankAdam {
    inner: LowRankAdam,
    schedule: AdaRank,
    /// Consensus-path bookkeeping: a rank decay decided at this step's
    /// refresh, applied *after* the step (mirroring the event-driven
    /// path, which steps in the old-rank subspace before retiring it).
    /// Always `None` between steps, so it is not checkpointed.
    pending_rank: Option<usize>,
}

impl AdaRankAdam {
    /// Standard construction: rSVD projector + fixed-interval switching
    /// at `interval`, decaying by `decay` per switch, floored at
    /// `max(rank/4, 2)` (the sim trainer's historical floor).
    pub fn new(rank: usize, interval: u64, decay: f64, seed: u64) -> Self {
        AdaRankAdam {
            inner: presets::rsvd_fixed(rank, interval, seed),
            schedule: AdaRank::new(interval, rank, decay, (rank / 4).max(2)),
            pending_rank: None,
        }
    }

    /// Consensus-mode construction for the distributed engine: the
    /// internal switching policy is inert (the runtime owns switching
    /// and drives refreshes through [`ProjectedGradient::refit_from`]).
    pub fn consensus(rank: usize, interval: u64, decay: f64, seed: u64) -> Self {
        use crate::projection::RandSvdProjector;
        use crate::subspace::FixedInterval;
        AdaRankAdam {
            inner: LowRankAdam::new(
                rank,
                Box::new(RandSvdProjector::new(seed)),
                Box::new(FixedInterval::new(u64::MAX)),
            ),
            schedule: AdaRank::new(interval, rank, decay, (rank / 4).max(2)),
            pending_rank: None,
        }
    }

    /// The live (possibly decayed) projection rank.
    pub fn current_rank(&self) -> usize {
        self.inner.rank
    }

    /// Builder: pass a moment-quantization policy through to the inner
    /// projected Adam (None keeps the bit-exact f32 path).
    pub fn with_moment_quant(mut self, q: Option<crate::quant::MomentQuant>) -> Self {
        self.inner = self.inner.with_moment_quant(q);
        self
    }

    /// Advance the decay schedule after a real switch; if the scheduled
    /// rank dropped, retire the subspace so the next fit uses it.
    fn advance_schedule(&mut self) {
        self.schedule.advance();
        let rank = self.schedule.rank();
        if rank < self.inner.rank {
            self.inner.set_rank(rank);
        }
    }
}

impl Optimizer for AdaRankAdam {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        match self.inner.step(w, g, hyper, step) {
            StepEvent::Switched { reason, lifetime, .. } => {
                // the init fit just instantiates the starting rank; only
                // real switches walk the decay schedule
                if reason != SwitchReason::Init {
                    self.advance_schedule();
                }
                StepEvent::Switched { reason, lifetime, rank: self.inner.rank }
            }
            other => other,
        }
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn name(&self) -> &'static str {
        "adarank-adam"
    }

    fn diagnostic(&self) -> Option<f64> {
        // the rank trace is the method's interesting diagnostic
        Some(self.inner.rank as f64)
    }

    fn export_state(&self) -> OptState {
        OptState::AdaRank {
            inner: Box::new(self.inner.export_state()),
            current_rank: self.schedule.rank() as u64,
            rng: self.inner.projector_rng_state(),
        }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            OptState::AdaRank { inner, current_rank, rng } => {
                self.schedule.restore_rank(current_rank as usize);
                // pre-size the live rank; a LowRank inner snapshot then
                // restores its own (possibly older) fitted rank
                self.inner.set_rank(self.schedule.rank());
                self.inner.restore_state(*inner)?;
                // covers the retired-subspace window where the inner
                // snapshot is Empty but the stream has advanced
                if let Some(s) = rng {
                    self.inner.restore_projector_rng(s);
                }
                Ok(())
            }
            other => Err(format!("adarank-adam cannot restore '{}' state", other.kind())),
        }
    }

    fn projected(&mut self) -> Option<&mut dyn ProjectedGradient> {
        Some(self)
    }

    fn probe_sample(&self) -> Option<crate::telemetry::ProbeSample> {
        self.inner.probe_sample()
    }
}

impl ProjectedGradient for AdaRankAdam {
    fn projection(&self) -> Option<&crate::projection::Projection> {
        self.inner.projection()
    }

    /// Consensus-driven refresh, the exact twin of the event-driven
    /// path in [`Optimizer::step`]: a real (non-init) switch refits at
    /// the *current* rank and steps once in that subspace; the decay is
    /// applied after the step ([`Self::step_preprojected`] below), so
    /// the runtime's next refresh — an init fit, because the subspace
    /// was retired — lands at the decayed rank. A 1-shard dist run
    /// therefore consumes the projector RNG stream and visits the same
    /// subspace sequence as the sim trainer, bit for bit.
    fn refit_from(&mut self, g: &Matrix, step: u64) {
        let real_switch = self.inner.projection().is_some();
        self.inner.refit_from(g, step);
        if real_switch {
            self.schedule.advance();
            let rank = self.schedule.rank();
            if rank < self.inner.rank {
                self.pending_rank = Some(rank);
            }
        }
    }

    fn step_preprojected(&mut self, w: &mut Matrix, low: &Matrix, hyper: &Hyper, step: u64) {
        self.inner.step_preprojected(w, low, hyper, step);
        if let Some(rank) = self.pending_rank.take() {
            self.inner.set_rank(rank);
        }
    }

    fn projector_rng_state(&self) -> Option<(u64, u64)> {
        self.inner.projector_rng_state()
    }

    fn restore_projector_rng(&mut self, state: (u64, u64)) {
        self.inner.restore_projector_rng(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rank_decays_across_switches_to_floor() {
        let mut opt = AdaRankAdam::new(16, 4, 0.5, 3);
        let mut rng = Rng::new(120);
        let mut w = Matrix::randn(12, 40, 1.0, &mut rng);
        let hyper = Hyper::default();
        let mut seen = vec![];
        for t in 1..=40u64 {
            let g = Matrix::randn(12, 40, 1.0, &mut rng);
            if let StepEvent::Switched { rank, .. } = opt.step(&mut w, &g, &hyper, t) {
                seen.push(rank);
            }
        }
        assert!(seen.len() >= 3, "switches: {seen:?}");
        assert_eq!(seen[0], 16, "init fit at the starting rank");
        assert!(seen.last().copied().unwrap() <= 8, "rank decayed: {seen:?}");
        // floored at max(16/4, 2) = 4
        assert!(seen.iter().all(|&r| r >= 4), "floor respected: {seen:?}");
        assert_eq!(opt.current_rank(), *seen.last().unwrap());
        assert!(w.fro_norm().is_finite());
    }

    #[test]
    fn state_roundtrip_preserves_decayed_rank_and_trajectory() {
        let hyper = Hyper::default();
        let mut rng = Rng::new(121);
        let grads: Vec<Matrix> = (0..20).map(|_| Matrix::randn(10, 24, 1.0, &mut rng)).collect();
        let mut a = AdaRankAdam::new(8, 3, 0.5, 9);
        let mut wa = Matrix::randn(10, 24, 1.0, &mut rng);
        for (i, g) in grads[..10].iter().enumerate() {
            a.step(&mut wa, g, &hyper, i as u64 + 1);
        }
        let mut b = AdaRankAdam::new(8, 3, 0.5, 9);
        b.restore_state(a.export_state()).unwrap();
        assert_eq!(b.current_rank(), a.current_rank());
        let mut wb = wa.clone();
        for (i, g) in grads[10..].iter().enumerate() {
            let t = i as u64 + 11;
            assert_eq!(a.step(&mut wa, g, &hyper, t), b.step(&mut wb, g, &hyper, t));
            assert_eq!(wa.data, wb.data, "diverged at step {t}");
        }
    }
}
