//! Optimizers: full-rank Adam/AdamW/SGD, the projected low-rank Adam at
//! the heart of GaLore/Lotus ([`lowrank::LowRankAdam`]), its rank-decay
//! variant ([`adarank::AdaRankAdam`]), adapter-based baselines (LoRA,
//! ReLoRA, plain low-rank factorization) and Apollo's random-projection
//! scaled update.
//!
//! Everything operates per-layer on [`crate::tensor::Matrix`] weights
//! behind one first-class [`Optimizer`] trait: a uniform
//! `step → StepEvent` surface, measured `state_bytes`, typed
//! [`OptState`] export/restore for checkpointing, and an explicit
//! capability accessor ([`Optimizer::projected`]) for the distributed
//! runtime's split project/reduce/step pipeline — no downcasts
//! anywhere. The [`registry`] is the single `Method → Box<dyn Optimizer>`
//! factory every trainer (sim, fine-tune, dist, PJRT) constructs
//! through. All update rules use f64 scalar accumulation where it
//! matters and match the JAX reference graphs in
//! `python/compile/optim.py` (cross-checked by
//! `rust/tests/runtime_pjrt.rs`).

pub mod adam;
pub mod adarank;
pub mod apollo;
pub mod lora;
pub mod lowrank;
pub mod method;
pub mod registry;
pub mod state;

pub use adam::{Adam, Adam8bit, AdamBf16, AdamParams, Sgd};
pub use adarank::AdaRankAdam;
pub use apollo::Apollo;
pub use lora::{LoRALayer, LowRankFactor, ReLoRALayer};
pub use lowrank::LowRankAdam;
pub use method::Method;
pub use registry::{MethodInfo, TrainPhase};
pub use state::OptState;

use crate::projection::Projection;
use crate::subspace::SwitchReason;
use crate::tensor::Matrix;

/// Hyper-parameters shared by every method (a subset applies to each).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// GaLore/Lotus α scale applied to the lifted low-rank update.
    pub galore_scale: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            galore_scale: 0.25,
        }
    }
}

/// What one optimizer step did, uniformly across methods — subspace
/// switches (projection methods), adapter merges (ReLoRA), or nothing.
/// Trainers fold these into [`crate::subspace::SubspaceStats`] without
/// per-method dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepEvent {
    /// Nothing noteworthy happened.
    None,
    /// The optimizer re-fitted its gradient subspace.
    Switched {
        reason: SwitchReason,
        /// Steps the retired subspace lived (0 on the initial fit).
        lifetime: u64,
        /// Post-switch projection rank (constant for most methods;
        /// decays for AdaRankGrad).
        rank: usize,
    },
    /// Adapter merge-and-restart (ReLoRA).
    Merged {
        /// Steps since the previous merge.
        lifetime: u64,
    },
    /// The step was withheld by a numerical guard: the incoming loss or
    /// gradient was non-finite, so neither the weights nor the moments
    /// were touched (PR 6 skip-step semantics). Emitted by the trainers'
    /// guard layer, not by individual optimizers.
    SkippedNonFinite,
}

impl StepEvent {
    /// The switch reason, if this event is a subspace switch.
    pub fn switch_reason(&self) -> Option<SwitchReason> {
        match self {
            StepEvent::Switched { reason, .. } => Some(*reason),
            _ => None,
        }
    }
}

/// A per-layer optimizer: consumes the full-rank gradient of its layer,
/// updates the weight in place and reports what happened. This is the
/// single surface all four trainers drive — one step/event/checkpoint
/// pipeline whether the step runs in the simulator, the fine-tuning
/// loop, a distributed replica or the PJRT coordinator.
pub trait Optimizer: Send {
    /// Apply one step. `step` is 1-based (bias correction).
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent;

    /// Bytes of persistent optimizer state currently held (measured, not
    /// analytic — the analytic model lives in [`crate::memcount`]).
    fn state_bytes(&self) -> usize;

    /// Name for logs.
    fn name(&self) -> &'static str;

    /// The policy diagnostic this optimizer thresholds on (‖d̄‖, ρ_t or
    /// the current rank), for Fig. 1 style traces.
    fn diagnostic(&self) -> Option<f64> {
        None
    }

    /// Persistent state for checkpointing, as a typed [`OptState`]
    /// (serializable to named f32 tensors via
    /// [`OptState::to_tensors`]). Restoring the returned value into a
    /// freshly constructed optimizer of the same spec reproduces the
    /// original's trajectory bit-for-bit.
    fn export_state(&self) -> OptState;

    /// Restore an [`Optimizer::export_state`] snapshot; rejects a
    /// snapshot taken from a different optimizer kind or shape.
    fn restore_state(&mut self, state: OptState) -> Result<(), String>;

    /// Capability accessor for the distributed runtime: optimizers whose
    /// update factors into *project → (all-reduce) → step-in-subspace*
    /// expose [`ProjectedGradient`]; everything else returns `None` and
    /// is driven with the densely all-reduced gradient. This replaces
    /// per-trainer downcasts/enums.
    fn projected(&mut self) -> Option<&mut dyn ProjectedGradient> {
        None
    }

    /// The last subspace-quality probe sample, for optimizers that
    /// observe one (`telemetry::diag`): capture ratio, residual energy,
    /// displacement-vs-threshold margin, subspace age and the
    /// gradient-noise-scale estimate. `None` for unprojected methods and
    /// whenever probes are disabled — the trainers emit records only for
    /// slots that return `Some`, so probe-off streams are byte-identical
    /// to pre-probe ones.
    fn probe_sample(&self) -> Option<crate::telemetry::ProbeSample> {
        None
    }
}

/// The split-pipeline capability the data-parallel engine drives
/// ([`crate::dist`]): project the local gradient, exchange only the
/// low-rank payload, step every replica identically, and refresh the
/// subspace in lockstep from an externally reduced dense gradient.
pub trait ProjectedGradient {
    /// The live projection (None before the first fit).
    fn projection(&self) -> Option<&Projection>;

    /// Re-fit the subspace from an externally supplied full-rank
    /// gradient — the distributed runtime's consensus refresh hands in
    /// the *all-reduced* gradient here so every replica fits the same
    /// basis. Moments are reset in the new subspace.
    fn refit_from(&mut self, g: &Matrix, step: u64);

    /// One step from an externally reduced *low-rank* gradient (the
    /// subspace must already be fitted): Adam in the subspace + fused
    /// lift, skipping both the down-projection and the internal
    /// switching policy — in data-parallel training those belong to the
    /// runtime, which reduces per-shard projections and decides switches
    /// by consensus.
    fn step_preprojected(&mut self, w: &mut Matrix, low: &Matrix, hyper: &Hyper, step: u64);

    /// The projector's RNG stream position (None for deterministic
    /// projectors) — checkpointed so a resumed run's next refresh fits
    /// the same basis as the uninterrupted one.
    fn projector_rng_state(&self) -> Option<(u64, u64)>;

    /// Restore a [`ProjectedGradient::projector_rng_state`] snapshot.
    fn restore_projector_rng(&mut self, state: (u64, u64));
}

/// Test/validation helper: measured state bytes of a freshly stepped
/// GaLore-style [`LowRankAdam`] at shape (m, n, r) — used by
/// [`crate::memcount`] to validate the analytic model against reality.
pub fn presets_state_bytes_probe(m: usize, n: usize, r: usize, hyper: &Hyper) -> usize {
    use crate::util::Rng;
    let mut rng = Rng::new(1);
    let mut opt = lowrank::presets::galore(r, 1_000_000);
    let mut w = Matrix::randn(m, n, 1.0, &mut rng);
    let g = Matrix::randn(m, n, 1.0, &mut rng);
    opt.step(&mut w, &g, hyper, 1);
    opt.state_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Shared check: an optimizer should reduce a convex quadratic
    /// f(W) = ½‖W − W*‖² when fed its gradient (W − W*).
    pub(crate) fn drives_quadratic_down(mut opt: impl Optimizer, steps: usize) -> f32 {
        let mut rng = Rng::new(90);
        let target = Matrix::randn(16, 24, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 24);
        let hyper = Hyper { lr: 0.05, ..Default::default() };
        for t in 1..=steps {
            let g = w.sub(&target);
            opt.step(&mut w, &g, &hyper, t as u64);
        }
        w.sub(&target).fro_norm() / target.fro_norm()
    }

    #[test]
    fn adam_solves_quadratic() {
        let rel = drives_quadratic_down(Adam::new(16, 24), 400);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn sgd_solves_quadratic() {
        let rel = drives_quadratic_down(Sgd::new(0.9, 16, 24), 400);
        assert!(rel < 0.05, "rel={rel}");
    }
}
