//! Optimizers: full-rank Adam/AdamW/SGD, the projected low-rank Adam at
//! the heart of GaLore/Lotus ([`lowrank::LowRankAdam`]), adapter-based
//! baselines (LoRA, ReLoRA, plain low-rank factorization) and Apollo's
//! random-projection scaled update.
//!
//! Everything operates per-layer on [`crate::tensor::Matrix`] weights;
//! the trainer composes per-layer optimizers into a model update. All
//! update rules use f64 scalar accumulation where it matters and match
//! the JAX reference graphs in `python/compile/optim.py` (cross-checked
//! by `rust/tests/runtime_pjrt.rs`).

pub mod adam;
pub mod lowrank;
pub mod lora;
pub mod apollo;

pub use adam::{Adam, AdamParams, Sgd};
pub use apollo::Apollo;
pub use lora::{LoRALayer, LowRankFactor, ReLoRALayer};
pub use lowrank::{LowRankAdam, LowRankEvent};

use crate::tensor::Matrix;

/// Hyper-parameters shared by every method (a subset applies to each).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// GaLore/Lotus α scale applied to the lifted low-rank update.
    pub galore_scale: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            galore_scale: 0.25,
        }
    }
}

/// A per-layer optimizer: consumes the full-rank gradient of its layer
/// and updates the weight in place.
pub trait LayerOptimizer: Send {
    /// Apply one step. `step` is 1-based (bias correction).
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64);
    /// Bytes of persistent optimizer state currently held (measured, not
    /// analytic — the analytic model lives in [`crate::memcount`]).
    fn state_bytes(&self) -> usize;
    /// Name for logs.
    fn name(&self) -> &'static str;
}

/// Test/validation helper: measured state bytes of a freshly stepped
/// GaLore-style [`LowRankAdam`] at shape (m, n, r) — used by
/// [`crate::memcount`] to validate the analytic model against reality.
pub fn presets_state_bytes_probe(m: usize, n: usize, r: usize, hyper: &Hyper) -> usize {
    use crate::util::Rng;
    let mut rng = Rng::new(1);
    let mut opt = lowrank::presets::galore(r, 1_000_000);
    let mut w = Matrix::randn(m, n, 1.0, &mut rng);
    let g = Matrix::randn(m, n, 1.0, &mut rng);
    opt.step(&mut w, &g, hyper, 1);
    opt.state_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Shared check: an optimizer should reduce a convex quadratic
    /// f(W) = ½‖W − W*‖² when fed its gradient (W − W*).
    pub(crate) fn drives_quadratic_down(mut opt: impl LayerOptimizer, steps: usize) -> f32 {
        let mut rng = Rng::new(90);
        let target = Matrix::randn(16, 24, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 24);
        let hyper = Hyper { lr: 0.05, ..Default::default() };
        for t in 1..=steps {
            let g = w.sub(&target);
            opt.step(&mut w, &g, &hyper, t as u64);
        }
        w.sub(&target).fro_norm() / target.fro_norm()
    }

    #[test]
    fn adam_solves_quadratic() {
        let rel = drives_quadratic_down(Adam::new(16, 24), 400);
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn sgd_solves_quadratic() {
        let rel = drives_quadratic_down(Sgd::new(0.9, 16, 24), 400);
        assert!(rel < 0.05, "rel={rel}");
    }
}
