//! Apollo-style optimizer (Zhu et al. 2024): SGD-like memory with
//! AdamW-level behaviour by estimating *channel-wise* learning-rate
//! scales from a rank-r random projection of the gradient.
//!
//! Per layer: maintain Adam moments only in a rank-r randomly projected
//! space (R = Pᵀ G with Gaussian P, no SVD at all). From the projected
//! Adam direction compute per-channel scaling factors
//! `s_j = ‖dir_j‖ / ‖R_j‖` and update with the *full-rank* gradient
//! rescaled channel-wise: ΔW = −lr · (G ⊙ s). This captures Apollo's
//! memory profile (rank-r states, random projection, channel-wise
//! scaling) without its tensor-parallel machinery.

use super::adam::Adam;
use super::{Hyper, OptState, Optimizer, StepEvent};
use crate::projection::{GaussianProjector, Projection, Projector, Side};
use crate::subspace::SwitchReason;
use crate::tensor::Matrix;

/// Apollo: random-projection channel-wise scaled update.
pub struct Apollo {
    pub rank: usize,
    pub refresh_every: u64,
    projector: GaussianProjector,
    proj: Option<Projection>,
    m: Matrix,
    v: Matrix,
    steps_in_proj: u64,
    /// RNG position at construction — restoring a pre-fit
    /// ([`OptState::Empty`]) snapshot rewinds the stream here, so a
    /// rollback on an already-stepped optimizer is exact.
    rng0: (u64, u64),
}

impl Apollo {
    pub fn new(rank: usize, refresh_every: u64, seed: u64) -> Self {
        let projector = GaussianProjector::new(seed);
        let rng0 = projector.rng_state().expect("gaussian projector has an RNG stream");
        Apollo {
            rank,
            refresh_every,
            projector,
            proj: None,
            m: Matrix::zeros(0, 0),
            v: Matrix::zeros(0, 0),
            steps_in_proj: 0,
            rng0,
        }
    }
}

impl Optimizer for Apollo {
    fn step(&mut self, w: &mut Matrix, g: &Matrix, hyper: &Hyper, step: u64) -> StepEvent {
        let mut event = StepEvent::None;
        if self.proj.is_none() || self.steps_in_proj >= self.refresh_every {
            let reason =
                if self.proj.is_none() { SwitchReason::Init } else { SwitchReason::Interval };
            event =
                StepEvent::Switched { reason, lifetime: self.steps_in_proj, rank: self.rank };
            let proj = self.projector.fit(g, self.rank);
            let low = proj.down(g);
            self.m = Matrix::zeros(low.rows, low.cols);
            self.v = Matrix::zeros(low.rows, low.cols);
            self.proj = Some(proj);
            self.steps_in_proj = 0;
        }
        let proj = self.proj.as_ref().unwrap();
        let low = proj.down(g); // r×n (Left) or m×r (Right)
        let mut dir = Matrix::zeros(low.rows, low.cols);
        Adam::direction(&mut self.m, &mut self.v, &low, hyper, step, &mut dir);

        // Channel-wise scale: for Left side, channels are columns of the
        // r×n low-rank view (matching the weight's n dimension); for
        // Right, rows (m dimension).
        match proj.side {
            crate::projection::Side::Left => {
                let n = low.cols;
                let mut scale = vec![0.0f32; n];
                for j in 0..n {
                    let (mut num, mut den) = (0.0f64, 0.0f64);
                    for i in 0..low.rows {
                        num += (dir.at(i, j) as f64).powi(2);
                        den += (low.at(i, j) as f64).powi(2);
                    }
                    // dir already includes lr; normalize it out of the ratio
                    scale[j] = if den > 1e-30 { (num / den).sqrt() as f32 } else { 0.0 };
                }
                let cols = w.cols;
                for i in 0..w.rows {
                    let wrow = w.row_mut(i);
                    let grow = g.row(i);
                    for j in 0..cols {
                        wrow[j] -= grow[j] * scale[j];
                    }
                }
            }
            crate::projection::Side::Right => {
                let m = low.rows;
                let mut scale = vec![0.0f32; m];
                for i in 0..m {
                    let (mut num, mut den) = (0.0f64, 0.0f64);
                    for j in 0..low.cols {
                        num += (dir.at(i, j) as f64).powi(2);
                        den += (low.at(i, j) as f64).powi(2);
                    }
                    scale[i] = if den > 1e-30 { (num / den).sqrt() as f32 } else { 0.0 };
                }
                let cols = w.cols;
                for i in 0..w.rows {
                    let s = scale[i];
                    let wrow = w.row_mut(i);
                    let grow = g.row(i);
                    for j in 0..cols {
                        wrow[j] -= grow[j] * s;
                    }
                }
            }
        }
        if hyper.weight_decay > 0.0 {
            w.scale(1.0 - hyper.lr * hyper.weight_decay);
        }
        self.steps_in_proj += 1;
        event
    }

    fn state_bytes(&self) -> usize {
        let moments = (self.m.len() + self.v.len()) * 4;
        let basis = self.proj.as_ref().map(|p| p.basis.len() * 4).unwrap_or(0);
        moments + basis
    }

    fn name(&self) -> &'static str {
        "apollo"
    }

    fn export_state(&self) -> OptState {
        match &self.proj {
            None => OptState::Empty,
            Some(p) => OptState::Apollo {
                basis: p.basis.clone(),
                side: p.side,
                m: self.m.clone(),
                v: self.v.clone(),
                steps_in_proj: self.steps_in_proj,
                rng: self.projector.rng_state().expect("gaussian projector has an RNG stream"),
            },
        }
    }

    fn restore_state(&mut self, state: OptState) -> Result<(), String> {
        match state {
            // a pre-fit snapshot: rewind to the just-constructed state
            // (restoring is a rollback — the target may have stepped)
            OptState::Empty => {
                self.proj = None;
                self.m = Matrix::zeros(0, 0);
                self.v = Matrix::zeros(0, 0);
                self.steps_in_proj = 0;
                self.projector.set_rng_state(self.rng0);
                Ok(())
            }
            OptState::Apollo { basis, side, m, v, steps_in_proj, rng } => {
                if m.shape() != v.shape() {
                    return Err("apollo moment shapes must match".into());
                }
                if basis.cols != self.rank {
                    return Err(format!(
                        "apollo snapshot at rank {} cannot restore into rank {}",
                        basis.cols, self.rank
                    ));
                }
                let low_rank_dim = match side {
                    Side::Left => m.rows,
                    Side::Right => m.cols,
                };
                if low_rank_dim != self.rank {
                    return Err(format!(
                        "apollo snapshot moments ({}x{}) do not match rank {} on side {side:?}",
                        m.rows, m.cols, self.rank
                    ));
                }
                self.proj = Some(Projection { basis, side });
                self.m = m;
                self.v = v;
                self.steps_in_proj = steps_in_proj;
                self.projector.set_rng_state(rng);
                Ok(())
            }
            other => Err(format!("apollo cannot restore '{}' state", other.kind())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn apollo_reduces_quadratic() {
        let mut rng = Rng::new(111);
        let target = Matrix::randn(16, 24, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 24);
        let mut opt = Apollo::new(4, 100, 7);
        let hyper = Hyper { lr: 0.05, ..Default::default() };
        for t in 1..=500 {
            let g = w.sub(&target);
            opt.step(&mut w, &g, &hyper, t);
        }
        let rel = w.sub(&target).fro_norm() / target.fro_norm();
        assert!(rel < 0.2, "rel={rel}");
    }

    #[test]
    fn apollo_state_is_low_rank() {
        let mut rng = Rng::new(112);
        let mut w = Matrix::randn(64, 256, 1.0, &mut rng);
        let g = Matrix::randn(64, 256, 1.0, &mut rng);
        let mut opt = Apollo::new(4, 100, 8);
        opt.step(&mut w, &g, &Hyper::default(), 1);
        assert!(opt.state_bytes() < 2 * 64 * 256 * 4 / 8);
    }

    #[test]
    fn update_direction_is_descent_on_average() {
        // ⟨ΔW, −G⟩ > 0 for a random but fixed gradient
        let mut rng = Rng::new(113);
        let g = Matrix::randn(16, 32, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 32);
        let w0 = w.clone();
        let mut opt = Apollo::new(4, 100, 9);
        opt.step(&mut w, &g, &Hyper { lr: 0.01, ..Default::default() }, 1);
        let dw = w.sub(&w0);
        assert!(dw.dot(&g) < 0.0, "must move against the gradient");
    }
}
