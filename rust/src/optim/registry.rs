//! The single `Method → Box<dyn Optimizer>` factory.
//!
//! Every trainer — the sim pre-trainer, the GLUE-sim fine-tuner, the
//! distributed engine and (for its supported subset) the PJRT
//! coordinator — constructs per-matrix optimizers here, so a method
//! behaves identically at every entry point and adding a method is one
//! optimizer file plus one registry line. The catalog doubles as the
//! `lotus methods` CLI listing.

use super::adam::{Adam, Adam8bit, AdamBf16};
use super::adarank::AdaRankAdam;
use super::apollo::Apollo;
use super::lora::{LoRALayer, LowRankFactor, ReLoRALayer};
use super::lowrank::{presets, LowRankAdam};
use super::method::Method;
use super::{Hyper, Optimizer};
use crate::projection::{RandSvdProjector, SvdProjector};
use crate::quant::MomentQuant;
use crate::subspace::FixedInterval;
use crate::util::Rng;

/// Where the optimizer will run — the only per-trainer divergence left,
/// and it is explicit: fine-tuning starts from pretrained weights, so
/// the from-scratch "Low Rank" factorization (which replaces W with a
/// random B·A product) falls back to full Adam there, as in the paper's
/// Table 2 line-up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainPhase {
    /// Training from random init (sim pre-trainer, dist engine).
    Pretrain,
    /// Adapting pretrained weights (GLUE-sim fine-tuner).
    FineTune,
}

/// Build the optimizer for one `rows × cols` weight matrix.
///
/// `seed` derives per-matrix projector/adapter RNG streams (the trainers
/// pass [`crate::sim::trainer::mat_seed`] so sim and dist streams
/// coincide); `rng` is the shared construction stream adapter inits draw
/// from (LoRA's Gaussian A, the factorization's B·A).
pub fn build(
    method: Method,
    rank: usize,
    rows: usize,
    cols: usize,
    seed: u64,
    rng: &mut Rng,
    phase: TrainPhase,
) -> Box<dyn Optimizer> {
    match method {
        Method::FullRank => Box::new(Adam::new(rows, cols)),
        Method::GaLore { interval } => Box::new(presets::galore(rank, interval)),
        Method::Lotus { gamma, eta, t_min } => {
            Box::new(presets::lotus(rank, gamma, eta, t_min, seed))
        }
        Method::RsvdFixed { interval } => Box::new(presets::rsvd_fixed(rank, interval, seed)),
        Method::LowRank => match phase {
            TrainPhase::Pretrain => Box::new(LowRankFactor::new(rows, cols, rank, rng)),
            // factorizing a pretrained W from scratch would discard it
            TrainPhase::FineTune => Box::new(Adam::new(rows, cols)),
        },
        Method::LoRA => Box::new(LoRALayer::new(rows, cols, rank, 2.0 * rank as f32, rng)),
        Method::ReLoRA { merge_every } => {
            Box::new(ReLoRALayer::new(rows, cols, rank, 2.0 * rank as f32, merge_every, seed))
        }
        Method::Apollo { refresh_every } => Box::new(Apollo::new(rank, refresh_every, seed)),
        Method::AdaRankGrad { interval, decay } => {
            Box::new(AdaRankAdam::new(rank, interval, decay, seed))
        }
    }
}

/// Full-rank Adam at the requested moment grid (`--state-dtype`).
fn adam_with_state(rows: usize, cols: usize, q: MomentQuant) -> Box<dyn Optimizer> {
    match q {
        MomentQuant::Bf16 => Box::new(AdamBf16::new(rows, cols)),
        MomentQuant::Int8 { block } => Box::new(Adam8bit::new(rows, cols, block)),
    }
}

/// [`build`] plus an optional moment-quantization policy
/// (`--state-dtype bf16|int8`). The Adam-moment carriers — full-rank
/// Adam and the projected low-rank family — store their moments on the
/// quantized grid; adapter methods (LoRA/ReLoRA/Apollo) and the
/// factorization keep f32 moments, since their memory story is the
/// adapter parameterization itself, not moment storage at model scale.
pub fn build_with_state(
    method: Method,
    rank: usize,
    rows: usize,
    cols: usize,
    seed: u64,
    rng: &mut Rng,
    phase: TrainPhase,
    state: Option<MomentQuant>,
) -> Box<dyn Optimizer> {
    match (method, state) {
        (Method::FullRank, Some(q)) => adam_with_state(rows, cols, q),
        (Method::GaLore { interval }, Some(q)) => {
            Box::new(presets::galore(rank, interval).with_moment_quant(Some(q)))
        }
        (Method::Lotus { gamma, eta, t_min }, Some(q)) => {
            Box::new(presets::lotus(rank, gamma, eta, t_min, seed).with_moment_quant(Some(q)))
        }
        (Method::RsvdFixed { interval }, Some(q)) => {
            Box::new(presets::rsvd_fixed(rank, interval, seed).with_moment_quant(Some(q)))
        }
        (Method::AdaRankGrad { interval, decay }, Some(q)) => {
            Box::new(AdaRankAdam::new(rank, interval, decay, seed).with_moment_quant(Some(q)))
        }
        (other, _) => build(other, rank, rows, cols, seed, rng, phase),
    }
}

/// Build for the distributed engine: projection methods get an *inert*
/// internal switching policy (the runtime owns switching — per-shard
/// policy replicas vote and consensus drives
/// [`super::ProjectedGradient::refit_from`]); everything else builds
/// exactly as [`build`] and is driven with the densely all-reduced
/// gradient. Whether the engine uses the split low-rank pipeline is
/// decided by the capability accessor ([`super::Optimizer::projected`]),
/// not by matching on the method again.
pub fn build_dist(
    method: Method,
    rank: usize,
    rows: usize,
    cols: usize,
    seed: u64,
    rng: &mut Rng,
) -> Box<dyn Optimizer> {
    build_dist_with_state(method, rank, rows, cols, seed, rng, None)
}

/// [`build_dist`] plus an optional moment-quantization policy; the same
/// carrier/fallback split as [`build_with_state`].
pub fn build_dist_with_state(
    method: Method,
    rank: usize,
    rows: usize,
    cols: usize,
    seed: u64,
    rng: &mut Rng,
    state: Option<MomentQuant>,
) -> Box<dyn Optimizer> {
    let inert = || Box::new(FixedInterval::new(u64::MAX));
    match method {
        Method::FullRank => match state {
            Some(q) => adam_with_state(rows, cols, q),
            None => Box::new(Adam::new(rows, cols)),
        },
        Method::GaLore { .. } => Box::new(
            LowRankAdam::new(rank, Box::new(SvdProjector), inert()).with_moment_quant(state),
        ),
        Method::Lotus { .. } | Method::RsvdFixed { .. } => Box::new(
            LowRankAdam::new(rank, Box::new(RandSvdProjector::new(seed)), inert())
                .with_moment_quant(state),
        ),
        Method::AdaRankGrad { interval, decay } => {
            Box::new(AdaRankAdam::consensus(rank, interval, decay, seed).with_moment_quant(state))
        }
        other => build(other, rank, rows, cols, seed, rng, TrainPhase::Pretrain),
    }
}

/// True when the PJRT coordinator's artifact set covers this method
/// (the projected-Adam + rSVD/SVD refresh path).
pub fn pjrt_supported(method: Method) -> bool {
    matches!(
        method,
        Method::Lotus { .. } | Method::GaLore { .. } | Method::RsvdFixed { .. }
    )
}

/// One registry row: what the method is made of and where it runs.
#[derive(Clone, Copy, Debug)]
pub struct MethodInfo {
    /// Display name (the paper's table row).
    pub name: &'static str,
    /// CLI spelling (`--method <cli>`).
    pub cli: &'static str,
    /// A representative default spec (paper-ish hyper-parameters).
    pub default: Method,
    /// How the gradient subspace is fitted.
    pub projector: &'static str,
    /// When it is re-fitted.
    pub policy: &'static str,
    /// Every registered optimizer checkpoints through
    /// [`super::OptState`].
    pub checkpointable: bool,
    /// Runs under the distributed engine ([`crate::dist`]).
    pub dist: bool,
    /// Runs on the PJRT artifact path.
    pub pjrt: bool,
    /// Sim-scale training hyper defaults (lr, lifted-update scale). The
    /// CLI starts from these when `--method` selects the row and the
    /// user passes no explicit `--lr`/`--galore-scale`.
    pub hyper: Hyper,
}

/// The full registry, in the paper's table order.
pub fn catalog() -> Vec<MethodInfo> {
    // sim-scale hyper defaults: lr + lifted-update scale (adapter
    // methods train with the 2·r/r = 2 α convention the fine-tune suite
    // uses and a gentler lr; everything else matches the sim presets)
    let h = |lr: f32, scale: f32| Hyper { lr, galore_scale: scale, ..Default::default() };
    let row = |name, cli, default, projector, policy, pjrt, hyper| MethodInfo {
        name,
        cli,
        default,
        projector,
        policy,
        checkpointable: true,
        dist: true,
        pjrt,
        hyper,
    };
    vec![
        row("Full Rank", "full", Method::FullRank, "-", "-", false, h(3e-3, 1.0)),
        row(
            "GaLore",
            "galore",
            Method::GaLore { interval: 200 },
            "exact SVD",
            "fixed interval",
            true,
            h(3e-3, 1.0),
        ),
        row("Low Rank", "lowrank", Method::LowRank, "-", "-", false, h(3e-3, 1.0)),
        row("LoRA", "lora", Method::LoRA, "-", "-", false, h(2e-3, 2.0)),
        row(
            "ReLoRA",
            "relora",
            Method::ReLoRA { merge_every: 200 },
            "-",
            "merge interval",
            false,
            h(2e-3, 2.0),
        ),
        row(
            "AdaRankGrad",
            "adarankgrad",
            Method::AdaRankGrad { interval: 200, decay: 0.85 },
            "rSVD",
            "fixed + rank decay",
            false,
            h(3e-3, 1.0),
        ),
        row(
            "Apollo",
            "apollo",
            Method::Apollo { refresh_every: 200 },
            "Gaussian",
            "fixed interval",
            false,
            h(3e-3, 1.0),
        ),
        row(
            "Lotus",
            "lotus",
            Method::lotus_default(),
            "rSVD",
            "AdaSS (Alg. 1)",
            true,
            h(3e-3, 1.0),
        ),
        row(
            "rSVD+Fixed",
            "rsvd-fixed",
            Method::RsvdFixed { interval: 200 },
            "rSVD",
            "fixed interval",
            true,
            h(3e-3, 1.0),
        ),
    ]
}

/// Look up a catalog row by its CLI spelling (`--method <cli>`).
pub fn by_cli(name: &str) -> Option<MethodInfo> {
    catalog().into_iter().find(|i| i.cli == name)
}

/// Explicit CLI knobs for [`method_from_cli`]; `None` keeps the catalog
/// default. `interval` doubles as ReLoRA's merge and Apollo's refresh
/// interval, as before.
#[derive(Clone, Copy, Debug, Default)]
pub struct MethodOverrides {
    pub interval: Option<u64>,
    pub gamma: Option<f64>,
    pub eta: Option<u64>,
    pub t_min: Option<u64>,
    pub decay: Option<f64>,
}

/// Resolve a CLI method name to a live [`Method`] spec plus its default
/// training hypers: start from the catalog row, apply explicit
/// overrides. This is the single home of per-method defaults — the CLI
/// used to hand-roll them.
pub fn method_from_cli(name: &str, o: MethodOverrides) -> Result<(Method, Hyper), String> {
    let info =
        by_cli(name).ok_or_else(|| format!("unknown method '{name}' (see `lotus methods`)"))?;
    let method = match info.default {
        Method::FullRank => Method::FullRank,
        Method::LowRank => Method::LowRank,
        Method::LoRA => Method::LoRA,
        Method::GaLore { interval } => {
            Method::GaLore { interval: o.interval.unwrap_or(interval) }
        }
        Method::RsvdFixed { interval } => {
            Method::RsvdFixed { interval: o.interval.unwrap_or(interval) }
        }
        Method::ReLoRA { merge_every } => {
            Method::ReLoRA { merge_every: o.interval.unwrap_or(merge_every) }
        }
        Method::Apollo { refresh_every } => {
            Method::Apollo { refresh_every: o.interval.unwrap_or(refresh_every) }
        }
        Method::AdaRankGrad { interval, decay } => Method::AdaRankGrad {
            interval: o.interval.unwrap_or(interval),
            decay: o.decay.unwrap_or(decay),
        },
        Method::Lotus { gamma, eta, t_min } => Method::Lotus {
            gamma: o.gamma.unwrap_or(gamma),
            eta: o.eta.unwrap_or(eta),
            // --eta without --t_min keeps the two in lockstep, as the
            // CLI always has
            t_min: o.t_min.or(o.eta).unwrap_or(t_min),
        },
    };
    Ok((method, info.hyper))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Hyper;
    use crate::tensor::Matrix;

    #[test]
    fn catalog_covers_every_method_and_agrees_with_names() {
        let cat = catalog();
        assert_eq!(cat.len(), 9);
        for info in &cat {
            assert_eq!(info.default.name(), info.name, "{}", info.cli);
            assert!(info.checkpointable && info.dist);
        }
        // pjrt support matches the predicate
        for info in &cat {
            assert_eq!(pjrt_supported(info.default), info.pjrt, "{}", info.cli);
        }
    }

    #[test]
    fn every_registered_method_builds_and_steps() {
        let mut rng = Rng::new(7);
        let hyper = Hyper { lr: 1e-3, ..Default::default() };
        for info in catalog() {
            let mut opt = build(info.default, 4, 12, 20, 99, &mut rng, TrainPhase::Pretrain);
            let mut w = Matrix::randn(12, 20, 0.1, &mut rng);
            for t in 1..=3u64 {
                let g = Matrix::randn(12, 20, 1.0, &mut rng);
                let _ = opt.step(&mut w, &g, &hyper, t);
            }
            assert!(w.fro_norm().is_finite(), "{}", info.cli);
        }
    }

    #[test]
    fn method_from_cli_applies_catalog_defaults_and_overrides() {
        let (m, h) = method_from_cli("galore", MethodOverrides::default()).unwrap();
        assert_eq!(m, Method::GaLore { interval: 200 });
        assert!((h.lr - 3e-3).abs() < 1e-9);
        let o = MethodOverrides { interval: Some(77), ..Default::default() };
        assert_eq!(method_from_cli("galore", o).unwrap().0, Method::GaLore { interval: 77 });
        assert_eq!(method_from_cli("relora", o).unwrap().0, Method::ReLoRA { merge_every: 77 });
        // --eta without --t_min keeps them in lockstep
        let o = MethodOverrides { eta: Some(10), ..Default::default() };
        assert_eq!(
            method_from_cli("lotus", o).unwrap().0,
            Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 }
        );
        // adapters get the fine-tune-style defaults
        let (_, h) = method_from_cli("lora", MethodOverrides::default()).unwrap();
        assert!((h.galore_scale - 2.0).abs() < 1e-9);
        assert!(method_from_cli("nope", MethodOverrides::default()).is_err());
    }

    #[test]
    fn finetune_phase_maps_lowrank_to_full_adam() {
        let mut rng = Rng::new(8);
        let mut pre = build(Method::LowRank, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain);
        let mut ft = build(Method::LowRank, 4, 8, 8, 1, &mut rng, TrainPhase::FineTune);
        assert_eq!(pre.name(), "lowrank-factor");
        assert_eq!(ft.name(), "adam");
        assert!(pre.projected().is_none() && ft.projected().is_none());
    }

    #[test]
    fn state_quant_builders_swap_moment_carriers() {
        use crate::quant::MomentQuant;
        let mut rng = Rng::new(10);
        let hyper = Hyper::default();
        let q8 = Some(MomentQuant::Int8 { block: 32 });
        let full = build_with_state(
            Method::FullRank,
            4,
            8,
            8,
            1,
            &mut rng,
            TrainPhase::Pretrain,
            Some(MomentQuant::Bf16),
        );
        assert_eq!(full.name(), "adam-bf16");
        let full8 =
            build_with_state(Method::FullRank, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain, q8);
        assert_eq!(full8.name(), "adam8bit");
        // projected carriers shrink their reported moment bytes
        let mut f32_opt =
            build_dist_with_state(Method::lotus_default(), 4, 16, 64, 5, &mut rng, None);
        let mut q_opt = build_dist_with_state(Method::lotus_default(), 4, 16, 64, 5, &mut rng, q8);
        let g = Matrix::randn(16, 64, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 64);
        let mut w2 = Matrix::zeros(16, 64);
        f32_opt.step(&mut w, &g, &hyper, 1);
        q_opt.step(&mut w2, &g, &hyper, 1);
        assert!(q_opt.state_bytes() < f32_opt.state_bytes());
        // adapters fall back to their f32 builds unchanged
        let base = build(Method::LoRA, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain);
        let lora = build_with_state(Method::LoRA, 4, 8, 8, 1, &mut rng, TrainPhase::Pretrain, q8);
        assert_eq!(lora.name(), base.name());
    }

    #[test]
    fn dist_builds_expose_projection_capability_where_expected() {
        let mut rng = Rng::new(9);
        let projected = [
            Method::GaLore { interval: 10 },
            Method::lotus_default(),
            Method::RsvdFixed { interval: 10 },
            Method::AdaRankGrad { interval: 10, decay: 0.85 },
        ];
        for m in projected {
            let mut opt = build_dist(m, 4, 8, 16, 3, &mut rng);
            assert!(opt.projected().is_some(), "{}", m.name());
        }
        for m in [Method::FullRank, Method::LoRA, Method::Apollo { refresh_every: 10 }] {
            let mut opt = build_dist(m, 4, 8, 16, 3, &mut rng);
            assert!(opt.projected().is_none(), "{}", m.name());
        }
    }
}
