//! RAII span tracing: fixed per-kind wall-clock accumulators on
//! lock-free atomics (the hot path is one relaxed load when disabled,
//! two relaxed adds when enabled) plus an optional Chrome `trace_event`
//! buffer that [`finish_trace`] serializes into a file loadable by
//! `chrome://tracing` / Perfetto.
//!
//! Span kinds are a closed enum rather than free-form strings so the
//! accumulators are plain arrays — no hashing, no locking and no
//! allocation on the instrumented step path (`tests/alloc_steady.rs`
//! counts zero allocations with the instrumentation compiled in).

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::JsonValue;

/// Number of [`SpanKind`] variants (sizes the accumulator arrays).
pub const SPAN_KINDS: usize = 18;

/// Everything a span can label: trainer step phases, the projected
/// optimizer's internal pipeline, comm internals, fault recovery and
/// the serve engine's admit/prefill/decode/retire lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One whole optimizer/trainer step.
    Step = 0,
    /// Forward + backward (gradient computation).
    Grad = 1,
    /// The per-step weight update (all matrices).
    Update = 2,
    /// Down-projection G → R (GaLore/Lotus hot path).
    Project = 3,
    /// Adam moment update in the subspace.
    OptStep = 4,
    /// Fused lift of the low-rank direction into the weight.
    Lift = 5,
    /// Tree all-reduce of a payload across workers.
    AllReduce = 6,
    /// Randomized-SVD subspace (re-)fit.
    RsvdRefresh = 7,
    /// Checkpoint save.
    Checkpoint = 8,
    /// Held-out perplexity evaluation.
    Eval = 9,
    /// Fault-recovery rollback to the last checkpoint.
    Rollback = 10,
    /// One point-to-point transfer inside the all-reduce.
    Transfer = 11,
    /// Checksum computation/verification of a transfer payload.
    ChecksumVerify = 12,
    /// Serve: admitting queued requests into lanes.
    Admit = 13,
    /// Serve: prompt prefill for freshly admitted lanes.
    Prefill = 14,
    /// Serve: batched incremental decode across busy lanes.
    Decode = 15,
    /// Serve: retiring completed/expired lanes.
    Retire = 16,
    /// Subspace-quality probe (`--probe-every`): capture ratio, residual
    /// energy, switch margin. Quarantined under its own kind so probe
    /// overhead never pollutes the training-phase wall times.
    Probe = 17,
}

/// All kinds in discriminant order (for snapshots and reports).
pub const ALL_KINDS: [SpanKind; SPAN_KINDS] = [
    SpanKind::Step,
    SpanKind::Grad,
    SpanKind::Update,
    SpanKind::Project,
    SpanKind::OptStep,
    SpanKind::Lift,
    SpanKind::AllReduce,
    SpanKind::RsvdRefresh,
    SpanKind::Checkpoint,
    SpanKind::Eval,
    SpanKind::Rollback,
    SpanKind::Transfer,
    SpanKind::ChecksumVerify,
    SpanKind::Admit,
    SpanKind::Prefill,
    SpanKind::Decode,
    SpanKind::Retire,
    SpanKind::Probe,
];

impl SpanKind {
    /// Stable name used in trace events, metrics records and reports.
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Step => "step",
            SpanKind::Grad => "grad",
            SpanKind::Update => "update",
            SpanKind::Project => "project",
            SpanKind::OptStep => "opt_step",
            SpanKind::Lift => "lift",
            SpanKind::AllReduce => "all_reduce",
            SpanKind::RsvdRefresh => "rsvd_refresh",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Eval => "eval",
            SpanKind::Rollback => "rollback",
            SpanKind::Transfer => "transfer",
            SpanKind::ChecksumVerify => "checksum_verify",
            SpanKind::Admit => "admit",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::Retire => "retire",
            SpanKind::Probe => "probe",
        }
    }
}

static SPANS_ON: AtomicBool = AtomicBool::new(false);
static TRACE_ON: AtomicBool = AtomicBool::new(false);
static PHASE_NS: [AtomicU64; SPAN_KINDS] = [const { AtomicU64::new(0) }; SPAN_KINDS];
static PHASE_COUNT: [AtomicU64; SPAN_KINDS] = [const { AtomicU64::new(0) }; SPAN_KINDS];
static TRACE: Mutex<Option<TraceBuf>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
    static LANE: Cell<u64> = const { Cell::new(0) };
}

/// Chrome-trace tid space for *logical* dist lanes: worker `w` renders
/// as tid `LANE_TID_BASE + w`, disjoint from the 1-based OS-thread tids
/// so a trace shows stable per-worker rows regardless of which pool
/// thread executed the shard.
pub const LANE_TID_BASE: u64 = 1000;

struct TraceBuf {
    path: String,
    events: Vec<TraceEvent>,
    /// Ring capacity: 0 means unbounded (classic full-trace mode);
    /// otherwise the buffer keeps the newest `cap` complete events.
    cap: usize,
    /// Next overwrite slot once the ring is full.
    head: usize,
}

impl TraceBuf {
    fn push(&mut self, ev: TraceEvent) {
        if self.cap == 0 || self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Events in chronological order (unwraps the ring when it filled).
    fn ordered(&self) -> impl Iterator<Item = &TraceEvent> {
        let (older, newer) = self.events.split_at(self.head);
        newer.iter().chain(older.iter())
    }
}

struct TraceEvent {
    kind: SpanKind,
    ts_us: u64,
    dur_us: u64,
    tid: u64,
}

impl TraceEvent {
    fn to_json(&self) -> JsonValue {
        JsonValue::obj(vec![
            ("name", JsonValue::str(self.kind.as_str())),
            ("cat", JsonValue::str("lotus")),
            ("ph", JsonValue::str("X")),
            ("pid", JsonValue::num(1)),
            ("tid", JsonValue::num(self.tid as f64)),
            ("ts", JsonValue::num(self.ts_us as f64)),
            ("dur", JsonValue::num(self.dur_us as f64)),
        ])
    }
}

/// Master switch for the span accumulators. [`install_trace`] and
/// metrics installation turn it on; benches toggle it directly.
pub fn set_spans_enabled(on: bool) {
    SPANS_ON.store(on, Ordering::Relaxed);
}

/// Whether spans record anything (one relaxed load — the entire
/// disabled-path cost of an instrumentation site).
#[inline]
pub fn spans_enabled() -> bool {
    SPANS_ON.load(Ordering::Relaxed)
}

/// Whether a Chrome trace buffer is installed.
pub fn tracing_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Install a Chrome trace buffer; [`finish_trace`] writes it to `path`.
/// Implies [`set_spans_enabled`]\(true).
pub fn install_trace(path: &str) {
    install_trace_with(path, 0);
}

/// [`install_trace`] with a ring capacity: `cap == 0` keeps every event
/// (the buffer grows with the run), `cap > 0` keeps only the newest
/// `cap` complete events — `--trace-mode ring --trace-cap N` for long
/// runs where a full trace would grow without bound.
pub fn install_trace_with(path: &str, cap: usize) {
    EPOCH.get_or_init(Instant::now);
    let mut buf = TRACE.lock().unwrap_or_else(|p| p.into_inner());
    *buf = Some(TraceBuf {
        path: path.to_string(),
        events: Vec::with_capacity(cap),
        cap,
        head: 0,
    });
    drop(buf);
    TRACE_ON.store(true, Ordering::Relaxed);
    SPANS_ON.store(true, Ordering::Relaxed);
}

/// Serialize and write the installed trace buffer (no-op when none is
/// installed). The output is a single `{"traceEvents": [...]}` document
/// of complete (`"ph": "X"`) events, loadable by Perfetto.
pub fn finish_trace() -> Result<(), String> {
    TRACE_ON.store(false, Ordering::Relaxed);
    let taken = TRACE.lock().unwrap_or_else(|p| p.into_inner()).take();
    let Some(buf) = taken else {
        return Ok(());
    };
    let events: Vec<JsonValue> = buf.ordered().map(TraceEvent::to_json).collect();
    let doc = JsonValue::obj(vec![("traceEvents", JsonValue::arr(events))]);
    std::fs::write(&buf.path, doc.to_string()).map_err(|e| format!("write {}: {e}", buf.path))
}

/// Cumulative per-kind span time in nanoseconds, indexed by
/// discriminant (relaxed loads; allocation-free).
pub fn phase_totals_ns() -> [u64; SPAN_KINDS] {
    let mut out = [0u64; SPAN_KINDS];
    for i in 0..SPAN_KINDS {
        out[i] = PHASE_NS[i].load(Ordering::Relaxed);
    }
    out
}

/// Cumulative per-kind span counts, indexed by discriminant.
pub fn phase_counts() -> [u64; SPAN_KINDS] {
    let mut out = [0u64; SPAN_KINDS];
    for i in 0..SPAN_KINDS {
        out[i] = PHASE_COUNT[i].load(Ordering::Relaxed);
    }
    out
}

/// Zero the per-kind accumulators (benches/tests).
pub fn reset_phases() {
    for i in 0..SPAN_KINDS {
        PHASE_NS[i].store(0, Ordering::Relaxed);
        PHASE_COUNT[i].store(0, Ordering::Relaxed);
    }
}

/// RAII guard from [`lane_scope`]; restores the previous lane tag on
/// drop so nested scopes compose.
pub struct LaneScope {
    prev: u64,
    active: bool,
}

/// While the returned guard lives, trace events recorded on this thread
/// carry the logical lane tid `LANE_TID_BASE + worker` instead of the
/// OS pool-thread tid. Free (no thread-local touch) when no trace
/// buffer is installed; never perturbs arithmetic or the span-time
/// accumulators.
#[inline]
pub fn lane_scope(worker: usize) -> LaneScope {
    if !TRACE_ON.load(Ordering::Relaxed) {
        return LaneScope { prev: 0, active: false };
    }
    let prev = LANE.with(|c| c.replace(LANE_TID_BASE + worker as u64));
    LaneScope { prev, active: true }
}

impl Drop for LaneScope {
    fn drop(&mut self) {
        if self.active {
            let prev = self.prev;
            LANE.with(|c| c.set(prev));
        }
    }
}

fn this_tid() -> u64 {
    TID.with(|c| {
        let v = c.get();
        if v != 0 {
            v
        } else {
            let n = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(n);
            n
        }
    })
}

/// A scoped timer: measures from construction to drop. When telemetry
/// is disabled the constructor takes no timestamp and drop is a no-op,
/// so instrumentation sites cost one atomic load on the untouched path.
pub struct Span {
    kind: SpanKind,
    start: Option<Instant>,
}

/// Open a span of `kind`; it closes (and records) when dropped.
#[inline]
pub fn span(kind: SpanKind) -> Span {
    if SPANS_ON.load(Ordering::Relaxed) {
        Span { kind, start: Some(Instant::now()) }
    } else {
        Span { kind, start: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else {
            return;
        };
        let dur = start.elapsed();
        let i = self.kind as usize;
        PHASE_NS[i].fetch_add(dur.as_nanos() as u64, Ordering::Relaxed);
        PHASE_COUNT[i].fetch_add(1, Ordering::Relaxed);
        if TRACE_ON.load(Ordering::Relaxed) {
            let epoch = *EPOCH.get_or_init(Instant::now);
            let lane = LANE.with(|c| c.get());
            let ev = TraceEvent {
                kind: self.kind,
                ts_us: start.saturating_duration_since(epoch).as_micros() as u64,
                dur_us: dur.as_micros() as u64,
                tid: if lane != 0 { lane } else { this_tid() },
            };
            if let Some(buf) = TRACE.lock().unwrap_or_else(|p| p.into_inner()).as_mut() {
                buf.push(ev);
            }
        }
    }
}
