//! Metrics primitives: lock-free counters/gauges and a fixed
//! log2-bucket histogram, all const-constructible so hot paths can hit
//! dedicated `static` instruments with zero registration cost, plus a
//! name-keyed [`Registry`] (a mutex is taken at *registration* only —
//! callers hold the returned `Arc` and update through plain atomics).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::JsonValue;

/// A monotonically increasing counter.
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// A last-write-wins gauge.
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of histogram buckets: bucket 0 holds exact zeros, bucket
/// `i ≥ 1` holds values in `[2^(i−1), 2^i − 1]`, up to `i = 64`.
pub const HIST_BUCKETS: usize = 65;

/// Fixed log2-bucket histogram over `u64` samples (latencies in ns,
/// payload bytes, …). Recording is two relaxed adds plus one relaxed
/// add into the bucket — no locking, no allocation, bounded memory.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Self {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Bucket index of a sample: 0 for 0, else `⌊log2 v⌋ + 1`.
    #[inline]
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    /// Inclusive `[lo, hi]` value range covered by bucket `i`.
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        if i == 0 {
            (0, 0)
        } else if i >= 64 {
            (1u64 << 63, u64::MAX)
        } else {
            (1u64 << (i - 1), (1u64 << i) - 1)
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }

    /// Upper bound of the bucket containing the `p`-th percentile
    /// sample (0 when empty). Bucketed, so accurate to a factor of 2 —
    /// enough for latency/byte distributions across decades.
    pub fn percentile_upper_bound(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((p / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for i in 0..HIST_BUCKETS {
            cum += self.bucket(i);
            if cum >= target {
                return Self::bucket_bounds(i).1;
            }
        }
        Self::bucket_bounds(HIST_BUCKETS - 1).1
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
    }

    /// JSON summary: count, sum, mean and the non-empty buckets keyed
    /// by their lower bound.
    pub fn to_json(&self) -> JsonValue {
        let mut buckets = Vec::new();
        for i in 0..HIST_BUCKETS {
            let c = self.bucket(i);
            if c > 0 {
                let (lo, _) = Self::bucket_bounds(i);
                buckets.push(JsonValue::obj(vec![
                    ("lo", JsonValue::num(lo as f64)),
                    ("count", JsonValue::num(c as f64)),
                ]));
            }
        }
        JsonValue::obj(vec![
            ("count", JsonValue::num(self.count() as f64)),
            ("sum", JsonValue::num(self.sum() as f64)),
            ("mean", JsonValue::num(self.mean())),
            ("p50_ub", JsonValue::num(self.percentile_upper_bound(50.0) as f64)),
            ("p99_ub", JsonValue::num(self.percentile_upper_bound(99.0) as f64)),
            ("buckets", JsonValue::arr(buckets)),
        ])
    }
}

/// Name-keyed instrument registry. `counter`/`gauge`/`histogram`
/// get-or-register under a mutex and hand back an `Arc` the caller
/// caches; steady-state updates never touch the registry again.
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub const fn new() -> Self {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.counters.lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())))
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.gauges.lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())))
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut map = self.histograms.lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(map.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())))
    }

    /// Snapshot every registered instrument as one JSON object (keys
    /// sorted — `BTreeMap` under the hood — so output is deterministic
    /// given deterministic registration).
    pub fn snapshot(&self) -> JsonValue {
        let counters = self.counters.lock().unwrap_or_else(|p| p.into_inner());
        let gauges = self.gauges.lock().unwrap_or_else(|p| p.into_inner());
        let histograms = self.histograms.lock().unwrap_or_else(|p| p.into_inner());
        let mut c = BTreeMap::new();
        for (k, v) in counters.iter() {
            c.insert(k.clone(), JsonValue::num(v.get() as f64));
        }
        let mut g = BTreeMap::new();
        for (k, v) in gauges.iter() {
            g.insert(k.clone(), JsonValue::num(v.get() as f64));
        }
        let mut h = BTreeMap::new();
        for (k, v) in histograms.iter() {
            h.insert(k.clone(), v.to_json());
        }
        JsonValue::obj(vec![
            ("counters", JsonValue::Obj(c)),
            ("gauges", JsonValue::Obj(g)),
            ("histograms", JsonValue::Obj(h)),
        ])
    }
}

/// The process-wide registry.
pub static REGISTRY: Registry = Registry::new();

/// Dedicated instruments for the comm hot path (`dist/comm.rs`
/// transfers record through these without a registry lookup).
pub static COMM_BYTES: Histogram = Histogram::new();
pub static COMM_RETRIES: Counter = Counter::new();

/// Quantized-wire accounting: bytes actually shipped by encoded
/// transfers vs the f32 bytes the same payloads represent. Recorded by
/// the quantized transfer path in `dist/comm.rs`; surfaced by
/// `lotus report --registry`.
pub static WIRE_QUANT_BYTES: Counter = Counter::new();
pub static WIRE_LOGICAL_BYTES: Counter = Counter::new();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(1023), 10);
        assert_eq!(Histogram::bucket_index(1024), 11);
        assert_eq!(Histogram::bucket_index(u64::MAX), 64);
    }

    #[test]
    fn bucket_bounds_partition_the_line() {
        let mut expect_lo = 0u64;
        for i in 0..HIST_BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert_eq!(lo, expect_lo, "bucket {i} lower bound");
            assert!(hi >= lo);
            // every value in [lo, hi] maps back to bucket i
            assert_eq!(Histogram::bucket_index(lo), i);
            assert_eq!(Histogram::bucket_index(hi), i);
            if hi == u64::MAX {
                assert_eq!(i, HIST_BUCKETS - 1);
                break;
            }
            expect_lo = hi + 1;
        }
    }

    #[test]
    fn histogram_counts_and_percentiles() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 100, 1000, 100_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum(), 101_106);
        assert_eq!(h.bucket(0), 1);
        assert_eq!(h.bucket(1), 1);
        assert_eq!(h.bucket(2), 2);
        // p100 lands in the bucket holding 100_000 = [65536, 131071]
        assert_eq!(h.percentile_upper_bound(100.0), 131_071);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_upper_bound(50.0), 0);
    }

    #[test]
    fn registry_get_or_register_is_stable() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.add(2);
        b.inc();
        assert_eq!(r.counter("x").get(), 3);
        r.gauge("g").set(7);
        assert_eq!(r.gauge("g").get(), 7);
        r.histogram("h").record(9);
        assert_eq!(r.histogram("h").count(), 1);
        let snap = r.snapshot();
        assert_eq!(snap.get("counters").get("x").as_f64(), Some(3.0));
        assert_eq!(snap.get("gauges").get("g").as_f64(), Some(7.0));
        assert_eq!(snap.get("histograms").get("h").get("count").as_f64(), Some(1.0));
    }
}
