//! Structured JSONL event sink (`--metrics-out metrics.jsonl`): one
//! JSON object per line, written through a buffered writer behind a
//! mutex. The enabled check is a single relaxed atomic load so
//! instrumentation sites can skip record *construction* entirely when
//! no sink is installed.
//!
//! Determinism contract: every wall-clock-dependent field of a record
//! lives under its `"wall"` key. Two identical seeded runs emit
//! byte-identical streams once `"wall"` (and free-text `"log"`
//! records) are stripped — `rust/tests/telemetry.rs` enforces this.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::json::JsonValue;

static METRICS_ON: AtomicBool = AtomicBool::new(false);
static METRICS: Mutex<Option<BufWriter<File>>> = Mutex::new(None);

/// Open `path` for JSONL metrics output (truncates). Implies the span
/// accumulators turn on so step records can carry phase timings.
pub fn install_metrics(path: &str) -> Result<(), String> {
    let f = File::create(path).map_err(|e| format!("create {path}: {e}"))?;
    *METRICS.lock().unwrap_or_else(|p| p.into_inner()) = Some(BufWriter::new(f));
    METRICS_ON.store(true, Ordering::Relaxed);
    super::span::set_spans_enabled(true);
    Ok(())
}

/// Whether a metrics sink is installed (one relaxed load).
#[inline]
pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

/// Append one record as a JSONL line (no-op when no sink is installed).
pub fn emit_record(v: &JsonValue) {
    let mut guard = METRICS.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(w) = guard.as_mut() {
        let line = v.to_string();
        let _ = w.write_all(line.as_bytes());
        let _ = w.write_all(b"\n");
    }
}

/// Forward a log line into the metrics stream (called by
/// `util/log.rs::emit` when a sink is installed).
pub fn log_record(level: &str, msg: &str) {
    if !metrics_enabled() {
        return;
    }
    emit_record(&JsonValue::obj(vec![
        ("type", JsonValue::str("log")),
        ("level", JsonValue::str(level)),
        ("msg", JsonValue::str(msg)),
    ]));
}

/// Flush and close the sink (no-op when none is installed).
pub fn finish_metrics() -> Result<(), String> {
    METRICS_ON.store(false, Ordering::Relaxed);
    let taken = METRICS.lock().unwrap_or_else(|p| p.into_inner()).take();
    if let Some(mut w) = taken {
        w.flush().map_err(|e| format!("flush metrics sink: {e}"))?;
    }
    Ok(())
}
