//! Low-overhead, thread-safe observability: a metrics registry
//! ([`metrics`]), an RAII span tracer emitting Chrome `trace_event`
//! JSON ([`span`]), and a structured JSONL event stream ([`sink`]) —
//! the runtime view of where a Lotus step's wall-clock goes (project
//! vs. Adam vs. lift vs. all-reduce vs. rSVD refresh) and how the
//! paper's displacement/switching dynamics behave over a run.
//!
//! Design rules:
//!
//! * **Disabled means free.** Every instrumentation site gates on one
//!   relaxed atomic load; with no sink installed there is no
//!   timestamp, no lock and no allocation (`tests/alloc_steady.rs`
//!   counts zero with instrumentation compiled in, and
//!   `benches/telemetry.rs` gates the *enabled* overhead at ≤ 2%).
//! * **Telemetry never perturbs arithmetic.** Instruments only read
//!   values the trainers already computed; the bit-determinism
//!   contracts (any `LOTUS_THREADS`, any worker count) are untouched.
//! * **Wall-clock is quarantined.** JSONL records nest every timing
//!   field under `"wall"` so seeded runs are byte-identical modulo
//!   that key.
//!
//! Lifecycle: the CLI calls [`init_from_cfg`] after config load
//! (`--trace-out` / `--metrics-out` / `[telemetry]`), trainers emit
//! through [`span()`] / [`emit_record`], and [`finish`] writes the
//! trace file and flushes the JSONL stream. `lotus report` digests the
//! artifacts offline ([`report`]).

pub mod analyze;
pub mod diag;
pub mod metrics;
pub mod report;
pub mod sink;
pub mod span;

pub use diag::{probe_step, probes_enabled, ProbeSample, ProbeState};
pub use metrics::{
    Counter, Gauge, Histogram, Registry, COMM_BYTES, COMM_RETRIES, REGISTRY, WIRE_LOGICAL_BYTES,
    WIRE_QUANT_BYTES,
};
pub use report::{
    check_metrics, check_trace, digest_metrics, render_registry, CheckError, ReportDigest,
};
pub use sink::{emit_record, install_metrics, log_record, metrics_enabled};
pub use span::{
    install_trace, install_trace_with, lane_scope, phase_counts, phase_totals_ns, reset_phases,
    set_spans_enabled, span, spans_enabled, tracing_enabled, LaneScope, Span, SpanKind, ALL_KINDS,
    LANE_TID_BASE, SPAN_KINDS,
};

use crate::config::schema::TelemetryCfg;
use crate::subspace::SwitchReason;
use crate::util::json::JsonValue;

/// Install the sinks a `[telemetry]` block / CLI overrides ask for.
pub fn init_from_cfg(t: &TelemetryCfg) -> Result<(), String> {
    if !t.metrics_out.is_empty() {
        sink::install_metrics(&t.metrics_out)?;
    }
    if !t.trace_out.is_empty() {
        if t.trace_mode == "ring" {
            let cap = if t.trace_cap == 0 { 4096 } else { t.trace_cap as usize };
            span::install_trace_with(&t.trace_out, cap);
        } else {
            span::install_trace(&t.trace_out);
        }
    }
    if !t.prom_out.is_empty() {
        diag::install_prom(&t.prom_out)
            .map_err(|e| format!("prom out {}: {e}", t.prom_out))?;
    }
    if t.probe_every > 0 {
        diag::set_probe_every(t.probe_every);
        diag::set_probes_enabled(true);
    }
    Ok(())
}

/// Write the trace file (if tracing), flush/close the JSONL sink, and
/// take a final prometheus snapshot. Leaves the span accumulators and
/// probes disabled. Safe to call when nothing is installed.
pub fn finish() -> Result<(), String> {
    if sink::metrics_enabled() {
        sink::emit_record(&registry_record());
    }
    diag::finish_prom();
    diag::set_probes_enabled(false);
    let trace = span::finish_trace();
    let metrics = sink::finish_metrics();
    span::set_spans_enabled(false);
    trace.and(metrics)
}

/// Trailing JSONL record carrying the full instrument state
/// ([`metrics::REGISTRY`] snapshot + the dedicated comm/wire statics),
/// rendered offline by `lotus report --registry`. The instruments are
/// process-cumulative (they outlive any single seeded run), so the
/// whole payload sits under the `"wall"` quarantine key like the
/// timing fields — seeded streams stay byte-identical modulo `"wall"`.
fn registry_record() -> JsonValue {
    JsonValue::obj(vec![
        ("type", JsonValue::str("registry")),
        (
            "wall",
            JsonValue::obj(vec![
                ("registry", metrics::REGISTRY.snapshot()),
                (
                    "comm",
                    JsonValue::obj(vec![
                        ("bytes_hist", metrics::COMM_BYTES.to_json()),
                        ("retries", JsonValue::num(metrics::COMM_RETRIES.get() as f64)),
                        (
                            "wire_quant_bytes",
                            JsonValue::num(metrics::WIRE_QUANT_BYTES.get() as f64),
                        ),
                        (
                            "wire_logical_bytes",
                            JsonValue::num(metrics::WIRE_LOGICAL_BYTES.get() as f64),
                        ),
                    ]),
                ),
            ]),
        ),
    ])
}

/// Stable lower-case name of a switch reason for metrics records and
/// the `lotus report` cadence table.
pub fn reason_str(r: SwitchReason) -> &'static str {
    match r {
        SwitchReason::Interval => "interval",
        SwitchReason::Displacement => "displacement",
        SwitchReason::PathEfficiency => "path_efficiency",
        SwitchReason::Init => "init",
    }
}

/// Per-kind span-time deltas between two [`phase_totals_ns`] snapshots
/// as a JSON object keyed by span name, including a kind when its
/// *count* advanced (so record shape is timing-independent). Used by
/// the trainers to attach a `"wall": {"phase_ns": ...}` block to each
/// step record.
pub fn phase_delta_json(
    ns_before: &[u64; SPAN_KINDS],
    counts_before: &[u64; SPAN_KINDS],
    ns_after: &[u64; SPAN_KINDS],
    counts_after: &[u64; SPAN_KINDS],
) -> JsonValue {
    let mut pairs = Vec::new();
    for (i, kind) in ALL_KINDS.iter().enumerate() {
        if counts_after[i] > counts_before[i] {
            let d = ns_after[i].saturating_sub(ns_before[i]);
            pairs.push((kind.as_str(), JsonValue::num(d as f64)));
        }
    }
    JsonValue::obj(vec![("phase_ns", JsonValue::obj(pairs))])
}
