//! Run-diagnostics probes and live Prometheus-text exposition.
//!
//! Online subspace-quality probes computed from quantities the projected
//! optimizers already hold: projection capture ratio `‖PᵀG‖F / ‖G‖F`,
//! residual energy `1 − capture²`, displacement-vs-threshold margin,
//! subspace age, and a gradient-noise-scale estimator (EMA
//! coefficient-of-variation of the per-matrix gradient norm). The probes
//! follow the telemetry contracts: a disabled probe site costs exactly one
//! relaxed atomic load, an enabled probe is allocation-free in steady state
//! (plain f64 field updates plus two Frobenius-norm passes), and probes
//! never perturb arithmetic — they only read values the optimizer already
//! computed, so seeded streams stay byte-identical modulo `"wall"`.
//!
//! The prometheus exposition (`--prom-out`) renders the metrics registry
//! plus the comm hot-path statics as Prometheus text and atomically
//! rewrites the snapshot file (write to `<path>.tmp`, then rename) on every
//! flush, so a tailing reader (`lotus top`) never observes a torn file.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::telemetry::metrics::{
    COMM_BYTES, COMM_RETRIES, REGISTRY, WIRE_LOGICAL_BYTES, WIRE_QUANT_BYTES,
};
use crate::util::json::JsonValue;

// ---------------------------------------------------------------------------
// Probe gating
// ---------------------------------------------------------------------------

static PROBES_ON: AtomicBool = AtomicBool::new(false);
static PROBE_EVERY: AtomicU64 = AtomicU64::new(1);

/// One relaxed load — the whole cost of a disabled probe site.
#[inline(always)]
pub fn probes_enabled() -> bool {
    PROBES_ON.load(Ordering::Relaxed)
}

pub fn set_probes_enabled(on: bool) {
    PROBES_ON.store(on, Ordering::Relaxed);
}

/// Sample probes every `k` steps (`k` is clamped to ≥ 1).
pub fn set_probe_every(k: u64) {
    PROBE_EVERY.store(k.max(1), Ordering::Relaxed);
}

pub fn probe_every() -> u64 {
    PROBE_EVERY.load(Ordering::Relaxed).max(1)
}

/// Should step `step` be probed? Short-circuits on the enable flag, so the
/// disabled path is still a single relaxed load.
#[inline(always)]
pub fn probe_step(step: u64) -> bool {
    probes_enabled() && step % probe_every() == 0
}

// ---------------------------------------------------------------------------
// Probe state + samples
// ---------------------------------------------------------------------------

/// Per-matrix probe accumulator held inside a projected optimizer. All
/// fields are plain `f64`/`u64` — observing is allocation-free.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProbeState {
    /// `‖G‖F²` at the last sampled step.
    pub g_norm_sq: f64,
    /// `‖PᵀG‖F²` at the last sampled step (under the subspace active
    /// *after* any switch taken at that step).
    pub low_norm_sq: f64,
    /// EMA of `‖G‖F` across sampled steps.
    pub ema_n: f64,
    /// EMA of `‖G‖F²` across sampled steps.
    pub ema_n2: f64,
    /// Number of samples observed.
    pub seen: u64,
}

impl ProbeState {
    /// EMA decay for the noise-scale estimator.
    pub const NOISE_BETA: f64 = 0.9;

    /// Record one sampled step. `g_norm_sq` / `low_norm_sq` are the squared
    /// Frobenius norms of the dense and projected gradient.
    #[inline]
    pub fn observe(&mut self, g_norm_sq: f64, low_norm_sq: f64) {
        self.g_norm_sq = g_norm_sq;
        self.low_norm_sq = low_norm_sq;
        let n = g_norm_sq.sqrt();
        if self.seen == 0 {
            self.ema_n = n;
            self.ema_n2 = n * n;
        } else {
            self.ema_n = Self::NOISE_BETA * self.ema_n + (1.0 - Self::NOISE_BETA) * n;
            self.ema_n2 = Self::NOISE_BETA * self.ema_n2 + (1.0 - Self::NOISE_BETA) * n * n;
        }
        self.seen += 1;
    }

    /// Gradient-noise-scale estimate: the EMA coefficient of variation
    /// `(E[n²] − E[n]²) / E[n]²` of the per-matrix gradient norm. Small
    /// values mean the gradient direction is stable (a long-lived subspace
    /// is cheap); large values mean the signal is noise-dominated.
    pub fn noise_scale(&self) -> f64 {
        if self.ema_n <= 0.0 {
            return 0.0;
        }
        ((self.ema_n2 - self.ema_n * self.ema_n) / (self.ema_n * self.ema_n)).max(0.0)
    }

    /// Build a sample from the last observation, or `None` before the first
    /// one (or on a zero gradient, where the ratio is undefined).
    pub fn sample(&self, age: u64, rank: usize, margin: Option<f64>) -> Option<ProbeSample> {
        if self.seen == 0 || self.g_norm_sq <= 0.0 {
            return None;
        }
        let energy = (self.low_norm_sq / self.g_norm_sq).clamp(0.0, 1.0);
        Some(ProbeSample {
            capture: energy.sqrt(),
            residual: 1.0 - energy,
            margin,
            age,
            rank,
            noise_scale: self.noise_scale(),
        })
    }
}

/// One subspace-quality sample for one (layer, matrix) slot.
#[derive(Clone, Copy, Debug)]
pub struct ProbeSample {
    /// Projection capture ratio `‖PᵀG‖F / ‖G‖F` ∈ [0, 1].
    pub capture: f64,
    /// Residual gradient energy `1 − capture²` ∈ [0, 1].
    pub residual: f64,
    /// `diagnostic − threshold` for the active switch policy (negative
    /// means the policy is inside its switch region). `None` for policies
    /// without a scalar threshold.
    pub margin: Option<f64>,
    /// Steps since the subspace was last refit.
    pub age: u64,
    /// Current projection rank.
    pub rank: usize,
    /// Gradient-noise-scale estimate (see [`ProbeState::noise_scale`]).
    pub noise_scale: f64,
}

impl ProbeSample {
    /// The typed JSONL record for this sample. `margin` renders as `null`
    /// for threshold-free policies so the record shape is stable.
    pub fn to_record(&self, step: u64, layer: usize, mat: &str) -> JsonValue {
        JsonValue::obj(vec![
            ("type", JsonValue::str("probe")),
            ("step", JsonValue::num(step as f64)),
            ("layer", JsonValue::num(layer as f64)),
            ("mat", JsonValue::str(mat)),
            ("capture", JsonValue::num(self.capture)),
            ("residual", JsonValue::num(self.residual)),
            (
                "margin",
                match self.margin {
                    Some(m) => JsonValue::num(m),
                    None => JsonValue::Null,
                },
            ),
            ("age", JsonValue::num(self.age as f64)),
            ("rank", JsonValue::num(self.rank as f64)),
            ("noise_scale", JsonValue::num(self.noise_scale)),
        ])
    }

    /// Publish this sample as fixed-point registry gauges (`Gauge` stores
    /// `u64`; ratios are scaled to micro-units).
    pub fn set_gauges(&self, layer: usize, mat: &str) {
        REGISTRY
            .gauge(&format!("diag.capture_micro.L{layer}.{mat}"))
            .set(micro(self.capture));
        REGISTRY
            .gauge(&format!("diag.residual_micro.L{layer}.{mat}"))
            .set(micro(self.residual));
        REGISTRY
            .gauge(&format!("diag.noise_micro.L{layer}.{mat}"))
            .set(micro(self.noise_scale));
        REGISTRY.gauge(&format!("diag.age.L{layer}.{mat}")).set(self.age);
    }
}

/// Fixed-point scaling for `u64` gauges: 1.0 → 1_000_000.
pub fn micro(x: f64) -> u64 {
    (x.max(0.0) * 1e6).round() as u64
}

// ---------------------------------------------------------------------------
// Prometheus-text exposition
// ---------------------------------------------------------------------------

static PROM_ON: AtomicBool = AtomicBool::new(false);
static PROM: Mutex<Option<String>> = Mutex::new(None);

/// Install the prometheus snapshot file. The parent directory must exist;
/// the file is (re)written atomically on every [`flush_prom`].
pub fn install_prom(path: &str) -> std::io::Result<()> {
    write_atomic(path, &render_prom())?;
    *PROM.lock().unwrap() = Some(path.to_string());
    PROM_ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// One relaxed load — the whole cost of a disabled flush site.
#[inline(always)]
pub fn prom_enabled() -> bool {
    PROM_ON.load(Ordering::Relaxed)
}

/// Atomically rewrite the snapshot file with the current registry state.
/// I/O errors are swallowed (exposition must never kill a training run).
pub fn flush_prom() {
    if !prom_enabled() {
        return;
    }
    let guard = PROM.lock().unwrap();
    if let Some(path) = guard.as_ref() {
        let _ = write_atomic(path, &render_prom());
    }
}

/// Final flush + disable (called from `telemetry::finish`).
pub fn finish_prom() {
    flush_prom();
    PROM_ON.store(false, Ordering::Relaxed);
    *PROM.lock().unwrap() = None;
}

fn write_atomic(path: &str, text: &str) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Render the registry + comm statics as Prometheus text exposition.
/// Names are prefixed `lotus_` with dots mapped to underscores; histograms
/// expand to cumulative `_bucket{le="…"}` series plus `_sum` / `_count`.
pub fn render_prom() -> String {
    let mut out = String::new();
    let snap = REGISTRY.snapshot();
    if let Some(counters) = snap.get("counters").as_obj() {
        for (name, v) in counters {
            prom_line(&mut out, name, "counter", v.as_f64().unwrap_or(0.0));
        }
    }
    if let Some(gauges) = snap.get("gauges").as_obj() {
        for (name, v) in gauges {
            prom_line(&mut out, name, "gauge", v.as_f64().unwrap_or(0.0));
        }
    }
    if let Some(hists) = snap.get("histograms").as_obj() {
        for (name, h) in hists {
            prom_hist(&mut out, name, h);
        }
    }
    prom_line(&mut out, "comm.retries", "counter", COMM_RETRIES.get() as f64);
    prom_line(&mut out, "wire.quant_bytes", "counter", WIRE_QUANT_BYTES.get() as f64);
    prom_line(&mut out, "wire.logical_bytes", "counter", WIRE_LOGICAL_BYTES.get() as f64);
    prom_hist(&mut out, "comm.bytes", &COMM_BYTES.to_json());
    out
}

fn prom_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 6);
    s.push_str("lotus_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            s.push(c);
        } else {
            s.push('_');
        }
    }
    s
}

fn prom_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn prom_line(out: &mut String, name: &str, kind: &str, value: f64) {
    let n = prom_name(name);
    out.push_str(&format!("# TYPE {n} {kind}\n{n} {}\n", prom_num(value)));
}

/// Standard Prometheus histogram exposition from a [`Histogram::to_json`]
/// summary: one `# TYPE … histogram` header, a cumulative `_bucket` series
/// over the occupied log2 buckets (upper bound `2·lo − 1`, or `0` for the
/// zero bucket) closed by `le="+Inf"`, then `_sum` and `_count`.
fn prom_hist(out: &mut String, name: &str, h: &JsonValue) {
    let n = prom_name(name);
    let count = h.get("count").as_f64().unwrap_or(0.0);
    let sum = h.get("sum").as_f64().unwrap_or(0.0);
    out.push_str(&format!("# TYPE {n} histogram\n"));
    let mut cum = 0.0;
    if let Some(buckets) = h.get("buckets").as_arr() {
        for b in buckets {
            let lo = b.get("lo").as_f64().unwrap_or(0.0);
            cum += b.get("count").as_f64().unwrap_or(0.0);
            let le = if lo == 0.0 { "0".to_string() } else { prom_num(2.0 * lo - 1.0) };
            out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {}\n", prom_num(cum)));
        }
    }
    out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", prom_num(count)));
    out.push_str(&format!("{n}_sum {}\n", prom_num(sum)));
    out.push_str(&format!("{n}_count {}\n", prom_num(count)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_state_capture_and_residual() {
        let mut p = ProbeState::default();
        assert!(p.sample(0, 8, None).is_none());
        p.observe(4.0, 1.0); // capture² = 0.25
        let s = p.sample(3, 8, Some(-0.1)).unwrap();
        assert!((s.capture - 0.5).abs() < 1e-12);
        assert!((s.residual - 0.75).abs() < 1e-12);
        assert_eq!(s.age, 3);
        assert_eq!(s.rank, 8);
        assert_eq!(s.margin, Some(-0.1));
    }

    #[test]
    fn noise_scale_is_zero_for_constant_norms_and_positive_for_varying() {
        let mut p = ProbeState::default();
        for _ in 0..20 {
            p.observe(9.0, 4.0);
        }
        assert!(p.noise_scale() < 1e-12);
        let mut q = ProbeState::default();
        for i in 0..20 {
            let n = if i % 2 == 0 { 1.0 } else { 4.0 };
            q.observe(n * n, 0.5);
        }
        assert!(q.noise_scale() > 0.01);
    }

    #[test]
    fn zero_gradient_yields_no_sample() {
        let mut p = ProbeState::default();
        p.observe(0.0, 0.0);
        assert!(p.sample(1, 4, None).is_none());
    }

    #[test]
    fn probe_record_shape() {
        let mut p = ProbeState::default();
        p.observe(1.0, 1.0);
        let s = p.sample(2, 4, None).unwrap();
        let r = s.to_record(7, 1, "wq");
        assert_eq!(r.get("type").as_str(), Some("probe"));
        assert_eq!(r.get("step").as_f64(), Some(7.0));
        assert_eq!(r.get("mat").as_str(), Some("wq"));
        assert_eq!(r.get("margin"), &JsonValue::Null);
        assert!((r.get("capture").as_f64().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn probe_step_respects_interval() {
        set_probes_enabled(true);
        set_probe_every(5);
        assert!(probe_step(10));
        assert!(!probe_step(11));
        set_probe_every(1);
        set_probes_enabled(false);
        assert!(!probe_step(10));
    }

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("diag.capture_micro.L0.wq"), "lotus_diag_capture_micro_L0_wq");
    }

    #[test]
    fn render_prom_includes_comm_statics() {
        let text = render_prom();
        assert!(text.contains("# TYPE lotus_comm_retries counter"));
        assert!(text.contains("# TYPE lotus_comm_bytes histogram"));
        assert!(text.contains("lotus_comm_bytes_bucket{le=\"+Inf\"} "));
        assert!(text.contains("lotus_comm_bytes_sum "));
        assert!(text.contains("lotus_comm_bytes_count "));
        assert!(text.contains("lotus_wire_quant_bytes "));
    }

    #[test]
    fn prom_hist_emits_cumulative_buckets() {
        use crate::telemetry::metrics::Histogram;
        let h = Histogram::new();
        h.record(0); // le="0"
        h.record(3); // bucket [2,3] → le="3"
        h.record(3);
        h.record(100); // bucket [64,127] → le="127"
        let mut out = String::new();
        prom_hist(&mut out, "q.lat", &h.to_json());
        let want = "# TYPE lotus_q_lat histogram\n\
                    lotus_q_lat_bucket{le=\"0\"} 1\n\
                    lotus_q_lat_bucket{le=\"3\"} 3\n\
                    lotus_q_lat_bucket{le=\"127\"} 4\n\
                    lotus_q_lat_bucket{le=\"+Inf\"} 4\n\
                    lotus_q_lat_sum 106\n\
                    lotus_q_lat_count 4\n";
        assert_eq!(out, want);
        // the cumulative series still round-trips through the text parser
        let parsed = crate::telemetry::analyze::parse_prom_text(&out).unwrap();
        assert_eq!(parsed.len(), 6);
        assert_eq!(parsed[3], ("lotus_q_lat_bucket{le=\"+Inf\"}".to_string(), 4.0));
    }
}
