//! Offline cross-run analysis of metrics JSONL streams (`lotus analyze`),
//! bench-trend diffs (`lotus analyze --bench`), and the parser/renderer
//! behind `lotus top`'s live view of a `--prom-out` snapshot.
//!
//! Everything here is a pure function of the artifact text, so the tables
//! inherit the stream's determinism contract: seeded runs are
//! byte-identical modulo the quarantined `"wall"` key, and no table below
//! reads wall-clock fields except the explicitly timing-flavoured
//! per-phase rows of the run-vs-run comparison.

use std::collections::BTreeMap;

use crate::util::fmt::Table;
use crate::util::json::{self, JsonValue};

/// One `type == "step"` (or `dist_step`) record.
pub struct StepRec {
    pub step: u64,
    pub loss: f64,
}

/// One subspace-switch event, stamped with the step it fired on.
pub struct SwitchRec {
    pub step: u64,
    pub layer: u64,
    pub mat: String,
    pub reason: String,
    pub lifetime: u64,
    pub rank: u64,
}

/// One `type == "probe"` record (see `telemetry::diag`).
pub struct ProbeRec {
    pub step: u64,
    pub layer: u64,
    pub mat: String,
    pub capture: f64,
    pub residual: f64,
    pub margin: Option<f64>,
    pub age: u64,
    pub rank: u64,
    pub noise_scale: f64,
}

/// Parsed view of one metrics JSONL stream.
pub struct RunData {
    pub steps: Vec<StepRec>,
    pub switches: Vec<SwitchRec>,
    pub probes: Vec<ProbeRec>,
    /// `(step, pre-clip grad norm)` from `type == "clipped"` records.
    pub clipped: Vec<(u64, f64)>,
    /// Per-phase wall nanoseconds summed across all records.
    pub phase_ns: BTreeMap<String, f64>,
    /// The trailing `type == "registry"` record, if the stream has one.
    pub registry: Option<JsonValue>,
    /// Total records of any type.
    pub records: usize,
}

impl RunData {
    /// Trapezoidal loss area under the curve over recorded steps — a
    /// scalar "how fast did it learn" summary for run-vs-run deltas.
    pub fn loss_auc(&self) -> f64 {
        let mut auc = 0.0;
        for w in self.steps.windows(2) {
            let ds = (w[1].step - w[0].step) as f64;
            auc += 0.5 * (w[0].loss + w[1].loss) * ds;
        }
        auc
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.steps.last().map(|s| s.loss)
    }
}

/// Parse a metrics JSONL stream into a [`RunData`].
pub fn parse_run(text: &str) -> Result<RunData, String> {
    let mut run = RunData {
        steps: Vec::new(),
        switches: Vec::new(),
        probes: Vec::new(),
        clipped: Vec::new(),
        phase_ns: BTreeMap::new(),
        registry: None,
        records: 0,
    };
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("metrics line {}: {e}", ln + 1))?;
        run.records += 1;
        if let Some(obj) = v.get("wall").get("phase_ns").as_obj() {
            for (k, x) in obj {
                if let Some(ns) = x.as_f64() {
                    *run.phase_ns.entry(k.clone()).or_insert(0.0) += ns;
                }
            }
        }
        match v.get("type").as_str() {
            Some("step") | Some("dist_step") => {
                let step = v.get("step").as_f64().unwrap_or(0.0) as u64;
                if let Some(loss) = v.get("loss").as_f64() {
                    run.steps.push(StepRec { step, loss });
                }
                if let Some(sw) = v.get("switches").as_arr() {
                    for s in sw {
                        run.switches.push(SwitchRec {
                            step,
                            layer: s.get("layer").as_f64().unwrap_or(0.0) as u64,
                            mat: s.get("mat").as_str().unwrap_or("?").to_string(),
                            reason: s.get("reason").as_str().unwrap_or("?").to_string(),
                            lifetime: s.get("lifetime").as_f64().unwrap_or(0.0) as u64,
                            rank: s.get("rank").as_f64().unwrap_or(0.0) as u64,
                        });
                    }
                }
            }
            Some("probe") => {
                run.probes.push(ProbeRec {
                    step: v.get("step").as_f64().unwrap_or(0.0) as u64,
                    layer: v.get("layer").as_f64().unwrap_or(0.0) as u64,
                    mat: v.get("mat").as_str().unwrap_or("?").to_string(),
                    capture: v.get("capture").as_f64().unwrap_or(0.0),
                    residual: v.get("residual").as_f64().unwrap_or(0.0),
                    margin: v.get("margin").as_f64(),
                    age: v.get("age").as_f64().unwrap_or(0.0) as u64,
                    rank: v.get("rank").as_f64().unwrap_or(0.0) as u64,
                    noise_scale: v.get("noise_scale").as_f64().unwrap_or(0.0),
                });
            }
            Some("clipped") => {
                run.clipped.push((
                    v.get("step").as_f64().unwrap_or(0.0) as u64,
                    v.get("grad_norm").as_f64().unwrap_or(0.0),
                ));
            }
            Some("registry") => run.registry = Some(v),
            _ => {}
        }
    }
    Ok(run)
}

fn fmt_opt(x: Option<f64>, prec: usize) -> String {
    match x {
        Some(v) => format!("{v:+.prec$}"),
        None => "-".to_string(),
    }
}

/// Per-switch quality table: for every switch event, the capture ratio at
/// the last probe *before* the switch step (the dying subspace), the first
/// probe *at or after* it (the fresh one), and the displacement margin
/// just before it fired.
pub fn switch_quality_table(run: &RunData) -> String {
    let mut t = Table::new(&[
        "step", "layer", "mat", "reason", "lifetime", "rank", "cap_pre", "cap_post", "margin_pre",
    ]);
    for sw in &run.switches {
        let slot = |p: &&ProbeRec| p.layer == sw.layer && p.mat == sw.mat;
        let pre = run.probes.iter().filter(|p| slot(p) && p.step < sw.step).next_back();
        let post = run.probes.iter().find(|p| slot(p) && p.step >= sw.step);
        t.row(&[
            sw.step.to_string(),
            sw.layer.to_string(),
            sw.mat.clone(),
            sw.reason.clone(),
            sw.lifetime.to_string(),
            sw.rank.to_string(),
            pre.map(|p| format!("{:.4}", p.capture)).unwrap_or_else(|| "-".into()),
            post.map(|p| format!("{:.4}", p.capture)).unwrap_or_else(|| "-".into()),
            fmt_opt(pre.and_then(|p| p.margin), 4),
        ]);
    }
    t.render()
}

/// Switch cadence vs threshold margin, aggregated per reason: how often
/// each trigger fires, how long subspaces live under it, how far inside
/// the switch region the criterion was (mean pre-switch margin), and how
/// good the replacement subspace is (mean post-switch capture).
pub fn cadence_table(run: &RunData) -> String {
    struct Agg {
        count: u64,
        lifetime: f64,
        margin: f64,
        margin_n: u64,
        cap_post: f64,
        cap_post_n: u64,
    }
    let mut agg: BTreeMap<String, Agg> = BTreeMap::new();
    for sw in &run.switches {
        let slot = |p: &&ProbeRec| p.layer == sw.layer && p.mat == sw.mat;
        let pre = run.probes.iter().filter(|p| slot(p) && p.step < sw.step).next_back();
        let post = run.probes.iter().find(|p| slot(p) && p.step >= sw.step);
        let e = agg.entry(sw.reason.clone()).or_insert(Agg {
            count: 0,
            lifetime: 0.0,
            margin: 0.0,
            margin_n: 0,
            cap_post: 0.0,
            cap_post_n: 0,
        });
        e.count += 1;
        e.lifetime += sw.lifetime as f64;
        if let Some(m) = pre.and_then(|p| p.margin) {
            e.margin += m;
            e.margin_n += 1;
        }
        if let Some(p) = post {
            e.cap_post += p.capture;
            e.cap_post_n += 1;
        }
    }
    let mut t =
        Table::new(&["reason", "switches", "mean_lifetime", "mean_margin_pre", "mean_cap_post"]);
    for (reason, a) in &agg {
        t.row(&[
            reason.clone(),
            a.count.to_string(),
            format!("{:.1}", a.lifetime / a.count.max(1) as f64),
            if a.margin_n > 0 {
                format!("{:+.4}", a.margin / a.margin_n as f64)
            } else {
                "-".to_string()
            },
            if a.cap_post_n > 0 {
                format!("{:.4}", a.cap_post / a.cap_post_n as f64)
            } else {
                "-".to_string()
            },
        ]);
    }
    t.render()
}

/// Per-(layer, matrix) probe summary across the whole run.
pub fn probe_table(run: &RunData) -> String {
    struct Agg {
        n: u64,
        cap_sum: f64,
        cap_min: f64,
        res_sum: f64,
        noise_last: f64,
        age_last: u64,
    }
    let mut agg: BTreeMap<(u64, String), Agg> = BTreeMap::new();
    for p in &run.probes {
        let e = agg.entry((p.layer, p.mat.clone())).or_insert(Agg {
            n: 0,
            cap_sum: 0.0,
            cap_min: f64::INFINITY,
            res_sum: 0.0,
            noise_last: 0.0,
            age_last: 0,
        });
        e.n += 1;
        e.cap_sum += p.capture;
        e.cap_min = e.cap_min.min(p.capture);
        e.res_sum += p.residual;
        e.noise_last = p.noise_scale;
        e.age_last = p.age;
    }
    let mut t = Table::new(&[
        "layer", "mat", "probes", "cap_mean", "cap_min", "res_mean", "noise_last", "age_last",
    ]);
    for ((layer, mat), a) in &agg {
        let n = a.n.max(1) as f64;
        t.row(&[
            layer.to_string(),
            mat.clone(),
            a.n.to_string(),
            format!("{:.4}", a.cap_sum / n),
            format!("{:.4}", a.cap_min),
            format!("{:.4}", a.res_sum / n),
            format!("{:.4}", a.noise_last),
            a.age_last.to_string(),
        ]);
    }
    t.render()
}

/// Heuristic anomaly flags over one run. Each flag is a one-line human
/// sentence; an empty vec means nothing looked off.
pub fn anomaly_flags(run: &RunData) -> Vec<String> {
    let mut flags = Vec::new();
    for w in run.steps.windows(2) {
        if w[0].loss.is_finite() && w[0].loss > 0.0 && w[1].loss > 2.0 * w[0].loss {
            flags.push(format!(
                "loss spike at step {}: {:.4} -> {:.4}",
                w[1].step, w[0].loss, w[1].loss
            ));
        }
    }
    if let Some(p) = run.probes.iter().find(|p| p.capture < 0.25) {
        let n = run.probes.iter().filter(|p| p.capture < 0.25).count();
        flags.push(format!(
            "capture collapse (<0.25) on {n} probe(s), first at step {} L{}/{}",
            p.step, p.layer, p.mat
        ));
    }
    // Criterion-fired-late detector: consecutive probes sitting inside the
    // switch region (margin < 0) with no switch between them mean the
    // policy wanted to switch but something (t_min, consensus) held it.
    let mut slots: BTreeMap<(u64, String), (u64, u64)> = BTreeMap::new(); // run length, first step
    let mut worst: Option<(u64, u64, u64, String)> = None; // (len, first step, layer, mat)
    for p in &run.probes {
        let key = (p.layer, p.mat.clone());
        let switched =
            run.switches.iter().any(|s| s.layer == p.layer && s.mat == p.mat && s.step == p.step);
        let entry = slots.entry(key.clone()).or_insert((0, p.step));
        if p.margin.map(|m| m < 0.0).unwrap_or(false) && !switched {
            if entry.0 == 0 {
                entry.1 = p.step;
            }
            entry.0 += 1;
            if worst.as_ref().map(|w| entry.0 > w.0).unwrap_or(true) {
                worst = Some((entry.0, entry.1, p.layer, p.mat.clone()));
            }
        } else {
            entry.0 = 0;
        }
    }
    if let Some((len, first, layer, mat)) = worst {
        if len >= 3 {
            flags.push(format!(
                "switch criterion eligible for {len} consecutive probes without firing at \
                 L{layer}/{mat} from step {first} (t_min or consensus gating?)"
            ));
        }
    }
    let noisy = run.probes.iter().filter(|p| p.noise_scale > 1.0).count();
    if noisy > 0 {
        flags.push(format!("gradient noise scale > 1.0 on {noisy} probe(s) (noise-dominated)"));
    }
    if !run.clipped.is_empty() {
        let max = run.clipped.iter().map(|c| c.1).fold(0.0f64, f64::max);
        flags.push(format!(
            "gradient clipped on {} step(s), max pre-clip norm {:.4}",
            run.clipped.len(),
            max
        ));
    }
    if run.registry.is_none() {
        flags.push("no trailing registry record (stream truncated or emitter killed?)".into());
    }
    flags
}

fn registry_leaf(run: &RunData, path: &[&str]) -> Option<f64> {
    let mut v = run.registry.as_ref()?.get("wall");
    for k in path {
        v = v.get(k);
    }
    v.as_f64()
}

fn fmt_val(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.3}")
    }
}

fn delta_pct(a: f64, b: f64) -> String {
    if b == 0.0 {
        "-".to_string()
    } else {
        format!("{:+.1}%", 100.0 * (a - b) / b)
    }
}

/// Run-vs-run comparison: loss AUC, final loss, switch/probe/clip counts,
/// wire bytes (from the trailing registry records) and per-phase wall time.
/// The phase rows are the only timing-derived cells in this module.
pub fn compare_table(run: &RunData, base: &RunData) -> String {
    let mut t = Table::new(&["metric", "run", "baseline", "delta"]);
    let mut row = |name: &str, a: Option<f64>, b: Option<f64>| {
        t.row(&[
            name.to_string(),
            a.map(fmt_val).unwrap_or_else(|| "-".into()),
            b.map(fmt_val).unwrap_or_else(|| "-".into()),
            match (a, b) {
                (Some(a), Some(b)) => delta_pct(a, b),
                _ => "-".to_string(),
            },
        ]);
    };
    row("steps", Some(run.steps.len() as f64), Some(base.steps.len() as f64));
    row("final_loss", run.final_loss(), base.final_loss());
    row("loss_auc", Some(run.loss_auc()), Some(base.loss_auc()));
    row("switches", Some(run.switches.len() as f64), Some(base.switches.len() as f64));
    row("probes", Some(run.probes.len() as f64), Some(base.probes.len() as f64));
    row("clipped_steps", Some(run.clipped.len() as f64), Some(base.clipped.len() as f64));
    for path in [
        &["comm", "wire_quant_bytes"][..],
        &["comm", "wire_logical_bytes"][..],
        &["comm", "bytes_hist", "sum"][..],
    ] {
        row(&path.join("."), registry_leaf(run, path), registry_leaf(base, path));
    }
    let mut kinds: Vec<&String> = run.phase_ns.keys().chain(base.phase_ns.keys()).collect();
    kinds.sort();
    kinds.dedup();
    for k in kinds {
        row(
            &format!("phase.{k}_ms"),
            run.phase_ns.get(k).map(|ns| ns / 1e6),
            base.phase_ns.get(k).map(|ns| ns / 1e6),
        );
    }
    t.render()
}

/// Diff two `BENCH_*.json` artifacts leaf-by-leaf (`lotus analyze --bench`).
/// Returns the rendered table plus regression flags for timing-flavoured
/// keys (`*_s`, `*_pct`, `*_ns`) that moved more than 10% the wrong way —
/// the CI trend step prints these without gating.
pub fn bench_diff(fresh: &JsonValue, base: &JsonValue) -> (String, Vec<String>) {
    let mut fa = Vec::new();
    let mut ba = Vec::new();
    super::report::flatten_numeric("", fresh, &mut fa);
    super::report::flatten_numeric("", base, &mut ba);
    let bmap: BTreeMap<&str, f64> = ba.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let fmap: BTreeMap<&str, f64> = fa.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let mut keys: Vec<&str> = fmap.keys().chain(bmap.keys()).copied().collect();
    keys.sort_unstable();
    keys.dedup();
    let mut t = Table::new(&["key", "fresh", "baseline", "delta"]);
    let mut flags = Vec::new();
    for k in keys {
        let f = fmap.get(k).copied();
        let b = bmap.get(k).copied();
        t.row(&[
            k.to_string(),
            f.map(fmt_val).unwrap_or_else(|| "-".into()),
            b.map(fmt_val).unwrap_or_else(|| "-".into()),
            match (f, b) {
                (Some(f), Some(b)) => delta_pct(f, b),
                _ => "-".to_string(),
            },
        ]);
        let timing = k.ends_with("_s") || k.ends_with("_pct") || k.ends_with("_ns");
        if let (Some(f), Some(b)) = (f, b) {
            if timing && b > 0.0 && f > 1.1 * b {
                flags.push(format!("{k} regressed {:.1}% ({} -> {})",
                    100.0 * (f - b) / b, fmt_val(b), fmt_val(f)));
            }
        }
    }
    (t.render(), flags)
}

// ---------------------------------------------------------------------------
// Prometheus-text parsing + the `lotus top` view
// ---------------------------------------------------------------------------

/// Parse Prometheus text exposition into ordered `(name, value)` pairs.
/// Comment/`# TYPE` lines are skipped; malformed sample lines are errors.
pub fn parse_prom_text(text: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, val) = line
            .split_once(' ')
            .ok_or_else(|| format!("prom line {}: no value", ln + 1))?;
        let v: f64 =
            val.trim().parse().map_err(|e| format!("prom line {}: bad value: {e}", ln + 1))?;
        out.push((name.to_string(), v));
    }
    Ok(out)
}

/// Render the `lotus top` screen from a parsed prom snapshot: a headline
/// line (loss, comm bytes, serve queue) plus a per-layer table aggregating
/// the diag gauges over each layer's matrices.
pub fn render_top(prom: &[(String, f64)]) -> String {
    let map: BTreeMap<&str, f64> = prom.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let mut out = String::new();
    let mut headline = Vec::new();
    if let Some(l) = map.get("lotus_train_loss_micro") {
        headline.push(format!("loss {:.4}", l / 1e6));
    }
    if let Some(s) = map.get("lotus_train_step") {
        headline.push(format!("step {}", *s as u64));
    }
    if let Some(b) = map.get("lotus_comm_bytes_sum") {
        headline.push(format!("comm {}", crate::util::fmt::bytes(*b as u64)));
    }
    if let (Some(q), Some(a)) = (map.get("lotus_serve_queued"), map.get("lotus_serve_active")) {
        headline.push(format!("serve q={} active={}", *q as u64, *a as u64));
    }
    if !headline.is_empty() {
        out.push_str(&headline.join("  |  "));
        out.push('\n');
    }
    // layer -> (capture sum, capture min, n, age max, noise sum)
    let mut layers: BTreeMap<u64, (f64, f64, u64, u64, f64)> = BTreeMap::new();
    for (k, v) in prom {
        if let Some(rest) = k.strip_prefix("lotus_diag_capture_micro_L") {
            if let Some((li, _mat)) = rest.split_once('_') {
                if let Ok(li) = li.parse::<u64>() {
                    let e = layers.entry(li).or_insert((0.0, f64::INFINITY, 0, 0, 0.0));
                    e.0 += v / 1e6;
                    e.1 = e.1.min(v / 1e6);
                    e.2 += 1;
                }
            }
        } else if let Some(rest) = k.strip_prefix("lotus_diag_age_L") {
            if let Some((li, _mat)) = rest.split_once('_') {
                if let Ok(li) = li.parse::<u64>() {
                    let e = layers.entry(li).or_insert((0.0, f64::INFINITY, 0, 0, 0.0));
                    e.3 = e.3.max(*v as u64);
                }
            }
        } else if let Some(rest) = k.strip_prefix("lotus_diag_noise_micro_L") {
            if let Some((li, _mat)) = rest.split_once('_') {
                if let Ok(li) = li.parse::<u64>() {
                    let e = layers.entry(li).or_insert((0.0, f64::INFINITY, 0, 0, 0.0));
                    e.4 += v / 1e6;
                }
            }
        }
    }
    if !layers.is_empty() {
        let mut t = Table::new(&["layer", "cap_mean", "cap_min", "age_max", "noise_mean"]);
        for (li, (sum, min, n, age, noise)) in &layers {
            let n_f = (*n).max(1) as f64;
            t.row(&[
                format!("L{li}"),
                format!("{:.4}", sum / n_f),
                if min.is_finite() { format!("{min:.4}") } else { "-".into() },
                age.to_string(),
                format!("{:.4}", noise / n_f),
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe_line(step: u64, layer: u64, mat: &str, capture: f64, margin: Option<f64>) -> String {
        let m = margin.map(|m| m.to_string()).unwrap_or_else(|| "null".into());
        format!(
            r#"{{"type":"probe","step":{step},"layer":{layer},"mat":"{mat}","capture":{capture},"residual":{:.2},"margin":{m},"age":3,"rank":16,"noise_scale":0.1}}"#,
            1.0 - capture * capture
        )
    }

    fn step_line(step: u64, loss: f64, switches: &str) -> String {
        format!(r#"{{"type":"step","step":{step},"loss":{loss},"switches":[{switches}]}}"#)
    }

    fn sample_run() -> RunData {
        let sw = r#"{"layer":0,"mat":"wq","reason":"displacement","lifetime":10,"rank":16}"#;
        let text = [
            probe_line(1, 0, "wq", 0.9, Some(0.2)),
            step_line(1, 4.0, ""),
            probe_line(2, 0, "wq", 0.6, Some(-0.05)),
            step_line(2, 3.5, ""),
            probe_line(3, 0, "wq", 0.95, Some(0.15)),
            step_line(3, 3.0, sw),
            r#"{"type":"registry","wall":{"comm":{"wire_quant_bytes":100,"wire_logical_bytes":400,"bytes_hist":{"sum":5000}}}}"#.to_string(),
        ]
        .join("\n")
            + "\n";
        parse_run(&text).unwrap()
    }

    #[test]
    fn parses_streams_and_switch_steps() {
        let run = sample_run();
        assert_eq!(run.records, 7);
        assert_eq!(run.steps.len(), 3);
        assert_eq!(run.probes.len(), 3);
        assert_eq!(run.switches.len(), 1);
        assert_eq!(run.switches[0].step, 3);
        assert_eq!(run.switches[0].reason, "displacement");
        // trapezoid: 0.5*(4+3.5)*1 + 0.5*(3.5+3)*1
        assert!((run.loss_auc() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn switch_quality_pairs_pre_and_post_probes() {
        let run = sample_run();
        let t = switch_quality_table(&run);
        // pre = step-2 probe (0.6), post = step-3 probe (0.95)
        assert!(t.contains("0.6000"), "{t}");
        assert!(t.contains("0.9500"), "{t}");
        assert!(t.contains("-0.0500"), "{t}");
        assert!(t.contains("displacement"), "{t}");
    }

    #[test]
    fn cadence_table_aggregates_per_reason() {
        let run = sample_run();
        let t = cadence_table(&run);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(
            lines[0],
            "reason        switches  mean_lifetime  mean_margin_pre  mean_cap_post"
        );
        assert_eq!(lines[2], "displacement  1         10.0           -0.0500          0.9500");
    }

    #[test]
    fn anomaly_flags_fire_on_late_criterion_and_clip() {
        // Three consecutive in-region probes with no switch.
        let text = [
            probe_line(1, 0, "wq", 0.5, Some(-0.1)),
            probe_line(2, 0, "wq", 0.5, Some(-0.1)),
            probe_line(3, 0, "wq", 0.5, Some(-0.1)),
            r#"{"type":"clipped","step":2,"grad_norm":9.5,"clip_norm":1.0,"anomaly":3.2}"#
                .to_string(),
        ]
        .join("\n");
        let run = parse_run(&text).unwrap();
        let flags = anomaly_flags(&run);
        assert!(flags.iter().any(|f| f.contains("3 consecutive probes")), "{flags:?}");
        assert!(flags.iter().any(|f| f.contains("clipped on 1 step")), "{flags:?}");
        assert!(flags.iter().any(|f| f.contains("no trailing registry")), "{flags:?}");
    }

    #[test]
    fn compare_table_reports_deltas() {
        let run = sample_run();
        let base = sample_run();
        let t = compare_table(&run, &base);
        assert!(t.contains("loss_auc"), "{t}");
        assert!(t.contains("+0.0%"), "{t}");
        assert!(t.contains("comm.wire_quant_bytes"), "{t}");
    }

    #[test]
    fn bench_diff_flags_timing_regressions() {
        let fresh = json::parse(r#"{"baseline_s":1.3,"steps":60}"#).unwrap();
        let base = json::parse(r#"{"baseline_s":1.0,"steps":60}"#).unwrap();
        let (table, flags) = bench_diff(&fresh, &base);
        assert!(table.contains("baseline_s"), "{table}");
        assert!(table.contains("+30.0%"), "{table}");
        assert_eq!(flags.len(), 1);
        assert!(flags[0].contains("baseline_s regressed 30.0%"), "{flags:?}");
        // counts are not timing keys: no flag even when they move
        let f2 = json::parse(r#"{"steps":120}"#).unwrap();
        let b2 = json::parse(r#"{"steps":60}"#).unwrap();
        assert!(bench_diff(&f2, &b2).1.is_empty());
    }

    #[test]
    fn prom_roundtrip_and_top_view() {
        let text = "# TYPE lotus_train_loss_micro gauge\nlotus_train_loss_micro 3500000\n\
                    lotus_diag_capture_micro_L0_wq 900000\n\
                    lotus_diag_capture_micro_L0_wk 700000\n\
                    lotus_diag_age_L0_wq 12\n";
        let prom = parse_prom_text(text).unwrap();
        assert_eq!(prom.len(), 4);
        let top = render_top(&prom);
        assert!(top.contains("loss 3.5000"), "{top}");
        assert!(top.contains("L0"), "{top}");
        assert!(top.contains("0.8000"), "{top}"); // mean of 0.9 / 0.7
        assert!(top.contains("0.7000"), "{top}"); // min
        assert!(parse_prom_text("lotus_x notanumber\n").is_err());
    }
}
