//! Offline digestion of emitted telemetry: turn a metrics JSONL stream
//! into the per-phase time breakdown and switch-cadence tables the
//! `lotus report` subcommand prints, and validate trace/metrics files
//! for CI (`lotus report --check`).

use std::collections::BTreeMap;

use crate::util::fmt::Table;
use crate::util::json::{self, JsonValue};

/// Digest of one metrics JSONL stream.
pub struct ReportDigest {
    /// Total records (all types).
    pub records: usize,
    /// `type == "step"` records.
    pub steps: u64,
    /// Loss of the last step record carrying one.
    pub last_loss: Option<f64>,
    /// Total switch events across the run.
    pub switches: u64,
    /// Rendered per-phase wall-time breakdown.
    pub phase_table: String,
    /// Rendered per-reason switch-cadence table.
    pub switch_table: String,
}

struct Cadence {
    count: u64,
    lifetime: f64,
    rank: f64,
}

/// Parse a metrics JSONL stream and aggregate phase time and switch
/// cadence across its step records.
pub fn digest_metrics(text: &str) -> Result<ReportDigest, String> {
    let mut phase_ns: BTreeMap<String, f64> = BTreeMap::new();
    let mut cadence: BTreeMap<String, Cadence> = BTreeMap::new();
    let mut records = 0usize;
    let mut steps = 0u64;
    let mut last_loss = None;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("metrics line {}: {e}", ln + 1))?;
        records += 1;
        if v.get("type").as_str() != Some("step") {
            continue;
        }
        steps += 1;
        if let Some(l) = v.get("loss").as_f64() {
            last_loss = Some(l);
        }
        if let Some(obj) = v.get("wall").get("phase_ns").as_obj() {
            for (k, x) in obj {
                if let Some(ns) = x.as_f64() {
                    *phase_ns.entry(k.clone()).or_insert(0.0) += ns;
                }
            }
        }
        if let Some(sw) = v.get("switches").as_arr() {
            for s in sw {
                let reason = s.get("reason").as_str().unwrap_or("?").to_string();
                let e = cadence
                    .entry(reason)
                    .or_insert_with(|| Cadence { count: 0, lifetime: 0.0, rank: 0.0 });
                e.count += 1;
                e.lifetime += s.get("lifetime").as_f64().unwrap_or(0.0);
                e.rank += s.get("rank").as_f64().unwrap_or(0.0);
            }
        }
    }
    let total: f64 = phase_ns.values().sum();
    let mut pt = Table::new(&["phase", "total_ms", "share"]);
    for (k, ns) in &phase_ns {
        pt.row(&[
            k.clone(),
            format!("{:.3}", ns / 1e6),
            format!("{:.1}%", 100.0 * ns / total.max(1.0)),
        ]);
    }
    let mut st = Table::new(&["reason", "switches", "mean_lifetime", "mean_rank"]);
    let mut switches = 0u64;
    for (k, c) in &cadence {
        switches += c.count;
        let n = c.count.max(1) as f64;
        st.row(&[
            k.clone(),
            c.count.to_string(),
            format!("{:.1}", c.lifetime / n),
            format!("{:.1}", c.rank / n),
        ]);
    }
    Ok(ReportDigest {
        records,
        steps,
        last_loss,
        switches,
        phase_table: pt.render(),
        switch_table: st.render(),
    })
}

/// Flatten nested JSON objects into dot-keyed numeric leaves
/// (`comm.retries`, `registry.counters.wire_quant_bytes`, ...). Also used
/// by `telemetry::analyze` for the `--bench` artifact diff.
pub(crate) fn flatten_numeric(prefix: &str, v: &JsonValue, out: &mut Vec<(String, f64)>) {
    if let Some(obj) = v.as_obj() {
        for (k, x) in obj {
            let key =
                if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
            flatten_numeric(&key, x, out);
        }
    } else if let Some(n) = v.as_f64() {
        out.push((prefix.to_string(), n));
    }
}

/// Render the trailing `type == "registry"` record of a metrics stream
/// — the [`crate::telemetry::metrics::REGISTRY`] snapshot plus the
/// dedicated comm/wire instruments that `telemetry::finish` appends —
/// as an instrument/value table (`lotus report --registry`).
pub fn render_registry(text: &str) -> Result<String, String> {
    let mut last: Option<JsonValue> = None;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line).map_err(|e| format!("metrics line {}: {e}", ln + 1))?;
        if v.get("type").as_str() == Some("registry") {
            last = Some(v);
        }
    }
    let rec = last.ok_or_else(|| {
        "no registry record in stream (it is appended when the emitting process exits)"
            .to_string()
    })?;
    let mut leaves = Vec::new();
    flatten_numeric("", rec.get("wall"), &mut leaves);
    let mut t = Table::new(&["instrument", "value"]);
    for (k, n) in &leaves {
        let val = if n.fract() == 0.0 && n.abs() < 1e15 {
            format!("{}", *n as i64)
        } else {
            format!("{n:.3}")
        };
        t.row(&[k.clone(), val]);
    }
    Ok(t.render())
}

/// Typed validation failure from [`check_metrics`]. Variants distinguish
/// the stream-shape failures CI cares about (a truncated tail or a stream
/// whose emitter died before `telemetry::finish` appended the registry
/// record) from per-line parse/monotonicity errors.
#[derive(Debug, Clone, PartialEq)]
pub enum CheckError {
    /// The stream has no records at all.
    Empty,
    /// A line failed to parse as JSON.
    Parse { line: usize, msg: String },
    /// A step record is missing its `step` field.
    MissingStep { line: usize },
    /// Step indices regressed or repeated.
    NonMonotone { line: usize, prev: f64, cur: f64 },
    /// The final line is not newline-terminated — the writer was cut off
    /// mid-record.
    TruncatedTail,
    /// The last record is not `type == "registry"`, so the emitting
    /// process never reached `telemetry::finish`.
    MissingRegistry { last_type: String },
}

impl std::fmt::Display for CheckError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckError::Empty => write!(f, "metrics stream is empty"),
            CheckError::Parse { line, msg } => write!(f, "metrics line {line}: {msg}"),
            CheckError::MissingStep { line } => {
                write!(f, "metrics line {line}: step record without step")
            }
            CheckError::NonMonotone { line, prev, cur } => {
                write!(f, "metrics line {line}: step {cur} not monotone after {prev}")
            }
            CheckError::TruncatedTail => {
                write!(f, "metrics stream truncated: last line is not newline-terminated")
            }
            CheckError::MissingRegistry { last_type } => write!(
                f,
                "metrics stream missing final registry record (last record type \
                 \"{last_type}\"; it is appended by telemetry::finish when the \
                 emitting process exits)"
            ),
        }
    }
}

impl std::error::Error for CheckError {}

/// Validate a metrics JSONL stream: every line parses, the `step` indices
/// of step records are strictly increasing, the final line is
/// newline-terminated (no truncated tail), and the last record is the
/// `registry` snapshot `telemetry::finish` appends. Returns the record
/// count.
pub fn check_metrics(text: &str) -> Result<usize, CheckError> {
    let mut last_step: Option<f64> = None;
    let mut last_type = String::new();
    let mut n = 0usize;
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|msg| CheckError::Parse { line: ln + 1, msg })?;
        n += 1;
        last_type = v.get("type").as_str().unwrap_or("?").to_string();
        if last_type == "step" {
            let s = v
                .get("step")
                .as_f64()
                .ok_or(CheckError::MissingStep { line: ln + 1 })?;
            if let Some(prev) = last_step {
                if s <= prev {
                    return Err(CheckError::NonMonotone { line: ln + 1, prev, cur: s });
                }
            }
            last_step = Some(s);
        }
    }
    if n == 0 {
        return Err(CheckError::Empty);
    }
    if !text.ends_with('\n') {
        return Err(CheckError::TruncatedTail);
    }
    if last_type != "registry" {
        return Err(CheckError::MissingRegistry { last_type });
    }
    Ok(n)
}

/// Validate a Chrome trace file: parses as JSON, has a `traceEvents`
/// array, and every event is a closed complete-event (`"ph": "X"`)
/// with a name and non-negative timestamps. Returns
/// `(events, distinct span kinds)`.
pub fn check_trace(text: &str) -> Result<(usize, usize), String> {
    let v = json::parse(text).map_err(|e| format!("trace: {e}"))?;
    let evs = v
        .get("traceEvents")
        .as_arr()
        .ok_or_else(|| "trace: missing traceEvents array".to_string())?;
    let mut kinds = std::collections::BTreeSet::new();
    for (i, e) in evs.iter().enumerate() {
        if e.get("ph").as_str() != Some("X") {
            return Err(format!("trace event {i}: ph != \"X\" (span did not close)"));
        }
        let name = e.get("name").as_str().ok_or_else(|| format!("trace event {i}: no name"))?;
        if name.is_empty() {
            return Err(format!("trace event {i}: empty name"));
        }
        kinds.insert(name.to_string());
        let ts = e.get("ts").as_f64().ok_or_else(|| format!("trace event {i}: no ts"))?;
        let dur = e.get("dur").as_f64().ok_or_else(|| format!("trace event {i}: no dur"))?;
        if ts < 0.0 || dur < 0.0 {
            return Err(format!("trace event {i}: negative ts/dur"));
        }
    }
    Ok((evs.len(), kinds.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stream() -> String {
        let mut s = String::new();
        for (t, sw) in [(1u64, false), (2, true), (3, false)] {
            let switches = if sw {
                JsonValue::arr(vec![JsonValue::obj(vec![
                    ("layer", JsonValue::num(0)),
                    ("mat", JsonValue::str("wq")),
                    ("reason", JsonValue::str("displacement")),
                    ("lifetime", JsonValue::num(10)),
                    ("rank", JsonValue::num(16)),
                ])])
            } else {
                JsonValue::arr(vec![])
            };
            let rec = JsonValue::obj(vec![
                ("type", JsonValue::str("step")),
                ("step", JsonValue::num(t as f64)),
                ("loss", JsonValue::num(5.0 - t as f64)),
                ("switches", switches),
                (
                    "wall",
                    JsonValue::obj(vec![(
                        "phase_ns",
                        JsonValue::obj(vec![
                            ("grad", JsonValue::num(3_000_000)),
                            ("update", JsonValue::num(1_000_000)),
                        ]),
                    )]),
                ),
            ]);
            s.push_str(&rec.to_string());
            s.push('\n');
        }
        s
    }

    #[test]
    fn digest_aggregates_phases_and_switches() {
        let d = digest_metrics(&sample_stream()).unwrap();
        assert_eq!(d.records, 3);
        assert_eq!(d.steps, 3);
        assert_eq!(d.switches, 1);
        assert_eq!(d.last_loss, Some(2.0));
        assert!(d.phase_table.contains("grad"));
        assert!(d.phase_table.contains("75.0%"));
        assert!(d.switch_table.contains("displacement"));
    }

    #[test]
    fn render_registry_flattens_the_trailing_snapshot() {
        let mut s = sample_stream();
        s.push_str(
            r#"{"type":"registry","wall":{"registry":{"counters":{"quant.encode_calls":7}},"comm":{"retries":2,"wire":{"quant_bytes":1200,"logical_bytes":4800}}}}"#,
        );
        s.push('\n');
        let table = render_registry(&s).unwrap();
        assert!(table.contains("registry.counters.quant.encode_calls"), "{table}");
        assert!(table.contains("comm.wire.quant_bytes"), "{table}");
        assert!(table.contains("1200"), "{table}");
        // streams without the trailing record give a typed error
        assert!(render_registry(&sample_stream()).unwrap_err().contains("no registry record"));
    }

    fn finished_stream() -> String {
        let mut s = sample_stream();
        s.push_str("{\"type\":\"registry\",\"wall\":{}}\n");
        s
    }

    #[test]
    fn check_metrics_accepts_monotone_rejects_regression() {
        assert_eq!(check_metrics(&finished_stream()).unwrap(), 4);
        let bad = "{\"type\":\"step\",\"step\":2}\n{\"type\":\"step\",\"step\":2}\n";
        assert_eq!(
            check_metrics(bad).unwrap_err(),
            CheckError::NonMonotone { line: 2, prev: 2.0, cur: 2.0 }
        );
        assert_eq!(check_metrics("").unwrap_err(), CheckError::Empty);
        assert!(matches!(check_metrics("not json\n").unwrap_err(), CheckError::Parse { .. }));
    }

    #[test]
    fn check_metrics_rejects_truncated_tail_and_missing_registry() {
        // A stream without the trailing registry record fails typed.
        assert_eq!(
            check_metrics(&sample_stream()).unwrap_err(),
            CheckError::MissingRegistry { last_type: "step".to_string() }
        );
        // A registry record cut off mid-write (no trailing newline) fails
        // before the missing-registry check can be fooled by the fragment.
        let mut s = finished_stream();
        s.pop();
        assert_eq!(check_metrics(&s).unwrap_err(), CheckError::TruncatedTail);
        // ... and a torn final line that no longer parses is a parse error.
        let torn = &s[..s.len() - 4];
        assert!(matches!(check_metrics(torn).unwrap_err(), CheckError::Parse { .. }));
    }

    #[test]
    fn check_trace_validates_shape() {
        let good = r#"{"traceEvents":[{"name":"grad","cat":"lotus","ph":"X","pid":1,"tid":1,"ts":0,"dur":5},{"name":"update","cat":"lotus","ph":"X","pid":1,"tid":1,"ts":5,"dur":2}]}"#;
        assert_eq!(check_trace(good).unwrap(), (2, 2));
        let open = r#"{"traceEvents":[{"name":"grad","ph":"B","ts":0}]}"#;
        assert!(check_trace(open).unwrap_err().contains("did not close"));
        assert!(check_trace("[]").is_err());
    }
}
