//! Shared helpers for the `benches/` harnesses (offline stand-in for
//! criterion): run configs sized for bench-time budgets, table printing
//! glue, and CSV emission for the figure benches.

use crate::models::presets as mp;
use crate::sim::trainer::{Method, SimRunCfg};
use std::io::Write;

/// The four Table 1 size rows, scaled to this testbed: the paper's
/// 60M/130M/350M/1B become tiny/mini×{1,2}/20m shapes with the same
/// r/d_model aspect ratios (r/d = 0.5, 1/3, 1/4, 1/4).
pub fn table1_sizes() -> Vec<(&'static str, &'static str, SimRunCfg)> {
    use crate::models::LlamaConfig;
    let mk = |model, rank, steps| {
        let mut c = SimRunCfg::quick(model, rank, steps);
        c.batch = 4;
        c.eval_batches = 2;
        c
    };
    vec![
        // (paper row label, our scale label, cfg) — sizes shrink the
        // paper's 60M→1B ladder onto this CPU testbed while keeping the
        // r/d_model aspect ratios (0.5, 1/3, 1/4, 1/4) of Table 1.
        ("60M", "0.5M", mk(mp::llama_tiny_cfg(), 64, 200)),
        (
            "130M",
            "0.9M",
            mk(
                LlamaConfig { vocab: 768, d_model: 160, n_layers: 2, n_heads: 4, d_ff: 432, seq_len: 64 },
                53,
                120,
            ),
        ),
        (
            "350M",
            "1.6M",
            mk(
                LlamaConfig { vocab: 1024, d_model: 192, n_layers: 3, n_heads: 4, d_ff: 512, seq_len: 64 },
                48,
                80,
            ),
        ),
        (
            "1B",
            "3M",
            mk(
                LlamaConfig { vocab: 1024, d_model: 256, n_layers: 3, n_heads: 4, d_ff: 688, seq_len: 80 },
                64,
                50,
            ),
        ),
    ]
}

/// The method column of Table 1, with bench-scale hyper-parameters.
pub fn table1_methods() -> Vec<Method> {
    vec![
        Method::FullRank,
        Method::GaLore { interval: 50 },
        Method::LowRank,
        Method::LoRA,
        Method::ReLoRA { merge_every: 50 },
        Method::AdaRankGrad { interval: 50, decay: 0.85 },
        Method::lotus_default_bench(),
    ]
}

impl Method {
    /// Lotus with bench-scale gaps (η scaled to the shorter runs).
    pub fn lotus_default_bench() -> Method {
        Method::Lotus { gamma: 0.01, eta: 20, t_min: 20 }
    }
}

/// The Table 2 method rows at a given rank.
pub fn table2_methods(rank_interval: u64) -> Vec<Method> {
    vec![
        Method::FullRank,
        Method::LoRA,
        Method::GaLore { interval: rank_interval },
        Method::Apollo { refresh_every: rank_interval },
        Method::AdaRankGrad { interval: rank_interval, decay: 0.85 },
        Method::Lotus { gamma: 0.01, eta: 10, t_min: 10 },
    ]
}

/// Write a CSV file under `bench_out/` (creating the directory), used by
/// the figure benches so results can be re-plotted.
pub fn write_csv(name: &str, header: &str, rows: &[String]) -> std::io::Result<String> {
    std::fs::create_dir_all("bench_out")?;
    let path = format!("bench_out/{name}.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(path)
}

/// Bench-time flag: `LOTUS_BENCH_FAST=1` shrinks step counts ~4× so the
/// full suite finishes quickly in CI; default runs the full budget.
pub fn fast_mode() -> bool {
    std::env::var("LOTUS_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Scale a step count down in fast mode.
pub fn steps(full: u64) -> u64 {
    if fast_mode() {
        (full / 4).max(10)
    } else {
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_are_populated() {
        assert_eq!(table1_sizes().len(), 4);
        assert_eq!(table1_methods().len(), 7);
        assert_eq!(table2_methods(100).len(), 6);
    }

    #[test]
    fn table1_configs_validate() {
        for (_, _, cfg) in table1_sizes() {
            assert_eq!(cfg.model.d_model % cfg.model.n_heads, 0);
            assert!(cfg.rank <= cfg.model.d_model);
        }
    }

    #[test]
    fn csv_roundtrip() {
        let p = write_csv("test_csv", "a,b", &["1,2".into(), "3,4".into()]).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("a,b"));
        assert!(body.lines().count() == 3);
        let _ = std::fs::remove_file(p);
    }
}
