//! `lotus` — CLI launcher for the Lotus training framework.
//!
//! Subcommands: train (PJRT path), sim (Rust-native), finetune
//! (GLUE-sim suite), inspect (configs/manifest), sweep (paper tables).

use anyhow::{anyhow, bail, Result};
use lotus::cli::{self, Args};
use lotus::config::{presets, RunConfig};
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::util::fmt;
use lotus::util::log::{set_level, Level};

fn main() {
    lotus::util::log::init_from_env();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::help());
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        set_level(Level::Debug);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_toml(&text).map_err(|e| anyhow!("config error: {e}"))?
    } else if let Some(name) = args.opt("preset") {
        presets::run_preset(name).ok_or_else(|| anyhow!("unknown preset '{name}'"))?
    } else {
        RunConfig::default()
    };
    cli::apply_overrides(&mut cfg, args).map_err(|e| anyhow!("{e}"))?;
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("sim") => cmd_sim(args),
        Some("finetune") => cmd_finetune(args),
        Some("inspect") => cmd_inspect(args),
        Some("sweep") => cmd_sweep(args),
        Some("methods") => cmd_methods(args),
        Some("help") | None => {
            println!("{}", cli::help());
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{}", cli::help()),
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    bail!(
        "this build has no PJRT runtime (compile with `--features pjrt`, which needs the \
         vendored `xla` crate); use `lotus sim` for the Rust-native path"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use lotus::train::PjrtTrainer;
    let cfg = load_config(args)?;
    let method = cfg.method.method;
    if !lotus::optim::registry::pjrt_supported(method) {
        bail!(
            "PJRT path supports lotus/galore/rsvd-fixed (got {:?}); \
             use `lotus sim` for the other baselines (see `lotus methods`)",
            method
        );
    }
    println!(
        "[lotus train] {} | {} params | method {} rank {} | {} steps",
        cfg.name,
        fmt::params(lotus::train::HostParams::init(cfg.model, cfg.seed).param_count()),
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps
    );
    let steps = cfg.steps;
    let mut trainer = PjrtTrainer::new(cfg, method)?;
    let report = trainer.train(steps)?;
    println!(
        "done: loss {:.4} (ppl {:.1}) | subspaces {} | fwdbwd {} update {} refresh {} (compile {})",
        report.final_loss,
        report.final_ppl,
        report.stats.subspace_count,
        fmt::duration_s(report.time_fwdbwd_s),
        fmt::duration_s(report.time_update_s),
        fmt::duration_s(report.time_refresh_s),
        fmt::duration_s(report.compile_s),
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let sim_cfg = SimRunCfg {
        model: cfg.model,
        rank: cfg.method.rank,
        batch: cfg.batch,
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: 4,
        hyper: cfg.hyper,
        seed: cfg.seed,
        coherence: cfg.coherence,
    };
    if cfg.dist.is_distributed() {
        return cmd_sim_dist(&cfg, &sim_cfg);
    }
    println!(
        "[lotus sim] {} | method {} rank {} | {} steps",
        cfg.name,
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps
    );
    let mut t = SimTrainer::new(&sim_cfg, cfg.method.method, cfg.seed);
    let report = t.train(cfg.steps);
    println!(
        "done: ppl {:.2} | subspaces {} (freq {:.1}/100 layer-steps) | grad {} update {}",
        report.final_ppl,
        report.stats.subspace_count,
        report.stats.frequency_per_100(),
        fmt::duration_s(report.time_grad_s),
        fmt::duration_s(report.time_update_s),
    );
    for (step, ppl) in &report.eval_curve {
        println!("  step {step:>6}  eval ppl {ppl:.2}");
    }
    Ok(())
}

/// N-worker data-parallel sim training: low-rank gradient exchange +
/// subspace consensus (`--workers N`, `rust/src/dist/`).
fn cmd_sim_dist(cfg: &lotus::config::RunConfig, sim_cfg: &SimRunCfg) -> Result<()> {
    use lotus::dist::DistTrainer;
    println!(
        "[lotus sim] {} | method {} rank {} | {} steps | {} workers x {} shards",
        cfg.name,
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps,
        cfg.dist.workers,
        cfg.dist.shard_count(),
    );
    let mut t = DistTrainer::new(sim_cfg, cfg.method.method, cfg.dist, cfg.seed)?;
    let report = t.train_checkpointed(cfg.steps, cfg.ckpt_every, &cfg.out_dir, &cfg.name)?;
    println!(
        "done: ppl {:.2} | subspaces {} | consensus {}/{} rounds triggered",
        report.final_ppl,
        report.stats.subspace_count,
        report.consensus.triggered,
        report.consensus.rounds,
    );
    // ratios are undefined when no projected bytes crossed a worker
    // boundary (single worker, or the dense full-rank baseline)
    let saving = if report.comm.reduction_vs_dense().is_finite() {
        format!(
            " => {:.1}x less all-reduce traffic ({:.1}x steady-state)",
            report.comm.reduction_vs_dense(),
            report.comm.steady_reduction_vs_dense(),
        )
    } else {
        String::new()
    };
    println!(
        "comm: low-rank {} + refresh {} + dense {} (dense baseline {} for projected){saving}",
        fmt::bytes(report.comm.lowrank_bytes),
        fmt::bytes(report.comm.refresh_dense_bytes),
        fmt::bytes(report.comm.other_dense_bytes),
        fmt::bytes(report.comm.dense_equiv_bytes),
    );
    for (step, ppl) in &report.eval_curve {
        println!("  step {step:>6}  eval ppl {ppl:.2}");
    }
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    use lotus::data::glue::generate_suite;
    use lotus::models::presets::encoder_small_cfg;
    use lotus::optim::Hyper;
    use lotus::sim::finetune_task;

    let cfg = load_config(args)?;
    let rank = cfg.method.rank.min(8);
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, cfg.seed);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let epochs: usize = args.opt_parse("epochs").map_err(|e| anyhow!(e))?.unwrap_or(2);
    println!(
        "[lotus finetune] method {} rank {rank} | 8 GLUE-sim tasks, {epochs} epochs",
        cfg.method.method.name()
    );
    let mut table = fmt::Table::new(&["Task", "Metric", "Subspaces", "Time"]);
    let mut total = 0.0;
    for task in &suite {
        let r = finetune_task(&enc, task, cfg.method.method, rank, epochs, 8, &hyper, cfg.seed);
        total += r.metric;
        table.row(&[
            task.name.to_string(),
            format!("{:.2}", r.metric),
            r.stats.subspace_count.to_string(),
            fmt::duration_s(r.wall_s),
        ]);
    }
    table.row(&["Avg".into(), format!("{:.2}", total / suite.len() as f64), "".into(), "".into()]);
    println!("{}", table.render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if args.has("manifest") || args.opt("artifacts").is_some() {
        let dir = args.opt_or("artifacts", "artifacts");
        let man = lotus::runtime::Manifest::load(&dir)?;
        println!("manifest: {} artifacts, {} configs", man.artifacts.len(), man.configs.len());
        for (name, mm) in &man.configs {
            println!(
                "  config {name}: d={} L={} V={} T={} rank={} batch={} ({} params)",
                mm.config.d_model,
                mm.config.n_layers,
                mm.config.vocab,
                mm.config.seq_len,
                mm.rank,
                mm.batch,
                mm.params.len()
            );
        }
        for a in man.artifacts.values() {
            println!("  {}: {} in / {} out", a.name, a.inputs.len(), a.outputs.len());
        }
        return Ok(());
    }
    let cfg = load_config(args)?;
    println!("{}", cfg.to_toml());
    let shape = cfg.model.shape(&cfg.name);
    println!("# params: {}", fmt::params(shape.param_count()));
    for method in lotus::memcount::Method::all() {
        let mem = lotus::memcount::model_mem(method, &shape, cfg.method.rank as u64, 4);
        println!(
            "# {:12} grad+opt {:>8}  (+refresh peak {:>8})",
            method.name(),
            fmt::bytes(mem.grad_plus_opt()),
            fmt::bytes(mem.transient_peak)
        );
    }
    Ok(())
}

/// Print the optimizer registry: every method, its projector/policy
/// composition, which trainers it runs under, and its analytic
/// optimizer-state bytes at a reference shape — so valid methods are
/// discoverable without triggering config errors.
fn cmd_methods(args: &Args) -> Result<()> {
    use lotus::memcount;
    use lotus::optim::registry;

    // reference shape: a 4096×4096 attention matrix at rank 256, f32
    let (m, n): (u64, u64) = (4096, 4096);
    let rank: u64 = args.opt_parse("rank").map_err(|e| anyhow!(e))?.unwrap_or(256);
    println!(
        "registry: {} methods | state column = analytic optimizer state for one \
         {m}x{n} matrix at rank {rank} (f32; see memcount)",
        registry::catalog().len()
    );
    let mut table =
        fmt::Table::new(&["Method", "CLI", "Projector", "Policy", "Ckpt", "Dist", "PJRT", "State"]);
    for info in registry::catalog() {
        let mem = memcount::layer_mem(info.default.memcount(), m, n, rank, 4);
        let yn = |b: bool| if b { "yes" } else { "-" }.to_string();
        table.row(&[
            info.name.to_string(),
            info.cli.to_string(),
            info.projector.to_string(),
            info.policy.to_string(),
            yn(info.checkpointable),
            yn(info.dist),
            yn(info.pjrt),
            fmt::bytes(mem.opt_state),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let table: u32 = args.opt_parse("table").map_err(|e| anyhow!(e))?.unwrap_or(1);
    println!(
        "[lotus sweep] table {table} — use `cargo bench --bench table{table}` for the full harness"
    );
    // quick inline sweep at tiny scale
    let steps: u64 = args.opt_parse("steps").map_err(|e| anyhow!(e))?.unwrap_or(60);
    let cfg = SimRunCfg::quick(lotus::models::presets::llama_tiny_cfg(), 16, steps);
    let mut out = fmt::Table::new(&["Method", "PPL", "OptState", "Switches"]);
    for method in [
        Method::FullRank,
        Method::GaLore { interval: 20 },
        Method::lotus_default_bench(),
    ] {
        let mut t = SimTrainer::new(&cfg, method, cfg.seed);
        let r = t.train(steps);
        out.row(&[
            method.name().to_string(),
            format!("{:.2}", r.final_ppl),
            fmt::bytes(r.state_bytes),
            r.stats.subspace_count.to_string(),
        ]);
    }
    println!("{}", out.render());
    Ok(())
}
