//! `lotus` — CLI launcher for the Lotus training framework.
//!
//! Subcommands: train (PJRT path), sim (Rust-native, checkpoint/resume),
//! finetune (GLUE-sim suite), generate (one-shot decoding from a
//! checkpoint), serve (continuous-batching engine over a synthetic
//! trace), inspect (configs/manifest), sweep (paper tables), methods
//! (optimizer registry).

use anyhow::{anyhow, bail, Result};
use lotus::cli::{self, Args};
use lotus::config::{presets, RunConfig};
use lotus::sim::trainer::{Method, SimRunCfg, SimTrainer};
use lotus::util::fmt;
use lotus::util::log::{set_level, Level};

fn main() {
    lotus::util::log::init_from_env();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cli::help());
            std::process::exit(2);
        }
    };
    // raise-only: --verbose must not downgrade an explicit LOTUS_LOG=trace
    if args.has("verbose") && !lotus::util::log::enabled(Level::Debug) {
        set_level(Level::Debug);
    }
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = args.opt("config") {
        let text = std::fs::read_to_string(path)?;
        RunConfig::from_toml(&text).map_err(|e| anyhow!("config error: {e}"))?
    } else if let Some(name) = args.opt("preset") {
        presets::run_preset(name).ok_or_else(|| anyhow!("unknown preset '{name}'"))?
    } else {
        RunConfig::default()
    };
    cli::apply_overrides(&mut cfg, args).map_err(|e| anyhow!("{e}"))?;
    // the sinks open as soon as any command resolves its config, so
    // every trainer/engine the command constructs is instrumented
    lotus::telemetry::init_from_cfg(&cfg.telemetry).map_err(|e| anyhow!("{e}"))?;
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    let result = match args.subcommand.as_deref() {
        Some("train") => cmd_train(args),
        Some("sim") => cmd_sim(args),
        Some("finetune") => cmd_finetune(args),
        Some("generate") => cmd_generate(args),
        Some("serve") => cmd_serve(args),
        Some("inspect") => cmd_inspect(args),
        Some("sweep") => cmd_sweep(args),
        Some("methods") => cmd_methods(args),
        Some("faults") => cmd_faults(args),
        Some("report") => cmd_report(args),
        Some("analyze") => cmd_analyze(args),
        Some("top") => cmd_top(args),
        Some("help") | None => {
            println!("{}", cli::help());
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}'\n\n{}", cli::help()),
    };
    // main() exits via std::process::exit, so the trace/metrics sinks
    // must flush here, on both success and error paths
    let finished = lotus::telemetry::finish().map_err(|e| anyhow!("{e}"));
    result.and(finished)
}

/// Digest (or, with `--check`, validate) telemetry files emitted by
/// `--trace-out` / `--metrics-out`.
fn cmd_report(args: &Args) -> Result<()> {
    use lotus::telemetry::{check_metrics, check_trace, digest_metrics, render_registry};
    let metrics = args.opt("metrics");
    let trace = args.opt("trace");
    if metrics.is_none() && trace.is_none() {
        bail!("lotus report needs --metrics <file.jsonl> and/or --trace <file.json>");
    }
    if args.has("registry") {
        let path = metrics
            .ok_or_else(|| anyhow!("--registry renders from --metrics <file.jsonl>"))?;
        let text = std::fs::read_to_string(path)?;
        println!("[lotus report] {path} | trailing instrument snapshot");
        println!("{}", render_registry(&text).map_err(|e| anyhow!("{path}: {e}"))?);
        return Ok(());
    }
    if args.has("check") {
        if let Some(path) = metrics {
            let text = std::fs::read_to_string(path)?;
            let n = check_metrics(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            println!("metrics ok: {path} ({n} records)");
        }
        if let Some(path) = trace {
            let text = std::fs::read_to_string(path)?;
            let (events, kinds) = check_trace(&text).map_err(|e| anyhow!("{path}: {e}"))?;
            println!("trace ok: {path} ({events} events, {kinds} span kinds)");
        }
        return Ok(());
    }
    let path = metrics.ok_or_else(|| {
        anyhow!("lotus report needs --metrics <file.jsonl> (--check validates a trace alone)")
    })?;
    let text = std::fs::read_to_string(path)?;
    let d = digest_metrics(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let loss = d.last_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into());
    println!(
        "[lotus report] {path} | {} records, {} steps | last loss {loss} | {} switches",
        d.records, d.steps, d.switches,
    );
    println!("{}", d.phase_table);
    println!("{}", d.switch_table);
    if let Some(path) = trace {
        let text = std::fs::read_to_string(path)?;
        let (events, kinds) = check_trace(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        println!("trace: {path} ({events} events, {kinds} span kinds)");
    }
    Ok(())
}

/// Cross-run diagnostics over `--metrics-out` JSONL streams (or, with
/// `--bench`, over BENCH_*.json files): switch-quality and cadence
/// tables, per-matrix probe summaries, anomaly flags, and run-vs-run
/// deltas against a `--baseline`.
fn cmd_analyze(args: &Args) -> Result<()> {
    use lotus::telemetry::analyze::{
        anomaly_flags, bench_diff, cadence_table, compare_table, parse_run, probe_table,
        switch_quality_table,
    };
    if let Some(bench_path) = args.opt("bench") {
        let fresh_text = std::fs::read_to_string(bench_path)?;
        let fresh = lotus::util::json::JsonValue::parse(&fresh_text)
            .map_err(|e| anyhow!("{bench_path}: {e}"))?;
        let base_path = args.opt("baseline").ok_or_else(|| {
            anyhow!("--bench needs --baseline <BENCH.json> to diff against")
        })?;
        let base_text = std::fs::read_to_string(base_path)?;
        let base = lotus::util::json::JsonValue::parse(&base_text)
            .map_err(|e| anyhow!("{base_path}: {e}"))?;
        println!("[lotus analyze] bench {bench_path} vs baseline {base_path}");
        let (table, flags) = bench_diff(&fresh, &base);
        println!("{table}");
        if flags.is_empty() {
            println!("trend: ok (no timing regression over 10%)");
        } else {
            for f in &flags {
                println!("trend: {f}");
            }
        }
        return Ok(());
    }
    let path = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.opt("metrics"))
        .ok_or_else(|| anyhow!("lotus analyze <run.jsonl> [--baseline other.jsonl]"))?;
    let text = std::fs::read_to_string(path)?;
    let run = parse_run(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    println!(
        "[lotus analyze] {path} | {} steps, {} switches, {} probe samples",
        run.steps.len(),
        run.switches.len(),
        run.probes.len(),
    );
    println!("{}", switch_quality_table(&run));
    println!("{}", cadence_table(&run));
    println!("{}", probe_table(&run));
    let flags = anomaly_flags(&run);
    if flags.is_empty() {
        println!("anomalies: none");
    } else {
        for f in &flags {
            println!("anomaly: {f}");
        }
    }
    if let Some(base_path) = args.opt("baseline") {
        let base_text = std::fs::read_to_string(base_path)?;
        let base = parse_run(&base_text).map_err(|e| anyhow!("{base_path}: {e}"))?;
        println!("\nvs baseline {base_path}:");
        println!("{}", compare_table(&run, &base));
    }
    Ok(())
}

/// Live per-layer dashboard tailing a `--prom-out` snapshot. Renders
/// once with `--once`, otherwise redraws every `--refresh` seconds
/// until interrupted.
fn cmd_top(args: &Args) -> Result<()> {
    use lotus::telemetry::analyze::{parse_prom_text, render_top};
    let path = args
        .opt("prom")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow!("lotus top --prom <file.prom> [--once] [--refresh <secs>]"))?;
    let refresh: f64 = args.opt_parse("refresh").map_err(|e| anyhow!(e))?.unwrap_or(1.0);
    if !refresh.is_finite() || refresh <= 0.0 {
        bail!("--refresh must be a positive number of seconds");
    }
    loop {
        let text = std::fs::read_to_string(path)?;
        let prom = parse_prom_text(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        if args.has("once") {
            println!("[lotus top] {path}");
            println!("{}", render_top(&prom));
            return Ok(());
        }
        // ANSI clear + home, then the dashboard
        print!("\x1b[2J\x1b[H[lotus top] {path} (refresh {refresh}s, ctrl-c to quit)\n");
        println!("{}", render_top(&prom));
        std::thread::sleep(std::time::Duration::from_secs_f64(refresh));
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    bail!(
        "this build has no PJRT runtime (compile with `--features pjrt`, which needs the \
         vendored `xla` crate); use `lotus sim` for the Rust-native path"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    use lotus::train::PjrtTrainer;
    let cfg = load_config(args)?;
    let method = cfg.method.method;
    if !lotus::optim::registry::pjrt_supported(method) {
        bail!(
            "PJRT path supports lotus/galore/rsvd-fixed (got {:?}); \
             use `lotus sim` for the other baselines (see `lotus methods`)",
            method
        );
    }
    println!(
        "[lotus train] {} | {} params | method {} rank {} | {} steps",
        cfg.name,
        fmt::params(lotus::train::HostParams::init(cfg.model, cfg.seed).param_count()),
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps
    );
    let steps = cfg.steps;
    let mut trainer = PjrtTrainer::new(cfg, method)?;
    let report = trainer.train(steps)?;
    println!(
        "done: loss {:.4} (ppl {:.1}) | subspaces {} | fwdbwd {} update {} refresh {} (compile {})",
        report.final_loss,
        report.final_ppl,
        report.stats.subspace_count,
        fmt::duration_s(report.time_fwdbwd_s),
        fmt::duration_s(report.time_update_s),
        fmt::duration_s(report.time_refresh_s),
        fmt::duration_s(report.compile_s),
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let sim_cfg = SimRunCfg {
        model: cfg.model,
        rank: cfg.method.rank,
        batch: cfg.batch,
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: 4,
        hyper: cfg.hyper,
        seed: cfg.seed,
        coherence: cfg.coherence,
        quant: cfg.quant,
        clip_norm: cfg.faults.clip_norm,
    };
    if cfg.dist.is_distributed() {
        return cmd_sim_dist(&cfg, &sim_cfg);
    }
    println!(
        "[lotus sim] {} | method {} rank {} | {} steps",
        cfg.name,
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps
    );
    let mut t = SimTrainer::new(&sim_cfg, cfg.method.method, cfg.seed);
    if let Some(path) = args.opt("resume") {
        let step = t.load_checkpoint(path)?;
        println!(
            "resumed {path} at step {step} ({} of {} steps remaining)",
            cfg.steps.saturating_sub(step),
            cfg.steps
        );
    }
    let remaining = cfg.steps.saturating_sub(t.current_step());
    let report = t.train(remaining);
    println!(
        "done: ppl {:.2} | subspaces {} (freq {:.1}/100 layer-steps) | grad {} update {}",
        report.final_ppl,
        report.stats.subspace_count,
        report.stats.frequency_per_100(),
        fmt::duration_s(report.time_grad_s),
        fmt::duration_s(report.time_update_s),
    );
    for (step, ppl) in &report.eval_curve {
        println!("  step {step:>6}  eval ppl {ppl:.2}");
    }
    if let Some(path) = args.opt("ckpt-out") {
        ensure_parent_dir(path)?;
        t.save_checkpoint(path)?;
        println!("checkpoint -> {path} (step {}, resumable)", t.current_step());
    }
    if let Some(path) = args.opt("weights-out") {
        ensure_parent_dir(path)?;
        lotus::train::checkpoint::save_weights(path, t.current_step(), &t.model().params)?;
        println!("weights -> {path} (serve with `lotus generate --ckpt {path}`)");
    }
    Ok(())
}

/// Create the directory a checkpoint path points into, if any.
fn ensure_parent_dir(path: &str) -> Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    Ok(())
}

/// Parse `--prompt "t0 t1 ..."` token ids, or sample `--prompt-len`
/// tokens from the training corpus distribution (seeded, so repeat
/// invocations see the same prompt).
fn parse_or_sample_prompt(args: &Args, cfg: &RunConfig, default_len: usize) -> Result<Vec<u32>> {
    if let Some(s) = args.opt("prompt") {
        let mut out = Vec::new();
        for tok in s.split(|c: char| c == ',' || c.is_whitespace()).filter(|t| !t.is_empty()) {
            out.push(
                tok.parse::<u32>().map_err(|_| anyhow!("--prompt: '{tok}' is not a token id"))?,
            );
        }
        if out.is_empty() {
            bail!("--prompt contained no token ids");
        }
        return Ok(out);
    }
    let len: usize = args.opt_parse("prompt-len").map_err(|e| anyhow!(e))?.unwrap_or(default_len);
    if len == 0 {
        bail!("--prompt-len must be positive");
    }
    let mut gen = lotus::data::corpus::CorpusGen::new(cfg.model.vocab, cfg.seed, cfg.coherence);
    Ok((0..len).map(|_| gen.next_token()).collect())
}

/// One-shot KV-cached decoding from a trained checkpoint.
fn cmd_generate(args: &Args) -> Result<()> {
    use lotus::serve::{Sampling, ServeEngine};
    let cfg = load_config(args)?;
    let ckpt = args.opt("ckpt").ok_or_else(|| {
        anyhow!("--ckpt <file> is required (produce one with `lotus sim --ckpt-out ...`)")
    })?;
    let max_new: usize = args.opt_parse("max-new").map_err(|e| anyhow!(e))?.unwrap_or(32);
    let top_k: usize = args.opt_parse("top-k").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let temperature: f32 = args.opt_parse("temperature").map_err(|e| anyhow!(e))?.unwrap_or(1.0);
    let sample_seed: u64 = args.opt_parse("sample-seed").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let prompt = parse_or_sample_prompt(args, &cfg, 8)?;
    let sampling = Sampling::from_cli(top_k, temperature);
    let (step, mut eng) = ServeEngine::from_checkpoint_with_kv(
        cfg.model,
        ckpt,
        1,
        (prompt.len() + max_new).max(2),
        cfg.quant.kv,
    )?;
    println!(
        "[lotus generate] {} | {ckpt} (trained {step} steps) | {} prompt tokens + {max_new} new | {sampling:?} | kv {}",
        cfg.name,
        prompt.len(),
        cfg.quant.kv.as_str(),
    );
    lotus::log_debug!(
        "generate: {} engine slots, max_seq {}, sample seed {sample_seed}",
        eng.slots(),
        eng.max_seq()
    );
    let t0 = std::time::Instant::now();
    let tokens = eng.generate(&prompt, max_new, sampling, sample_seed)?;
    let wall = t0.elapsed().as_secs_f64();
    println!("prompt: {}", join_tokens(&prompt));
    println!("tokens: {}", join_tokens(&tokens));
    println!(
        "{} tokens in {} ({:.1} tok/s) | kv cache {}",
        tokens.len(),
        fmt::duration_s(wall),
        tokens.len() as f64 / wall.max(1e-9),
        fmt::bytes(eng.kv_bytes() as u64),
    );
    Ok(())
}

fn join_tokens(toks: &[u32]) -> String {
    toks.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(" ")
}

/// Continuous-batching engine over a synthetic request trace; prints
/// throughput and ttft/total latency percentiles.
fn cmd_serve(args: &Args) -> Result<()> {
    use lotus::serve::{synthetic_trace, LatencySummary, Sampling, ServeEngine, TraceCfg};
    let cfg = load_config(args)?;
    let slots: usize = args.opt_parse("slots").map_err(|e| anyhow!(e))?.unwrap_or(8);
    let requests: usize = args.opt_parse("requests").map_err(|e| anyhow!(e))?.unwrap_or(32);
    let prompt_len: usize = args.opt_parse("prompt-len").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let max_new: usize = args.opt_parse("max-new").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let top_k: usize = args.opt_parse("top-k").map_err(|e| anyhow!(e))?.unwrap_or(0);
    let temperature: f32 = args.opt_parse("temperature").map_err(|e| anyhow!(e))?.unwrap_or(1.0);
    let max_queue: usize = args.opt_parse("max-queue").map_err(|e| anyhow!(e))?.unwrap_or(1024);
    let deadline: Option<u64> = args.opt_parse("deadline").map_err(|e| anyhow!(e))?;
    if slots == 0 || requests == 0 {
        bail!("--slots and --requests must be positive");
    }
    if prompt_len == 0 || max_new == 0 {
        bail!("--prompt-len and --max-new must be positive");
    }
    if max_queue == 0 {
        bail!("--max-queue must be positive");
    }
    let sampling = Sampling::from_cli(top_k, temperature);
    let max_seq = (prompt_len + max_new).max(2);
    let (mut eng, source) = match args.opt("ckpt") {
        Some(path) => {
            let (step, e) = ServeEngine::from_checkpoint_with_kv(
                cfg.model,
                path,
                slots,
                max_seq,
                cfg.quant.kv,
            )?;
            (e, format!("{path} (trained {step} steps)"))
        }
        None => (
            ServeEngine::with_kv_dtype(
                lotus::sim::SimModel::new(cfg.model, cfg.seed),
                slots,
                max_seq,
                cfg.quant.kv,
            ),
            "fresh init (no --ckpt: throughput-only run)".into(),
        ),
    };
    let trace = synthetic_trace(&TraceCfg {
        requests,
        prompt_len,
        max_new,
        vocab: cfg.model.vocab,
        coherence: cfg.coherence,
        seed: cfg.seed,
    });
    println!(
        "[lotus serve] {} | {source} | {slots} slots | {requests} requests (≤{prompt_len} prompt, ≤{max_new} new) | {sampling:?}",
        cfg.name,
    );
    eng.configure_limits(max_queue, deadline);
    lotus::log_debug!(
        "serve limits: max_queue {max_queue}, deadline {:?} steps, max_seq {max_seq}",
        deadline
    );
    let t0 = std::time::Instant::now();
    let mut done = Vec::new();
    for (i, (prompt, new)) in trace.iter().enumerate() {
        // backpressure: a full queue means the submitter waits (drives
        // the engine) instead of shedding its own trace
        while eng.queued() >= max_queue {
            eng.step(&mut done);
        }
        eng.submit(prompt, *new, sampling, cfg.seed ^ i as u64)?;
    }
    while !eng.is_idle() {
        eng.step(&mut done);
    }
    let wall = t0.elapsed().as_secs_f64();
    let sum = LatencySummary::digest(&done, wall, eng.shed());
    if sum.timed_out > 0 || sum.shed > 0 {
        println!(
            "degraded: {} requests timed out (deadline {} steps), {} shed",
            sum.timed_out,
            deadline.unwrap_or(0),
            sum.shed,
        );
    }
    println!(
        "done: {} requests ({} shed, {} timed out) | {} prompt tokens prefilled, {} generated in {} ({:.1} tok/s) | {} engine steps | kv {}",
        sum.completed,
        sum.shed,
        sum.timed_out,
        eng.prefill_tokens(),
        sum.generated_tokens,
        fmt::duration_s(wall),
        sum.tokens_per_s,
        eng.steps(),
        fmt::bytes(eng.kv_bytes() as u64),
    );
    let mut table = fmt::Table::new(&["Latency", "p50", "p90", "p99"]);
    table.row(&[
        "first token".into(),
        fmt::duration_s(sum.ttft_p50_s),
        fmt::duration_s(sum.ttft_p90_s),
        fmt::duration_s(sum.ttft_p99_s),
    ]);
    table.row(&[
        "request total".into(),
        fmt::duration_s(sum.total_p50_s),
        fmt::duration_s(sum.total_p90_s),
        fmt::duration_s(sum.total_p99_s),
    ]);
    println!("{}", table.render());
    Ok(())
}

/// N-worker data-parallel sim training: low-rank gradient exchange +
/// subspace consensus (`--workers N`, `rust/src/dist/`).
fn cmd_sim_dist(cfg: &lotus::config::RunConfig, sim_cfg: &SimRunCfg) -> Result<()> {
    use lotus::dist::DistTrainer;
    println!(
        "[lotus sim] {} | method {} rank {} | {} steps | {} workers x {} shards",
        cfg.name,
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps,
        cfg.dist.workers,
        cfg.dist.shard_count(),
    );
    let mut t = DistTrainer::new(sim_cfg, cfg.method.method, cfg.dist, cfg.seed)?;
    t.set_guards(cfg.faults.guard());
    if let Some(plan) = cfg.faults.plan().map_err(|e| anyhow!(e))? {
        println!(
            "faults: armed \"{}\" ({} events, seed {:#x})",
            cfg.faults.plan,
            plan.events.len(),
            cfg.faults.seed,
        );
        t.arm_faults(plan);
    }
    let report = t.train_checkpointed(cfg.steps, cfg.ckpt_every, &cfg.out_dir, &cfg.name)?;
    println!(
        "done: ppl {:.2} | subspaces {} | consensus {}/{} rounds triggered",
        report.final_ppl,
        report.stats.subspace_count,
        report.consensus.triggered,
        report.consensus.rounds,
    );
    if report.faults.total() > 0 || report.recovery.skipped_steps > 0 {
        println!(
            "recovery: {} faults injected | {} payload retries ({} checksum failures, {} drops) | {} rollbacks, {} skipped steps, {} worker deaths",
            report.faults.total(),
            report.comm.retries,
            report.comm.checksum_failures,
            report.comm.dropped_payloads,
            report.recovery.rollbacks,
            report.recovery.skipped_steps,
            report.recovery.worker_deaths,
        );
    }
    // ratios are undefined when no projected bytes crossed a worker
    // boundary (single worker, or the dense full-rank baseline)
    let saving = if report.comm.reduction_vs_dense().is_finite() {
        format!(
            " => {:.1}x less all-reduce traffic ({:.1}x steady-state)",
            report.comm.reduction_vs_dense(),
            report.comm.steady_reduction_vs_dense(),
        )
    } else {
        String::new()
    };
    println!(
        "comm: low-rank {} + refresh {} + dense {} (dense baseline {} for projected){saving}",
        fmt::bytes(report.comm.lowrank_bytes),
        fmt::bytes(report.comm.refresh_dense_bytes),
        fmt::bytes(report.comm.other_dense_bytes),
        fmt::bytes(report.comm.dense_equiv_bytes),
    );
    for (step, ppl) in &report.eval_curve {
        println!("  step {step:>6}  eval ppl {ppl:.2}");
    }
    Ok(())
}

/// Count tensors whose bytes differ between two parameter sets (0 =
/// bit-identical models).
fn count_param_mismatches(a: &lotus::sim::model::Params, b: &lotus::sim::model::Params) -> usize {
    let mut bad = 0;
    if a.embed.data != b.embed.data {
        bad += 1;
    }
    if a.final_norm != b.final_norm {
        bad += 1;
    }
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        for (ma, mb) in [
            (&la.wq, &lb.wq),
            (&la.wk, &lb.wk),
            (&la.wv, &lb.wv),
            (&la.wo, &lb.wo),
            (&la.w1, &lb.w1),
            (&la.w3, &lb.w3),
            (&la.w2, &lb.w2),
        ] {
            if ma.data != mb.data {
                bad += 1;
            }
        }
        if la.norm1 != lb.norm1 {
            bad += 1;
        }
        if la.norm2 != lb.norm2 {
            bad += 1;
        }
    }
    bad
}

/// Serve-path fault drill (`lotus faults --serve`): run the same
/// synthetic trace twice — fault-free oracle, then with the serve fault
/// schedule armed (lane deaths, stalls) — and verify every request's
/// tokens match the oracle exactly; then mangle a checkpoint reload and
/// verify the CRC-verified container chain recovers with a typed
/// diagnosis instead of panicking.
fn cmd_faults_serve(args: &Args) -> Result<()> {
    use lotus::serve::{synthetic_trace, Sampling, ServeEngine, TraceCfg};
    use lotus::train::checkpoint;

    let mut cfg = load_config(args)?;
    if cfg.faults.plan.trim().is_empty() {
        cfg.faults.plan = "lane0@3,stall@5,lane1@6,ckpt_corrupt@load".into();
    }
    let plan = cfg
        .faults
        .plan()
        .map_err(|e| anyhow!(e))?
        .expect("plan is non-empty by construction");
    let slots: usize = args.opt_parse("slots").map_err(|e| anyhow!(e))?.unwrap_or(4);
    let requests: usize = args.opt_parse("requests").map_err(|e| anyhow!(e))?.unwrap_or(12);
    let prompt_len: usize = args.opt_parse("prompt-len").map_err(|e| anyhow!(e))?.unwrap_or(8);
    let max_new: usize = args.opt_parse("max-new").map_err(|e| anyhow!(e))?.unwrap_or(8);
    let top_k: usize = args.opt_parse("top-k").map_err(|e| anyhow!(e))?.unwrap_or(4);
    let temperature: f32 = args.opt_parse("temperature").map_err(|e| anyhow!(e))?.unwrap_or(0.9);
    if slots == 0 || requests == 0 || prompt_len == 0 || max_new == 0 {
        bail!("--slots/--requests/--prompt-len/--max-new must be positive");
    }
    // stochastic sampling by default: the drill then proves a retried
    // request's RNG *stream* is preserved across a lane death, not just
    // its argmax
    let sampling = Sampling::from_cli(top_k, temperature);
    let max_seq = (prompt_len + max_new).max(2);
    let trace = synthetic_trace(&TraceCfg {
        requests,
        prompt_len,
        max_new,
        vocab: cfg.model.vocab,
        coherence: cfg.coherence,
        seed: cfg.seed,
    });
    println!(
        "[lotus faults --serve] {} | {slots} slots | {requests} requests (≤{prompt_len} prompt, ≤{max_new} new) | {sampling:?} | plan \"{}\" (seed {:#x})",
        cfg.name, cfg.faults.plan, cfg.faults.seed,
    );

    let run = |armed: Option<lotus::faults::FaultPlan>| -> Result<(ServeEngine, Vec<(u64, Vec<u32>)>)> {
        let model = lotus::sim::SimModel::new(cfg.model, cfg.seed);
        let mut eng = ServeEngine::with_kv_dtype(model, slots, max_seq, cfg.quant.kv);
        if let Some(p) = armed {
            eng.arm_faults(p);
        }
        for (i, (prompt, new)) in trace.iter().enumerate() {
            eng.submit(prompt, *new, sampling, cfg.seed ^ i as u64)?;
        }
        let mut toks: Vec<(u64, Vec<u32>)> =
            eng.run_until_idle().into_iter().map(|c| (c.id, c.tokens)).collect();
        toks.sort_by_key(|(id, _)| *id);
        Ok((eng, toks))
    };
    let (_, want) = run(None)?;
    let (mut eng, got) = run(Some(plan))?;
    let fs = eng.fault_stats();
    println!(
        "faulted: {} lane kills, {} stalls | {} requeues, {} timed out | oracle {} / faulted {} completions",
        fs.lane_kills,
        fs.stalls,
        eng.requeues(),
        eng.timed_out(),
        want.len(),
        got.len(),
    );
    if want.len() != got.len() {
        bail!("VERDICT: MISMATCH — completion counts differ ({} vs {})", want.len(), got.len());
    }
    let bad = want.iter().zip(&got).filter(|(a, b)| a != b).count();
    if bad > 0 {
        bail!(
            "VERDICT: MISMATCH — {bad} of {} requests diverged from the fault-free oracle",
            want.len()
        );
    }

    // corrupt-checkpoint reload: an armed `ckpt_corrupt@load` mangles
    // the newest container's bytes in memory, so the CRC chain must
    // reject it (typed CkptError) and serve the older container
    std::fs::create_dir_all(&cfg.out_dir)?;
    let newest = std::path::Path::new(&cfg.out_dir).join(format!("{}-serve-new.ckpt", cfg.name));
    let older = std::path::Path::new(&cfg.out_dir).join(format!("{}-serve-old.ckpt", cfg.name));
    checkpoint::save_weights(&newest, 2, &eng.model().params)?;
    checkpoint::save_weights(&older, 1, &eng.model().params)?;
    let restored = eng.reload_from_chain(&[&newest, &older])?;
    if eng.fault_stats().ckpt_corruptions > 0 {
        println!("reload: ckpt_corrupt fired — chain fell back to the step-{restored} container");
        if restored != 1 {
            bail!("VERDICT: MISMATCH — corrupt reload served the mangled container");
        }
    } else {
        println!("reload: clean — served the step-{restored} container");
    }
    println!(
        "VERDICT: MATCH — every faulted request's tokens are identical to the fault-free oracle"
    );
    Ok(())
}

/// Fault-injection demo: run the same dist training twice — fault-free
/// oracle, then with the configured `--fault-plan` armed — and verify
/// the recovered weights match the fault-free oracle bit-for-bit. With
/// `--serve`, drill the serving path instead ([`cmd_faults_serve`]).
fn cmd_faults(args: &Args) -> Result<()> {
    use lotus::dist::DistTrainer;
    if args.has("serve") {
        return cmd_faults_serve(args);
    }
    let mut cfg = load_config(args)?;
    if cfg.faults.plan.trim().is_empty() {
        cfg.faults.plan = "flip@2,drop@3,dup@4,delay@5,nan@7".into();
    }
    if !cfg.dist.is_distributed() {
        cfg.dist.workers = 2;
        cfg.dist.validate(cfg.batch).map_err(|e| anyhow!(e))?;
    }
    // a demo wants seconds, not the 200-step default; explicit sources win
    if args.opt("steps").is_none() && args.opt("config").is_none() && args.opt("preset").is_none() {
        cfg.steps = 12;
    }
    if cfg.ckpt_every == 0 {
        cfg.ckpt_every = 4; // rollback needs periodic checkpoints
    }
    let plan = cfg
        .faults
        .plan()
        .map_err(|e| anyhow!(e))?
        .expect("plan is non-empty by construction");
    let sim_cfg = SimRunCfg {
        model: cfg.model,
        rank: cfg.method.rank,
        batch: cfg.batch,
        steps: cfg.steps,
        eval_every: cfg.eval_every,
        eval_batches: 4,
        hyper: cfg.hyper,
        seed: cfg.seed,
        coherence: cfg.coherence,
        quant: cfg.quant,
        clip_norm: cfg.faults.clip_norm,
    };
    println!(
        "[lotus faults] {} | method {} rank {} | {} steps | {} workers | plan \"{}\" (seed {:#x})",
        cfg.name,
        cfg.method.method.name(),
        cfg.method.rank,
        cfg.steps,
        cfg.dist.workers,
        cfg.faults.plan,
        cfg.faults.seed,
    );

    lotus::log_debug!(
        "faults: guard window {}, factor {}, rollback budget {}",
        cfg.faults.spike_window,
        cfg.faults.spike_factor,
        cfg.faults.max_rollbacks
    );
    let mut clean = DistTrainer::new(&sim_cfg, cfg.method.method, cfg.dist, cfg.seed)?;
    clean.set_guards(cfg.faults.guard());
    let oracle_name = format!("{}-oracle", cfg.name);
    let clean_report =
        clean.train_checkpointed(cfg.steps, cfg.ckpt_every, &cfg.out_dir, &oracle_name)?;
    println!("oracle:  ppl {:.2} (fault-free)", clean_report.final_ppl);

    let mut faulty = DistTrainer::new(&sim_cfg, cfg.method.method, cfg.dist, cfg.seed)?;
    faulty.set_guards(cfg.faults.guard());
    faulty.arm_faults(plan);
    let report = faulty.train_checkpointed(cfg.steps, cfg.ckpt_every, &cfg.out_dir, &cfg.name)?;
    println!(
        "faulted: ppl {:.2} | {} faults injected ({} flips, {} drops, {} dups, {} delays, {} kills, {} nan, {} spikes)",
        report.final_ppl,
        report.faults.total(),
        report.faults.bit_flips,
        report.faults.drops,
        report.faults.duplicates,
        report.faults.delays,
        report.faults.worker_kills,
        report.faults.nan_grads,
        report.faults.weight_corruptions,
    );
    println!(
        "recovery: {} payload retries ({} checksum failures) | {} rollbacks | {} skipped steps | {} worker deaths | {} loss spikes",
        report.comm.retries,
        report.comm.checksum_failures,
        report.recovery.rollbacks,
        report.recovery.skipped_steps,
        report.recovery.worker_deaths,
        report.recovery.loss_spikes,
    );
    println!(
        "consensus: {} rollback rounds ({} committed, {} outvoted, {} proposals cast)",
        report.rollback.rounds,
        report.rollback.committed,
        report.rollback.outvoted,
        report.rollback.proposals,
    );

    let bad = count_param_mismatches(&faulty.model().params, &clean.model().params);
    if bad > 0 {
        bail!("VERDICT: MISMATCH — {bad} weight tensors differ from the fault-free oracle");
    }
    println!("VERDICT: MATCH — recovered weights are bit-identical to the fault-free oracle");
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    use lotus::data::glue::generate_suite;
    use lotus::models::presets::encoder_small_cfg;
    use lotus::optim::Hyper;
    use lotus::sim::finetune_task;

    let cfg = load_config(args)?;
    let rank = cfg.method.rank.min(8);
    let enc = encoder_small_cfg();
    let suite = generate_suite(enc.vocab, enc.seq_len, cfg.seed);
    let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
    let epochs: usize = args.opt_parse("epochs").map_err(|e| anyhow!(e))?.unwrap_or(2);
    println!(
        "[lotus finetune] method {} rank {rank} | 8 GLUE-sim tasks, {epochs} epochs",
        cfg.method.method.name()
    );
    let mut table = fmt::Table::new(&["Task", "Metric", "Subspaces", "Time"]);
    let mut total = 0.0;
    for task in &suite {
        let r = finetune_task(&enc, task, cfg.method.method, rank, epochs, 8, &hyper, cfg.seed);
        total += r.metric;
        table.row(&[
            task.name.to_string(),
            format!("{:.2}", r.metric),
            r.stats.subspace_count.to_string(),
            fmt::duration_s(r.wall_s),
        ]);
    }
    table.row(&["Avg".into(), format!("{:.2}", total / suite.len() as f64), "".into(), "".into()]);
    println!("{}", table.render());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    if args.has("manifest") || args.opt("artifacts").is_some() {
        let dir = args.opt_or("artifacts", "artifacts");
        let man = lotus::runtime::Manifest::load(&dir)?;
        println!("manifest: {} artifacts, {} configs", man.artifacts.len(), man.configs.len());
        for (name, mm) in &man.configs {
            println!(
                "  config {name}: d={} L={} V={} T={} rank={} batch={} ({} params)",
                mm.config.d_model,
                mm.config.n_layers,
                mm.config.vocab,
                mm.config.seq_len,
                mm.rank,
                mm.batch,
                mm.params.len()
            );
        }
        for a in man.artifacts.values() {
            println!("  {}: {} in / {} out", a.name, a.inputs.len(), a.outputs.len());
        }
        return Ok(());
    }
    let cfg = load_config(args)?;
    println!("{}", cfg.to_toml());
    let shape = cfg.model.shape(&cfg.name);
    println!("# params: {}", fmt::params(shape.param_count()));
    // --dtype overrides; otherwise the config's optimizer-state dtype
    // drives the analytic table, so `[quant] state = "bf16"` is visible
    let dtype = element_dtype(args, cfg.quant.state)?;
    let b = dtype.element_bytes();
    for method in lotus::memcount::Method::all() {
        let mem = lotus::memcount::model_mem(method, &shape, cfg.method.rank as u64, b);
        println!(
            "# {:12} grad+opt {:>8} @{}  (+refresh peak {:>8})",
            method.name(),
            fmt::bytes(mem.grad_plus_opt()),
            dtype.as_str(),
            fmt::bytes(mem.transient_peak)
        );
    }
    Ok(())
}

/// Resolve `--dtype` for the analytic memory/comm tables, defaulting to
/// the caller's choice (int8 counts 1 byte/element; the blockwise scale
/// overhead is a codec property, reported by `Codec::encoded_len`).
fn element_dtype(
    args: &Args,
    default: lotus::quant::QuantDtype,
) -> Result<lotus::quant::QuantDtype> {
    match args.opt("dtype") {
        Some(s) => s.parse::<lotus::quant::QuantDtype>().map_err(|e| anyhow!("--dtype: {e}")),
        None => Ok(default),
    }
}

/// Print the optimizer registry: every method, its projector/policy
/// composition, which trainers it runs under, and its analytic
/// optimizer-state bytes at a reference shape — so valid methods are
/// discoverable without triggering config errors.
fn cmd_methods(args: &Args) -> Result<()> {
    use lotus::memcount;
    use lotus::optim::registry;

    // reference shape: a 4096×4096 attention matrix at rank 256; the
    // state/wire columns honour --dtype (f32|bf16|int8, default f32)
    let (m, n): (u64, u64) = (4096, 4096);
    let rank: u64 = args.opt_parse("rank").map_err(|e| anyhow!(e))?.unwrap_or(256);
    let dtype = element_dtype(args, lotus::quant::QuantDtype::F32)?;
    let b = dtype.element_bytes();
    println!(
        "registry: {} methods | state/wire columns = analytic optimizer state and \
         per-step all-reduce payload for one {m}x{n} matrix at rank {rank} \
         ({}; see memcount)",
        registry::catalog().len(),
        dtype.as_str(),
    );
    let mut table = fmt::Table::new(&[
        "Method", "CLI", "Projector", "Policy", "Ckpt", "Dist", "PJRT", "LR", "State", "Wire",
    ]);
    for info in registry::catalog() {
        let mem = memcount::layer_mem(info.default.memcount(), m, n, rank, b);
        let wire = memcount::allreduce_layer_bytes(info.default.memcount(), m, n, rank, b);
        let yn = |b: bool| if b { "yes" } else { "-" }.to_string();
        table.row(&[
            info.name.to_string(),
            info.cli.to_string(),
            info.projector.to_string(),
            info.policy.to_string(),
            yn(info.checkpointable),
            yn(info.dist),
            yn(info.pjrt),
            format!("{:.0e}", info.hyper.lr),
            fmt::bytes(mem.opt_state),
            fmt::bytes(wire),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let table: u32 = args.opt_parse("table").map_err(|e| anyhow!(e))?.unwrap_or(1);
    println!(
        "[lotus sweep] table {table} — use `cargo bench --bench table{table}` for the full harness"
    );
    // quick inline sweep at tiny scale
    let steps: u64 = args.opt_parse("steps").map_err(|e| anyhow!(e))?.unwrap_or(60);
    let cfg = SimRunCfg::quick(lotus::models::presets::llama_tiny_cfg(), 16, steps);
    let mut out = fmt::Table::new(&["Method", "PPL", "OptState", "Switches"]);
    for method in [
        Method::FullRank,
        Method::GaLore { interval: 20 },
        Method::lotus_default_bench(),
    ] {
        let mut t = SimTrainer::new(&cfg, method, cfg.seed);
        let r = t.train(steps);
        out.row(&[
            method.name().to_string(),
            format!("{:.2}", r.final_ppl),
            fmt::bytes(r.state_bytes),
            r.stats.subspace_count.to_string(),
        ]);
    }
    println!("{}", out.render());
    Ok(())
}
