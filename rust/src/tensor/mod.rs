//! Dense row-major f32 matrices and related helpers.
//!
//! This is the in-crate numeric substrate for the Rust-native simulator
//! and the baselines — deliberately simple (no generic dtype, no strides)
//! so the linear algebra in [`crate::linalg`] stays auditable.

pub mod matrix;
pub mod bf16;
pub mod init;
pub mod workspace;

pub use matrix::Matrix;
pub use workspace::Workspace;
