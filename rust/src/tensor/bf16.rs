//! bfloat16 conversion (round-to-nearest-even), hand-rolled because the
//! `half` crate is not vendored. Used by the memory model (the paper
//! trains in BF16) and by the Adam8bit/bf16-state simulations to
//! reproduce the *numerics* of reduced-precision optimizer state.

/// Convert f32 → bf16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest even on the truncated 16 bits
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0xFFFF;
    let mut hi = bits >> 16;
    if lower > round_bit || (lower == round_bit && (hi & 1) == 1) {
        hi += 1;
    }
    hi as u16
}

/// Convert bf16 bits → f32 (exact).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round-trip an f32 through bf16 (simulates storing in bf16).
#[inline]
pub fn quantize_bf16(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Quantize a whole slice in place.
pub fn quantize_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = quantize_bf16(*x);
    }
}

/// Blockwise absmax 8-bit quantization of a slice (the bitsandbytes-style
/// scheme behind the paper's "8-bit optimizer" in Fig. 2a): each block of
/// `block` values is scaled by its absmax into int8 and dequantized back.
/// Returns the max elementwise absolute error for diagnostics.
pub fn quantize_int8_blockwise(xs: &mut [f32], block: usize) -> f32 {
    let mut max_err = 0.0f32;
    for chunk in xs.chunks_mut(block) {
        let absmax = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        if absmax == 0.0 {
            continue;
        }
        let scale = absmax / 127.0;
        for x in chunk.iter_mut() {
            let q = (*x / scale).round().clamp(-127.0, 127.0);
            let deq = q * scale;
            max_err = max_err.max((deq - *x).abs());
            *x = deq;
        }
    }
    max_err
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_for_representable() {
        for &x in &[0.0f32, 1.0, -2.0, 0.5, 1.5, -0.25] {
            assert_eq!(quantize_bf16(x), x);
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between two bf16 values around 1.0
        let x = f32::from_bits(0x3F80_8000);
        let q = quantize_bf16(x);
        // must round to even mantissa: stays at 1.0
        assert_eq!(q, 1.0);
        // slightly above the halfway point must round up
        let x2 = f32::from_bits(0x3F80_8001);
        assert!(quantize_bf16(x2) > 1.0);
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..1000 {
            let x = rng.normal_f32(0.0, 10.0);
            if x == 0.0 {
                continue;
            }
            let q = quantize_bf16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 1.0 / 128.0, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(quantize_bf16(f32::NAN).is_nan());
        assert_eq!(quantize_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn int8_blockwise_error_bound() {
        let mut rng = crate::util::Rng::new(12);
        let mut xs: Vec<f32> = (0..1024).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let orig = xs.clone();
        let err = quantize_int8_blockwise(&mut xs, 64);
        // per-block error ≤ absmax/254
        for (chunk, ochunk) in xs.chunks(64).zip(orig.chunks(64)) {
            let absmax = ochunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            for (q, x) in chunk.iter().zip(ochunk) {
                assert!((q - x).abs() <= absmax / 127.0 + 1e-6);
            }
        }
        assert!(err > 0.0); // generic data does quantize
    }
}
