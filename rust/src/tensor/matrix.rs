//! Row-major `f32` matrix with the small dense-algebra surface the
//! optimizers and projections need. Heavier kernels (blocked matmul, QR,
//! SVD) live in [`crate::linalg`].

use crate::util::Rng;

/// Dense row-major matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Matrix wrapping an existing buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. N(0, std^2) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Out-of-place transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a caller-owned buffer (reshaped as needed); the
    /// allocation-free twin of [`Matrix::transpose`].
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.ensure_shape(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    /// Reshape in place to `rows × cols`, reusing the existing buffer.
    /// All entries are zero afterwards; no allocation happens unless the
    /// buffer must grow.
    pub fn reset_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape in place to `rows × cols` without clearing: entry values
    /// are unspecified and the caller must overwrite every element.
    /// No allocation happens unless the buffer must grow.
    pub fn ensure_shape(&mut self, rows: usize, cols: usize) {
        let len = rows * cols;
        if self.data.len() != len {
            self.data.resize(len, 0.0);
        }
        self.rows = rows;
        self.cols = cols;
    }

    /// Become a copy of `other`, reusing this matrix's buffer (the
    /// allocation-free twin of `clone_from` that also reshapes).
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Squared Frobenius norm (f64 accumulate).
    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
    }

    /// Elementwise in-place: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise in-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// self - other, new matrix.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// self + other, new matrix.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Normalize to unit Frobenius norm (no-op on zero matrices);
    /// this is `NORMALIZE` in the paper's Algorithm 1.
    pub fn normalized(&self) -> Matrix {
        let n = self.fro_norm();
        if n <= f32::EPSILON {
            return self.clone();
        }
        let inv = 1.0 / n;
        let data = self.data.iter().map(|x| x * inv).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Flat dot product ⟨self, other⟩ (f64 accumulate).
    pub fn dot(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data.iter().zip(&other.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
    }

    /// Max |x| entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// True if any entry is NaN/inf.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Extract the leading `k` columns as a new matrix.
    pub fn take_cols(&self, k: usize) -> Matrix {
        assert!(k <= self.cols);
        let mut out = Matrix::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(5, 7), m.at(7, 5));
    }

    #[test]
    fn fro_norm_known_value() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn normalized_is_unit() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(8, 8, 3.0, &mut rng);
        assert!((m.normalized().fro_norm() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn normalize_zero_is_noop() {
        let z = Matrix::zeros(4, 4);
        assert_eq!(z.normalized(), z);
    }

    #[test]
    fn axpy_and_sub() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![10.0, 20.0, 30.0]);
        let mut c = a.clone();
        c.axpy(0.1, &b);
        assert_eq!(c.data, vec![2.0, 4.0, 6.0]);
        assert_eq!(b.sub(&a).data, vec![9.0, 18.0, 27.0]);
    }

    #[test]
    fn take_cols_extracts_prefix() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let t = m.take_cols(2);
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.row(2), &[8.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_length_checked() {
        Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn reset_and_ensure_reuse_capacity() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0; 6]);
        let cap = m.data.capacity();
        m.reset_to(3, 2);
        assert_eq!(m.shape(), (3, 2));
        assert!(m.data.iter().all(|&x| x == 0.0));
        assert_eq!(m.data.capacity(), cap);
        m.ensure_shape(1, 4);
        assert_eq!(m.shape(), (1, 4));
        assert_eq!(m.data.capacity(), cap);
    }

    #[test]
    fn copy_from_matches_clone() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        let mut b = Matrix::zeros(1, 1);
        b.copy_from(&a);
        assert_eq!(a, b);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Rng::new(8);
        let a = Matrix::randn(13, 37, 1.0, &mut rng);
        let mut t = Matrix::zeros(0, 0);
        a.transpose_into(&mut t);
        assert_eq!(t, a.transpose());
    }
}
