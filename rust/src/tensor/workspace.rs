//! Per-layer scratch arena: a free-list of `Vec<f32>` buffers that the
//! trainer, projectors and optimizers borrow intermediate matrices from,
//! eliminating steady-state heap allocations on the hot path.
//!
//! Protocol: [`Workspace::take`] hands out a zero-filled [`Matrix`] of
//! the requested shape, reusing the best-fitting retired buffer;
//! [`Workspace::give`] returns the buffer to the free list. After one
//! warm-up pass at a given working-set of shapes, a take/give cycle
//! performs no allocations (the buffers and the free-list vector both
//! retain their capacity). Buffers are zeroed on `take`, so stale scratch
//! from a previous borrower can never leak into results — the
//! stale-scratch regression test lives in `rust/tests/par_linalg.rs`.

use super::Matrix;

/// A free-list arena of reusable `f32` buffers.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
}

impl Workspace {
    /// Empty workspace; buffers are grown on demand and retained.
    pub const fn new() -> Self {
        Workspace { free: Vec::new() }
    }

    /// Pick (and detach) the best-fitting retired buffer for `len`
    /// elements: the smallest whose capacity fits, else the largest
    /// (which will grow), else a fresh one. Returned cleared.
    fn grab(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None; // (idx, capacity) fitting
        let mut largest: Option<(usize, usize)> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            if cap >= len && best.map_or(true, |(_, c)| cap < c) {
                best = Some((i, cap));
            }
            if largest.map_or(true, |(_, c)| cap > c) {
                largest = Some((i, cap));
            }
        }
        let mut buf = match best.or(largest) {
            Some((i, _)) => self.free.swap_remove(i),
            None => Vec::new(),
        };
        buf.clear();
        buf
    }

    /// Borrow a zero-filled `rows × cols` matrix (see [`Workspace::grab`]
    /// for the reuse policy).
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let len = rows * cols;
        let mut buf = self.grab(len);
        buf.resize(len, 0.0);
        Matrix::from_vec(rows, cols, buf)
    }

    /// Borrow a copy of `src` (reusing a retired buffer). Skips the
    /// zero-fill of [`Workspace::take`] — every element is overwritten
    /// by the copy, so stale scratch still cannot leak.
    pub fn take_copy(&mut self, src: &Matrix) -> Matrix {
        let mut buf = self.grab(src.len());
        buf.extend_from_slice(&src.data);
        Matrix::from_vec(src.rows, src.cols, buf)
    }

    /// Return a borrowed matrix's buffer to the free list.
    pub fn give(&mut self, m: Matrix) {
        self.free.push(m.data);
    }

    /// Number of retired buffers currently held.
    pub fn buffers(&self) -> usize {
        self.free.len()
    }

    /// Total bytes of retained buffer capacity (diagnostics).
    pub fn capacity_bytes(&self) -> usize {
        self.free.iter().map(|b| b.capacity() * std::mem::size_of::<f32>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zero_filled_even_after_dirty_give() {
        let mut ws = Workspace::new();
        let mut m = ws.take(4, 4);
        m.data.fill(7.0);
        ws.give(m);
        let back = ws.take(4, 4);
        assert!(back.data.iter().all(|&x| x == 0.0), "stale scratch leaked");
        assert_eq!(back.shape(), (4, 4));
    }

    #[test]
    fn steady_state_reuses_capacity() {
        let mut ws = Workspace::new();
        // warm up with the working set
        let a = ws.take(8, 8);
        let b = ws.take(3, 5);
        ws.give(a);
        ws.give(b);
        let cap_before = ws.capacity_bytes();
        for _ in 0..50 {
            let a = ws.take(8, 8);
            let b = ws.take(3, 5);
            ws.give(b);
            ws.give(a);
        }
        assert_eq!(ws.capacity_bytes(), cap_before, "workspace kept allocating");
        assert_eq!(ws.buffers(), 2);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take(32, 32);
        let small = ws.take(2, 2);
        let (big_cap, small_cap) = (big.data.capacity(), small.data.capacity());
        assert!(big_cap > small_cap);
        ws.give(big);
        ws.give(small);
        let got = ws.take(2, 2);
        assert_eq!(got.data.capacity(), small_cap, "best fit should pick the small buffer");
        ws.give(got);
    }

    #[test]
    fn take_copy_matches_source_and_reuses() {
        let mut rng = crate::util::Rng::new(9);
        let src = Matrix::randn(6, 7, 1.0, &mut rng);
        let mut ws = Workspace::new();
        let mut m = ws.take(6, 7);
        m.data.fill(5.0); // dirty the buffer
        ws.give(m);
        let cap_before = ws.capacity_bytes();
        let copy = ws.take_copy(&src);
        assert_eq!(copy, src);
        ws.give(copy);
        assert_eq!(ws.capacity_bytes(), cap_before);
    }

    #[test]
    fn grows_largest_when_nothing_fits() {
        let mut ws = Workspace::new();
        let m = ws.take(2, 2);
        ws.give(m);
        let grown = ws.take(16, 16);
        assert_eq!(grown.len(), 256);
        assert_eq!(ws.buffers(), 0);
    }
}
