//! Weight-initialization schemes matching the JAX model in
//! `python/compile/model.py` so the Rust simulator and the PJRT path
//! start from comparable distributions.

use super::Matrix;
use crate::util::Rng;

/// Truncated-normal-ish init with std = 1/sqrt(fan_in) (LLaMA-style).
pub fn lecun_normal(rows: usize, cols: usize, fan_in: usize, rng: &mut Rng) -> Matrix {
    let std = (1.0 / fan_in as f32).sqrt();
    Matrix::randn(rows, cols, std, rng)
}

/// Xavier/Glorot uniform.
pub fn xavier_uniform(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
    let a = (6.0 / (rows + cols) as f32).sqrt();
    let mut m = Matrix::zeros(rows, cols);
    rng.fill_uniform(&mut m.data, a);
    m
}

/// Scaled init for output projections (GPT-2 style 1/sqrt(2L) damping).
pub fn residual_out(rows: usize, cols: usize, fan_in: usize, n_layers: usize, rng: &mut Rng) -> Matrix {
    let std = (1.0 / fan_in as f32).sqrt() / (2.0 * n_layers as f32).sqrt();
    Matrix::randn(rows, cols, std, rng)
}

/// Gaussian random projection matrix with entries N(0, 1/r) — the
/// classic Johnson–Lindenstrauss scaling used by Flora/Apollo-style
/// projectors and as the rSVD test matrix Ω.
pub fn gaussian_projection(rows: usize, cols: usize, r: usize, rng: &mut Rng) -> Matrix {
    let std = (1.0 / r as f32).sqrt();
    Matrix::randn(rows, cols, std, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lecun_std_scales_with_fan_in() {
        let mut rng = Rng::new(3);
        let m = lecun_normal(64, 256, 256, &mut rng);
        let var: f64 =
            m.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>() / m.len() as f64;
        let expect = 1.0 / 256.0;
        assert!((var - expect).abs() < expect * 0.2, "var={var} expect={expect}");
    }

    #[test]
    fn xavier_bounded() {
        let mut rng = Rng::new(4);
        let m = xavier_uniform(32, 32, &mut rng);
        let a = (6.0 / 64.0f32).sqrt();
        assert!(m.max_abs() <= a + 1e-6);
    }

    #[test]
    fn residual_out_is_damped() {
        let mut rng = Rng::new(5);
        let base = lecun_normal(64, 64, 64, &mut rng);
        let damped = residual_out(64, 64, 64, 8, &mut rng);
        assert!(damped.fro_norm() < base.fro_norm());
    }
}
