//! Wall-clock timing utilities for the bench harness and the trainer's
//! per-phase accounting (data, fwdbwd, projection, switch, update).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A resettable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_s(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named phase durations; the trainer uses this to report
/// where each training step spends its time (the paper's Fig. 2 is a
/// phase-time comparison at heart).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    totals: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed());
        out
    }

    /// Add an externally measured duration.
    pub fn add(&mut self, phase: &'static str, d: Duration) {
        *self.totals.entry(phase).or_default() += d;
        *self.counts.entry(phase).or_default() += 1;
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or_default()
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Render a per-phase summary table.
    pub fn report(&self) -> String {
        let grand = self.grand_total().as_secs_f64().max(1e-12);
        let mut s = String::new();
        for (name, d) in &self.totals {
            let secs = d.as_secs_f64();
            let n = self.counts[name];
            s.push_str(&format!(
                "{name:<12} {secs:>9.3}s  {:>5.1}%  n={n}  avg={:.3}ms\n",
                100.0 * secs / grand,
                1e3 * secs / n.max(1) as f64,
            ));
        }
        s
    }
}

/// Simple repeated-measurement helper used by the `benches/` harnesses
/// (offline stand-in for criterion): warmups, then timed iterations,
/// reporting min/mean/p50.
pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 2, iters: 7 }
    }
}

impl BenchRunner {
    pub fn new(warmup: usize, iters: usize) -> Self {
        BenchRunner { warmup, iters }
    }

    /// Run `f` and return (min, mean, median) seconds.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        BenchStats::from_samples(samples)
    }
}

/// Summary statistics over bench samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub samples: Vec<f64>,
    pub min: f64,
    pub mean: f64,
    pub median: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = samples.first().copied().unwrap_or(0.0);
        let mean = samples.iter().sum::<f64>() / samples.len().max(1) as f64;
        let median = samples[samples.len() / 2];
        BenchStats { samples, min, mean, median }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", Duration::from_millis(10));
        pt.add("a", Duration::from_millis(20));
        pt.add("b", Duration::from_millis(5));
        assert_eq!(pt.count("a"), 2);
        assert!(pt.total("a") >= Duration::from_millis(30));
        assert!(pt.grand_total() >= Duration::from_millis(35));
        assert!(pt.report().contains("a"));
    }

    #[test]
    fn bench_runner_returns_ordered_stats() {
        let r = BenchRunner::new(0, 5);
        let stats = r.run(|| {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(stats.min <= stats.median);
        assert_eq!(stats.samples.len(), 5);
    }
}
