//! Human-readable formatting of byte sizes, durations and counts, plus a
//! tiny fixed-width table renderer used by the bench harnesses to print
//! the paper's tables.

/// Format a byte count like the paper's Table 1 ("0.24G", "747M").
pub fn bytes(n: u64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    let x = n as f64;
    if x >= G {
        format!("{:.2}G", x / G)
    } else if x >= M {
        format!("{:.0}M", x / M)
    } else if x >= K {
        format!("{:.0}K", x / K)
    } else {
        format!("{n}B")
    }
}

/// Format seconds as "1h23m", "4m05s", "12.3s" or "45ms".
pub fn duration_s(secs: f64) -> String {
    if secs >= 3600.0 {
        format!("{}h{:02}m", (secs / 3600.0) as u64, ((secs % 3600.0) / 60.0) as u64)
    } else if secs >= 60.0 {
        format!("{}m{:02}s", (secs / 60.0) as u64, (secs % 60.0) as u64)
    } else if secs >= 1.0 {
        format!("{secs:.1}s")
    } else {
        format!("{:.0}ms", secs * 1e3)
    }
}

/// Format a parameter count ("60M", "1.3B").
pub fn params(n: u64) -> String {
    let x = n as f64;
    if x >= 1e9 {
        format!("{:.1}B", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.0}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.0}K", x / 1e3)
    } else {
        format!("{n}")
    }
}

/// Fixed-width text table builder (for bench output that mirrors the
/// paper's tables row-for-row).
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.rows.push(cells.iter().map(|s| s.to_string()).collect());
        self
    }

    /// Render with per-column widths and a separator under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len().max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = w[i]));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header, &w));
            out.push('\n');
            out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (ncol - 1)));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r, &w));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(2048), "2K");
        assert_eq!(bytes(747 * 1024 * 1024), "747M");
        assert!(bytes(4_500_000_000).ends_with('G'));
    }

    #[test]
    fn duration_units() {
        assert_eq!(duration_s(0.045), "45ms");
        assert_eq!(duration_s(12.34), "12.3s");
        assert_eq!(duration_s(65.0), "1m05s");
        assert_eq!(duration_s(3700.0), "1h01m");
    }

    #[test]
    fn params_units() {
        assert_eq!(params(60_000_000), "60M");
        assert_eq!(params(1_300_000_000), "1.3B");
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["Method", "60M"]);
        t.row_str(&["GaLore", "34.88(0.24G)"]);
        t.row_str(&["Lotus", "33.75(0.23G)"]);
        let s = t.render();
        assert!(s.contains("Method"));
        assert!(s.lines().count() == 4);
        // columns align: both data rows have the same offset for col 2
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].find("34.88"), lines[3].find("33.75"));
    }
}
