//! Exact integer-in-f32 encoding for checkpoint tensors.
//!
//! Checkpoints store everything as named f32 tensors; integer state
//! (step counters, RNG stream positions) rides along as 16-bit limbs —
//! every limb ≤ 65535 is exactly representable in f32, so counters stay
//! exact past 2²⁴ and bit-identical resume holds on arbitrarily long
//! runs. Shared by the optimizer state codec ([`crate::optim::state`])
//! and the trainer checkpoint writers ([`crate::train::checkpoint`]).

/// Exact u64 → f32 tensor encoding via 16-bit limbs.
pub fn u64_to_f32x4(x: u64) -> [f32; 4] {
    [
        (x & 0xFFFF) as f32,
        ((x >> 16) & 0xFFFF) as f32,
        ((x >> 32) & 0xFFFF) as f32,
        ((x >> 48) & 0xFFFF) as f32,
    ]
}

/// Inverse of [`u64_to_f32x4`].
pub fn f32x4_to_u64(d: &[f32]) -> u64 {
    (d[0] as u64) | ((d[1] as u64) << 16) | ((d[2] as u64) << 32) | ((d[3] as u64) << 48)
}

/// Append `x` to an f32 meta buffer as four exact 16-bit limbs (plain
/// `as f32` would corrupt counters above 2²⁴ and break bit-identical
/// resume on long runs).
pub fn push_u64(buf: &mut Vec<f32>, x: u64) {
    buf.extend_from_slice(&u64_to_f32x4(x));
}

/// Read the u64 stored as 16-bit limbs at f32 offset `at` of a meta
/// buffer (inverse of [`push_u64`]).
pub fn read_u64_limbs(data: &[f32], at: usize) -> u64 {
    f32x4_to_u64(&data[at..at + 4])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u64_limb_encoding_is_exact() {
        for x in [0u64, 1, 0xFFFF, 0x1_0000, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            assert_eq!(f32x4_to_u64(&u64_to_f32x4(x)), x);
        }
    }

    #[test]
    fn push_read_roundtrip_at_offset() {
        let mut buf = vec![7.0f32];
        push_u64(&mut buf, 0x1234_5678_9ABC_DEF0);
        push_u64(&mut buf, 42);
        assert_eq!(read_u64_limbs(&buf, 1), 0x1234_5678_9ABC_DEF0);
        assert_eq!(read_u64_limbs(&buf, 5), 42);
    }
}
