//! Leveled stderr logging with a global verbosity switch.
//!
//! A stand-in for `tracing`/`env_logger` (offline build). Level is set
//! once at startup from `--verbose`/`LOTUS_LOG` and read lock-free.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `LOTUS_LOG` env var (error|warn|info|debug|trace).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("LOTUS_LOG") {
        let lv = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        set_level(lv);
    }
}

/// True when messages at `level` should be emitted.
#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a message (used by the macros below). When a `--metrics-out`
/// sink is installed the line also lands in the JSONL stream as a
/// `{"type":"log",...}` record, so run logs and run metrics share one
/// timeline.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
        if crate::telemetry::metrics_enabled() {
            crate::telemetry::log_record(tag.trim_end(), &format!("{args}"));
        }
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
