//! Minimal JSON: a value tree, a writer, and a small recursive-descent
//! parser (enough to read the artifact `manifest.json` emitted by
//! `python/compile/aot.py` and to write metrics / run reports).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are stored as f64 (the manifest only carries
/// shapes and names, well within f64's exact-integer range).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn str(s: impl Into<String>) -> Self {
        JsonValue::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Self {
        JsonValue::Num(x.into())
    }
    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> Self {
        JsonValue::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr(items: Vec<JsonValue>) -> Self {
        JsonValue::Arr(items)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["k"]` style access; returns Null on miss for easy chaining.
    pub fn get(&self, key: &str) -> &JsonValue {
        static NULL: JsonValue = JsonValue::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            JsonValue::Str(s) => write_escaped(out, s),
            JsonValue::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry a byte offset for debugging.
pub fn parse(input: &str) -> Result<JsonValue, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(JsonValue::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = JsonValue::obj(vec![
            ("name", JsonValue::str("fwdbwd_tiny")),
            ("shape", JsonValue::arr(vec![JsonValue::num(64), JsonValue::num(128)])),
            ("ok", JsonValue::Bool(true)),
            ("nil", JsonValue::Null),
        ]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested_and_escapes() {
        let s = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny\"z"}, "d": false}"#;
        let v = parse(s).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").get("c").as_str().unwrap(), "x\ny\"z");
        assert_eq!(v.get("d"), &JsonValue::Bool(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nulll").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(JsonValue::num(42.0).to_string(), "42");
        assert_eq!(JsonValue::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn get_on_missing_key_is_null() {
        let v = parse("{}").unwrap();
        assert_eq!(v.get("nope"), &JsonValue::Null);
    }
}
