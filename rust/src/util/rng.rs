//! Deterministic pseudo-random number generation.
//!
//! A PCG-XSH-RR 64/32 generator seeded through SplitMix64, plus the
//! distribution helpers the rest of the crate needs (uniform, normal via
//! Box–Muller, Zipf sampling for the synthetic corpus, shuffling).
//! Streams are reproducible across platforms: all math is integer or
//! strictly-ordered f64.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

/// SplitMix64 — used to expand a user seed into PCG state/stream values.
#[inline]
pub fn splitmix64(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *seed;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (stream id is derived from the seed too).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1; // stream increment must be odd
        let mut rng = Rng { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Raw generator state (state word, stream increment) — for
    /// checkpointing a stream mid-flight.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Rng::state`] — the stream continues
    /// exactly where the snapshot left off (no re-seeding scramble).
    pub fn from_state(state: u64, inc: u64) -> Rng {
        Rng { state, inc }
    }

    /// Derive an independent child stream (for per-layer / per-worker use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = state.wrapping_add(inc);
        rng.next_u32();
        rng
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two PCG draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; the pair's
    /// second member is discarded for simplicity and determinism).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(0, std^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Fill a slice with U[-a, a) samples.
    pub fn fill_uniform(&mut self, buf: &mut [f32], a: f32) {
        for v in buf.iter_mut() {
            *v = (self.f32() * 2.0 - 1.0) * a;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from explicit (unnormalized, non-negative) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over {0, .., n-1} using the inverse-CDF
/// table; used by the synthetic C4-like corpus generator.
#[derive(Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build the CDF table for `n` items with exponent `s` (s≈1 for text).
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw one sample (binary search over the CDF).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&x).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_snapshot_resumes_stream_exactly() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let (s, inc) = a.state();
        let mut b = Rng::from_state(s, inc);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
