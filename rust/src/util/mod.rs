//! Small self-contained utilities: deterministic RNG, JSON writer,
//! leveled logging, timers and human-readable formatting.
//!
//! Everything here is hand-rolled because the build is fully offline
//! (only `xla` + `anyhow` are vendored); these substrates stand in for
//! `rand`, `serde_json`, `tracing` and `humansize`.

pub mod rng;
pub mod json;
pub mod log;
pub mod timer;
pub mod fmt;
pub mod codec;

pub use rng::{Rng, Zipf};
pub use json::JsonValue;
pub use timer::Stopwatch;
