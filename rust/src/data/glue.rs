//! GLUE-like synthetic task suite for the Table 2 fine-tuning
//! experiments: eight tasks matching the GLUE benchmark's *metric
//! types*, *label spaces* and *relative dataset sizes*.
//!
//! Each task plants a linear concept in a latent space, renders examples
//! as token sequences through a task-specific codebook, and labels them
//! by the concept (with task-specific noise). Fine-tuning must therefore
//! learn real token → concept structure; methods separate the same way
//! they do on GLUE (harder/low-data tasks like CoLA-sim and RTE-sim show
//! the largest spread — matching the paper's Table 2).

use crate::util::Rng;

/// Task archetype, mapping to the paper's reported metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Binary classification scored by Matthews correlation (CoLA).
    Matthews,
    /// Regression in [0,1] scored by Pearson correlation (STS-B).
    Pearson,
    /// Binary classification scored by F1 (MRPC).
    F1,
    /// Binary/multi-class accuracy (RTE, SST-2, MNLI, QNLI, QQP).
    Accuracy,
}

/// One labelled example: token sequence + target (class id, or the
/// regression value scaled to [0,1]).
#[derive(Clone, Debug)]
pub struct TaskExample {
    pub tokens: Vec<u32>,
    pub label: f32,
}

/// A generated task with train/dev splits.
pub struct GlueTask {
    pub name: &'static str,
    pub kind: TaskKind,
    pub n_classes: usize,
    pub train: Vec<TaskExample>,
    pub dev: Vec<TaskExample>,
    pub seq_len: usize,
    pub vocab: usize,
}

/// Parameters for one synthetic task.
struct TaskSpec {
    name: &'static str,
    kind: TaskKind,
    n_classes: usize,
    n_train: usize,
    n_dev: usize,
    /// label-noise rate (fraction of flipped/jittered labels)
    noise: f64,
    /// concept dimensionality (harder = higher)
    concept_dim: usize,
}

/// The 8 GLUE-sim tasks, sized relative to each other like GLUE
/// (RTE/CoLA/MRPC small, QQP/MNLI large — scaled down ~100×).
fn specs() -> [TaskSpec; 8] {
    [
        TaskSpec { name: "CoLA", kind: TaskKind::Matthews, n_classes: 2, n_train: 600, n_dev: 200, noise: 0.18, concept_dim: 6 },
        TaskSpec { name: "STS-B", kind: TaskKind::Pearson, n_classes: 1, n_train: 500, n_dev: 200, noise: 0.10, concept_dim: 4 },
        TaskSpec { name: "MRPC", kind: TaskKind::F1, n_classes: 2, n_train: 350, n_dev: 150, noise: 0.12, concept_dim: 4 },
        TaskSpec { name: "RTE", kind: TaskKind::Accuracy, n_classes: 2, n_train: 250, n_dev: 120, noise: 0.20, concept_dim: 8 },
        TaskSpec { name: "SST2", kind: TaskKind::Accuracy, n_classes: 2, n_train: 900, n_dev: 250, noise: 0.06, concept_dim: 3 },
        TaskSpec { name: "MNLI", kind: TaskKind::Accuracy, n_classes: 3, n_train: 1200, n_dev: 300, noise: 0.10, concept_dim: 6 },
        TaskSpec { name: "QNLI", kind: TaskKind::Accuracy, n_classes: 2, n_train: 1000, n_dev: 250, noise: 0.08, concept_dim: 5 },
        TaskSpec { name: "QQP", kind: TaskKind::Accuracy, n_classes: 2, n_train: 1200, n_dev: 300, noise: 0.08, concept_dim: 4 },
    ]
}

/// Names in paper order.
pub fn task_names() -> [&'static str; 8] {
    ["CoLA", "STS-B", "MRPC", "RTE", "SST2", "MNLI", "QNLI", "QQP"]
}

/// Generate all 8 tasks for a given vocab/seq (matching the encoder
/// config) and seed.
pub fn generate_suite(vocab: usize, seq_len: usize, seed: u64) -> Vec<GlueTask> {
    specs()
        .into_iter()
        .enumerate()
        .map(|(i, s)| generate_task(&s, vocab, seq_len, seed.wrapping_add(i as u64 * 7919)))
        .collect()
}

fn generate_task(spec: &TaskSpec, vocab: usize, seq_len: usize, seed: u64) -> GlueTask {
    let mut rng = Rng::new(seed);
    let k = spec.concept_dim;
    // Concept: k "indicator" token groups. Each group g has a set of
    // tokens; the latent score is a signed combination of group
    // occurrence counts. Labels derive from the score.
    let group_size = 6;
    let mut groups: Vec<Vec<u32>> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut g = Vec::with_capacity(group_size);
        for _ in 0..group_size {
            // avoid token 0 (pad/BOS)
            g.push(1 + rng.below(vocab as u64 - 1) as u32);
        }
        groups.push(g);
    }
    let weights: Vec<f32> =
        (0..k).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 } * (1.0 + rng.f32())).collect();

    let mut gen_example = |rng: &mut Rng| -> TaskExample {
        let mut tokens = vec![0u32; seq_len];
        // background tokens
        for t in tokens.iter_mut() {
            *t = 1 + rng.below(vocab as u64 - 1) as u32;
        }
        // plant group tokens with random intensity; center each count at
        // its expectation (1.5) so class priors stay balanced
        let mut score = 0.0f32;
        for (gi, g) in groups.iter().enumerate() {
            let count = rng.below(4) as usize;
            for _ in 0..count {
                let pos = rng.below(seq_len as u64) as usize;
                tokens[pos] = g[rng.below(group_size as u64) as usize];
            }
            score += weights[gi] * (count as f32 - 1.5);
        }
        // squash to [0,1]
        let squashed = 1.0 / (1.0 + (-score * 0.6).exp());
        let label = match spec.kind {
            TaskKind::Pearson => {
                // regression with jitter
                (squashed + rng.normal_f32(0.0, spec.noise as f32)).clamp(0.0, 1.0)
            }
            _ => {
                let c = if spec.n_classes == 3 {
                    // tri-class by score tertiles
                    if squashed < 0.4 {
                        0.0
                    } else if squashed < 0.6 {
                        1.0
                    } else {
                        2.0
                    }
                } else {
                    if squashed >= 0.5 {
                        1.0
                    } else {
                        0.0
                    }
                };
                // label noise: flip with prob `noise`
                if rng.f64() < spec.noise {
                    ((c as usize + 1 + rng.below(spec.n_classes.max(2) as u64 - 1) as usize)
                        % spec.n_classes.max(2)) as f32
                } else {
                    c
                }
            }
        };
        TaskExample { tokens, label }
    };

    let train = (0..spec.n_train).map(|_| gen_example(&mut rng)).collect();
    let dev = (0..spec.n_dev).map(|_| gen_example(&mut rng)).collect();
    GlueTask {
        name: spec.name,
        kind: spec.kind,
        n_classes: spec.n_classes.max(2),
        train,
        dev,
        seq_len,
        vocab,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_eight_named_tasks() {
        let suite = generate_suite(512, 32, 42);
        assert_eq!(suite.len(), 8);
        let names: Vec<_> = suite.iter().map(|t| t.name).collect();
        assert_eq!(names, task_names().to_vec());
    }

    #[test]
    fn labels_in_range() {
        for task in generate_suite(256, 24, 43) {
            for ex in task.train.iter().chain(&task.dev) {
                match task.kind {
                    TaskKind::Pearson => assert!((0.0..=1.0).contains(&ex.label)),
                    _ => {
                        let c = ex.label as usize;
                        assert!(c < task.n_classes, "{} label {c}", task.name);
                    }
                }
                assert!(ex.tokens.iter().all(|&t| (t as usize) < task.vocab));
                assert_eq!(ex.tokens.len(), task.seq_len);
            }
        }
    }

    #[test]
    fn classes_are_roughly_balanced() {
        let suite = generate_suite(512, 32, 44);
        let sst = suite.iter().find(|t| t.name == "SST2").unwrap();
        let pos = sst.train.iter().filter(|e| e.label > 0.5).count();
        let frac = pos as f64 / sst.train.len() as f64;
        assert!((0.25..=0.75).contains(&frac), "positive frac {frac}");
    }

    #[test]
    fn concept_is_learnable_by_token_counting() {
        // A trivial count-based predictor must beat chance on the dev
        // set of SST2-sim — i.e. the labels encode token structure.
        let suite = generate_suite(512, 32, 45);
        let sst = suite.iter().find(|t| t.name == "SST2").unwrap();
        // learn per-token log-odds from train
        let mut pos_counts = vec![1.0f64; sst.vocab];
        let mut neg_counts = vec![1.0f64; sst.vocab];
        for ex in &sst.train {
            let bucket = if ex.label > 0.5 { &mut pos_counts } else { &mut neg_counts };
            for &t in &ex.tokens {
                bucket[t as usize] += 1.0;
            }
        }
        let pos_total: f64 = pos_counts.iter().sum();
        let neg_total: f64 = neg_counts.iter().sum();
        let mut correct = 0usize;
        for ex in &sst.dev {
            let mut score = 0.0f64;
            for &t in &ex.tokens {
                score += (pos_counts[t as usize] / pos_total).ln()
                    - (neg_counts[t as usize] / neg_total).ln();
            }
            let pred = if score > 0.0 { 1.0 } else { 0.0 };
            if (pred - ex.label).abs() < 0.5 {
                correct += 1;
            }
        }
        let acc = correct as f64 / sst.dev.len() as f64;
        assert!(acc > 0.6, "naive-bayes acc {acc} must beat chance");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_suite(256, 16, 46);
        let b = generate_suite(256, 16, 46);
        assert_eq!(a[0].train[0].tokens, b[0].train[0].tokens);
        assert_eq!(a[3].dev[5].label, b[3].dev[5].label);
    }
}
