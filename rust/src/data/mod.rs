//! Data pipeline: synthetic C4-like corpus, tokenizer, LM batching and
//! the 8 GLUE-like synthetic classification/regression tasks.
//!
//! The paper trains on C4 and fine-tunes on GLUE; neither ships with
//! this testbed, so we build generators whose *statistics* exercise the
//! same optimizer behaviour (DESIGN.md §2): a Zipf-distributed unigram
//! law with Markov bigram structure gives a corpus with learnable
//! low/high-frequency structure (loss curves separate between methods),
//! and the GLUE-sim tasks span the same metric types the paper reports
//! (Matthews, Pearson, F1, accuracy).

pub mod corpus;
pub mod tokenizer;
pub mod batch;
pub mod glue;

pub use batch::{Batch, LmBatcher};
pub use corpus::CorpusGen;
pub use glue::{GlueTask, TaskExample, TaskKind};
pub use tokenizer::ByteTokenizer;
