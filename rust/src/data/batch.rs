//! Language-model batching: pack a token stream into (tokens, targets)
//! next-token-prediction batches, with a background prefetch thread so
//! data generation overlaps compute (the offline stand-in for an async
//! input pipeline).

use super::corpus::CorpusGen;
use std::sync::mpsc;
use std::thread;

/// One LM training batch: `tokens[b][t]` inputs, `targets[b][t]` = the
/// next token. Flattened row-major for direct upload as PJRT literals.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<u32>,
    pub targets: Vec<u32>,
}

impl Batch {
    pub fn token_count(&self) -> usize {
        self.batch * self.seq
    }
}

/// Batches drawn from a [`CorpusGen`] stream with double-buffered
/// prefetch on a worker thread.
pub struct LmBatcher {
    rx: mpsc::Receiver<Batch>,
    _worker: thread::JoinHandle<()>,
}

impl LmBatcher {
    pub fn new(mut gen: CorpusGen, batch: usize, seq: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Batch>(2); // double buffer
        let worker = thread::spawn(move || {
            loop {
                let mut tokens = vec![0u32; batch * seq];
                let mut targets = vec![0u32; batch * seq];
                for b in 0..batch {
                    // generate seq+1 tokens; inputs are [0..seq), targets [1..seq]
                    let mut buf = vec![0u32; seq + 1];
                    gen.fill(&mut buf);
                    tokens[b * seq..(b + 1) * seq].copy_from_slice(&buf[..seq]);
                    targets[b * seq..(b + 1) * seq].copy_from_slice(&buf[1..]);
                }
                if tx.send(Batch { batch, seq, tokens, targets }).is_err() {
                    break; // consumer dropped
                }
            }
        });
        LmBatcher { rx, _worker: worker }
    }

    /// Blocking fetch of the next batch.
    pub fn next(&self) -> Batch {
        self.rx.recv().expect("batcher worker died")
    }
}

/// Synchronous batcher (no thread) for deterministic tests.
pub struct SyncBatcher {
    gen: CorpusGen,
    batch: usize,
    seq: usize,
}

impl SyncBatcher {
    pub fn new(gen: CorpusGen, batch: usize, seq: usize) -> Self {
        SyncBatcher { gen, batch, seq }
    }

    pub fn next(&mut self) -> Batch {
        let (batch, seq) = (self.batch, self.seq);
        let mut tokens = vec![0u32; batch * seq];
        let mut targets = vec![0u32; batch * seq];
        for b in 0..batch {
            let mut buf = vec![0u32; seq + 1];
            self.gen.fill(&mut buf);
            tokens[b * seq..(b + 1) * seq].copy_from_slice(&buf[..seq]);
            targets[b * seq..(b + 1) * seq].copy_from_slice(&buf[1..]);
        }
        Batch { batch, seq, tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_are_shifted_inputs() {
        let gen = CorpusGen::new(128, 5, 0.5);
        let mut b = SyncBatcher::new(gen, 2, 16);
        let batch = b.next();
        // within each row, targets[t] should equal tokens[t+1]
        for row in 0..2 {
            for t in 0..15 {
                assert_eq!(batch.targets[row * 16 + t], batch.tokens[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn prefetch_matches_sync() {
        let sync_gen = CorpusGen::new(128, 6, 0.5);
        let mut sb = SyncBatcher::new(sync_gen, 2, 8);
        let pre_gen = CorpusGen::new(128, 6, 0.5);
        let pb = LmBatcher::new(pre_gen, 2, 8);
        // same seed → same stream regardless of prefetching
        for _ in 0..5 {
            let a = sb.next();
            let b = pb.next();
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.targets, b.targets);
        }
    }

    #[test]
    fn batch_shapes() {
        let gen = CorpusGen::new(64, 7, 0.3);
        let mut b = SyncBatcher::new(gen, 3, 10);
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 30);
        assert_eq!(batch.token_count(), 30);
    }
}
