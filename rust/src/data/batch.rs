//! Language-model batching: pack a token stream into (tokens, targets)
//! next-token-prediction batches, with a background prefetch thread so
//! data generation overlaps compute (the offline stand-in for an async
//! input pipeline).

use super::corpus::CorpusGen;
use std::sync::mpsc;
use std::thread;

/// One LM training batch: `tokens[b][t]` inputs, `targets[b][t]` = the
/// next token. Flattened row-major for direct upload as PJRT literals.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<u32>,
    pub targets: Vec<u32>,
}

impl Batch {
    pub fn token_count(&self) -> usize {
        self.batch * self.seq
    }
}

/// Batches drawn from a [`CorpusGen`] stream with double-buffered
/// prefetch on a worker thread.
pub struct LmBatcher {
    rx: mpsc::Receiver<Batch>,
    _worker: thread::JoinHandle<()>,
}

impl LmBatcher {
    pub fn new(mut gen: CorpusGen, batch: usize, seq: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Batch>(2); // double buffer
        let worker = thread::spawn(move || {
            loop {
                let mut tokens = vec![0u32; batch * seq];
                let mut targets = vec![0u32; batch * seq];
                for b in 0..batch {
                    // generate seq+1 tokens; inputs are [0..seq), targets [1..seq]
                    let mut buf = vec![0u32; seq + 1];
                    gen.fill(&mut buf);
                    tokens[b * seq..(b + 1) * seq].copy_from_slice(&buf[..seq]);
                    targets[b * seq..(b + 1) * seq].copy_from_slice(&buf[1..]);
                }
                if tx.send(Batch { batch, seq, tokens, targets }).is_err() {
                    break; // consumer dropped
                }
            }
        });
        LmBatcher { rx, _worker: worker }
    }

    /// Blocking fetch of the next batch.
    pub fn next(&self) -> Batch {
        self.rx.recv().expect("batcher worker died")
    }
}

/// Synchronous batcher (no thread) for deterministic tests.
pub struct SyncBatcher {
    gen: CorpusGen,
    batch: usize,
    seq: usize,
}

impl SyncBatcher {
    pub fn new(gen: CorpusGen, batch: usize, seq: usize) -> Self {
        SyncBatcher { gen, batch, seq }
    }

    pub fn next(&mut self) -> Batch {
        let (batch, seq) = (self.batch, self.seq);
        let mut tokens = vec![0u32; batch * seq];
        let mut targets = vec![0u32; batch * seq];
        for b in 0..batch {
            let mut buf = vec![0u32; seq + 1];
            self.gen.fill(&mut buf);
            tokens[b * seq..(b + 1) * seq].copy_from_slice(&buf[..seq]);
            targets[b * seq..(b + 1) * seq].copy_from_slice(&buf[1..]);
        }
        Batch { batch, seq, tokens, targets }
    }
}

/// Seed for data shard `shard` of a run seeded with `seed`: SplitMix64
/// decorrelation so shard streams are mutually independent while staying
/// fully determined by (seed, shard).
pub fn shard_seed(seed: u64, shard: u64) -> u64 {
    let mut s = seed ^ shard.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x5EED_DA7A;
    crate::util::rng::splitmix64(&mut s)
}

/// Deterministic per-shard sampler for data-parallel training.
///
/// The *total* batch of a distributed step is the union of `shards`
/// canonical shards; shard `s` draws from an independent [`CorpusGen`]
/// stream derived from `(seed, s)`. The decomposition is a property of
/// the run (like the global batch size), **not** of the worker count, so
/// any mapping of shards onto workers consumes identical token streams —
/// the data-side half of the dist engine's worker-count invariance
/// (`crate::dist`). A single-shard run (`shards == 1`) uses `seed`
/// unchanged and is stream-identical to the plain [`SyncBatcher`].
pub struct ShardSampler {
    inner: SyncBatcher,
    /// This sampler's shard index.
    pub shard: usize,
    /// Total canonical shards in the run.
    pub shards: usize,
}

impl ShardSampler {
    pub fn new(
        vocab: usize,
        seed: u64,
        coherence: f64,
        shard: usize,
        shards: usize,
        batch_per_shard: usize,
        seq: usize,
    ) -> Self {
        assert!(shards > 0 && shard < shards, "shard {shard} out of range 0..{shards}");
        let s = if shards == 1 { seed } else { shard_seed(seed, shard as u64) };
        ShardSampler {
            inner: SyncBatcher::new(CorpusGen::new(vocab, s, coherence), batch_per_shard, seq),
            shard,
            shards,
        }
    }

    /// Next batch of this shard's stream.
    pub fn next(&mut self) -> Batch {
        self.inner.next()
    }

    /// Fast-forward `n` batches (checkpoint resume replays the stream to
    /// the saved cursor — the offline stand-in for a dataset offset).
    pub fn skip(&mut self, n: u64) {
        for _ in 0..n {
            let _ = self.inner.next();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_are_shifted_inputs() {
        let gen = CorpusGen::new(128, 5, 0.5);
        let mut b = SyncBatcher::new(gen, 2, 16);
        let batch = b.next();
        // within each row, targets[t] should equal tokens[t+1]
        for row in 0..2 {
            for t in 0..15 {
                assert_eq!(batch.targets[row * 16 + t], batch.tokens[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn prefetch_matches_sync() {
        let sync_gen = CorpusGen::new(128, 6, 0.5);
        let mut sb = SyncBatcher::new(sync_gen, 2, 8);
        let pre_gen = CorpusGen::new(128, 6, 0.5);
        let pb = LmBatcher::new(pre_gen, 2, 8);
        // same seed → same stream regardless of prefetching
        for _ in 0..5 {
            let a = sb.next();
            let b = pb.next();
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.targets, b.targets);
        }
    }

    #[test]
    fn shard_streams_are_deterministic_and_independent() {
        let mut a = ShardSampler::new(128, 42, 0.5, 0, 4, 2, 16);
        let mut a2 = ShardSampler::new(128, 42, 0.5, 0, 4, 2, 16);
        let mut b = ShardSampler::new(128, 42, 0.5, 1, 4, 2, 16);
        let ba = a.next();
        assert_eq!(ba.tokens, a2.next().tokens, "same (seed, shard) → same stream");
        assert_ne!(ba.tokens, b.next().tokens, "different shards must differ");
    }

    #[test]
    fn single_shard_matches_plain_batcher() {
        let mut plain = SyncBatcher::new(CorpusGen::new(128, 7, 0.5), 4, 8);
        let mut sharded = ShardSampler::new(128, 7, 0.5, 0, 1, 4, 8);
        for _ in 0..3 {
            let p = plain.next();
            let s = sharded.next();
            assert_eq!(p.tokens, s.tokens);
            assert_eq!(p.targets, s.targets);
        }
    }

    #[test]
    fn skip_equals_discarding() {
        let mut a = ShardSampler::new(128, 9, 0.5, 2, 4, 2, 8);
        let mut b = ShardSampler::new(128, 9, 0.5, 2, 4, 2, 8);
        for _ in 0..5 {
            let _ = a.next();
        }
        b.skip(5);
        assert_eq!(a.next().tokens, b.next().tokens);
    }

    #[test]
    fn batch_shapes() {
        let gen = CorpusGen::new(64, 7, 0.3);
        let mut b = SyncBatcher::new(gen, 3, 10);
        let batch = b.next();
        assert_eq!(batch.tokens.len(), 30);
        assert_eq!(batch.token_count(), 30);
    }
}
