//! Synthetic C4-like corpus: a first-order Markov chain over a
//! Zipf-distributed vocabulary, with per-document "topics" that bias the
//! transition rows. Produces token streams with the two properties the
//! optimizer experiments need:
//!
//! 1. learnable structure at several frequency scales — bigram structure
//!    is learned fast (early loss drop), topic structure slowly (late
//!    loss drop), so optimizers that exploit subspace rotation differ
//!    visibly;
//! 2. a Zipf unigram law, so embedding-row gradients have the highly
//!    anisotropic spectrum real text induces (this is what makes
//!    low-rank projection work at all).

use crate::util::{Rng, Zipf};

/// Streaming corpus generator.
pub struct CorpusGen {
    vocab: usize,
    zipf: Zipf,
    rng: Rng,
    /// number of latent topics
    topics: usize,
    /// sparse Markov successor table: for each token, `k` preferred
    /// successors per topic (drawn once, deterministic per seed)
    successors: Vec<Vec<u32>>,
    /// mixing weight of Markov structure vs pure Zipf draw
    pub coherence: f64,
    // current document state
    topic: usize,
    prev: u32,
    remaining_in_doc: usize,
}

impl CorpusGen {
    /// `vocab` ≥ 16; `coherence` ∈ [0,1] controls how predictable the
    /// stream is (0 = i.i.d. Zipf, 1 = deterministic-ish chains).
    pub fn new(vocab: usize, seed: u64, coherence: f64) -> Self {
        assert!(vocab >= 16);
        let mut rng = Rng::new(seed);
        let topics = 8;
        let succ_per_topic = 4;
        let mut successors = Vec::with_capacity(vocab);
        for _tok in 0..vocab {
            let mut s = Vec::with_capacity(topics * succ_per_topic);
            for _ in 0..topics * succ_per_topic {
                // content tokens are 1..vocab; 0 is reserved for BOS
                s.push(1 + rng.below(vocab as u64 - 1) as u32);
            }
            successors.push(s);
        }
        let zipf = Zipf::new(vocab - 1, 1.05);
        let topic = rng.below(topics as u64) as usize;
        let prev = rng.below(vocab as u64) as u32;
        CorpusGen {
            vocab,
            zipf,
            rng,
            topics,
            successors,
            coherence,
            topic,
            prev,
            remaining_in_doc: 64,
        }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Next token in the stream (documents delimited internally by
    /// re-sampling the topic; token 0 is reserved as a BOS marker).
    pub fn next_token(&mut self) -> u32 {
        if self.remaining_in_doc == 0 {
            // new document: new topic, BOS
            self.topic = self.rng.below(self.topics as u64) as usize;
            self.remaining_in_doc = 32 + self.rng.below(96) as usize;
            self.prev = 0;
            return 0;
        }
        self.remaining_in_doc -= 1;
        let tok = if self.rng.f64() < self.coherence {
            // follow the Markov successor table for (prev, topic)
            let succ = &self.successors[self.prev as usize];
            let k = succ.len() / self.topics;
            let base = self.topic * k;
            succ[base + self.rng.below(k as u64) as usize]
        } else {
            // zipf ranks map to content ids 1..vocab (0 stays BOS-only)
            (1 + self.zipf.sample(&mut self.rng) as u32).min(self.vocab as u32 - 1)
        };
        self.prev = tok;
        tok
    }

    /// Fill a buffer with the next `buf.len()` tokens.
    pub fn fill(&mut self, buf: &mut [u32]) {
        for t in buf.iter_mut() {
            *t = self.next_token();
        }
    }

    /// Empirical bigram predictability: fraction of consecutive pairs
    /// (a,b) where b is one of a's preferred successors under any topic.
    /// Diagnostics / tests only.
    pub fn measure_coherence(&mut self, n: usize) -> f64 {
        let mut hits = 0usize;
        let mut total = 0usize;
        let mut prev = self.next_token();
        for _ in 0..n {
            let cur = self.next_token();
            if prev != 0 && cur != 0 {
                total += 1;
                if self.successors[prev as usize].contains(&cur) {
                    hits += 1;
                }
            }
            prev = cur;
        }
        hits as f64 / total.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = CorpusGen::new(256, 7, 0.7);
        let mut b = CorpusGen::new(256, 7, 0.7);
        let mut ba = [0u32; 128];
        let mut bb = [0u32; 128];
        a.fill(&mut ba);
        b.fill(&mut bb);
        assert_eq!(ba, bb);
    }

    #[test]
    fn tokens_in_range() {
        let mut g = CorpusGen::new(128, 8, 0.5);
        for _ in 0..10_000 {
            assert!((g.next_token() as usize) < 128);
        }
    }

    #[test]
    fn coherence_controls_predictability() {
        let mut lo = CorpusGen::new(256, 9, 0.0);
        let mut hi = CorpusGen::new(256, 9, 0.9);
        let c_lo = lo.measure_coherence(20_000);
        let c_hi = hi.measure_coherence(20_000);
        assert!(c_hi > c_lo + 0.3, "hi={c_hi} lo={c_lo}");
    }

    #[test]
    fn zipf_head_dominates() {
        let mut g = CorpusGen::new(512, 10, 0.0);
        let mut counts = vec![0usize; 512];
        for _ in 0..100_000 {
            counts[g.next_token() as usize] += 1;
        }
        let head: usize = counts[..32].iter().sum();
        let tail: usize = counts[256..].iter().sum();
        assert!(head > 5 * tail, "head={head} tail={tail}");
    }

    #[test]
    fn documents_are_delimited() {
        let mut g = CorpusGen::new(128, 11, 0.5);
        let mut bos = 0;
        for _ in 0..50_000 {
            if g.next_token() == 0 {
                bos += 1;
            }
        }
        // doc length 32..128 ⇒ roughly 50000/80 ≈ 600 BOS markers
        assert!((200..2500).contains(&bos), "bos={bos}");
    }
}
