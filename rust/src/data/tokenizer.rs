//! Byte-level tokenizer with a trained merge table (BPE-lite).
//!
//! The PJRT E2E driver trains on synthetic token streams, but the CLI
//! also accepts real text files; this tokenizer maps text ↔ ids with a
//! greedy longest-match over a merge vocabulary trained by pair
//! frequency — enough to exercise the full text → ids → batches path
//! without shipping a pretrained vocab.

use std::collections::HashMap;

/// Byte-level BPE-lite tokenizer. Ids 0..256 are raw bytes (0 doubles as
/// BOS in the synthetic corpus); merged tokens follow.
pub struct ByteTokenizer {
    /// merge table: (left id, right id) → merged id
    merges: HashMap<(u32, u32), u32>,
    /// id → byte sequence
    pieces: Vec<Vec<u8>>,
}

impl ByteTokenizer {
    /// Byte-only tokenizer (no merges).
    pub fn bytes_only() -> Self {
        ByteTokenizer { merges: HashMap::new(), pieces: (0..=255u8).map(|b| vec![b]).collect() }
    }

    /// Train `n_merges` BPE merges from a text sample.
    pub fn train(text: &str, n_merges: usize) -> Self {
        let mut tok = ByteTokenizer::bytes_only();
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        for _ in 0..n_merges {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let new_id = tok.pieces.len() as u32;
            let mut piece = tok.pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&tok.pieces[pair.1 as usize]);
            tok.pieces.push(piece);
            tok.merges.insert(pair, new_id);
            // apply the merge to the working ids
            ids = apply_merge(&ids, pair, new_id);
        }
        tok
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Encode text to ids by byte-split + iterative merge application
    /// (merge priority = merge order, lowest id first).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        loop {
            // find the lowest-id applicable merge present in ids
            let mut best: Option<((u32, u32), u32)> = None;
            for w in ids.windows(2) {
                if let Some(&m) = self.merges.get(&(w[0], w[1])) {
                    if best.map(|(_, b)| m < b).unwrap_or(true) {
                        best = Some(((w[0], w[1]), m));
                    }
                }
            }
            match best {
                Some((pair, id)) => ids = apply_merge(&ids, pair, id),
                None => break,
            }
        }
        ids
    }

    /// Decode ids back to (lossless) bytes → lossy UTF-8 string.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

fn apply_merge(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        let t = ByteTokenizer::bytes_only();
        let s = "hello, lotus! ☺";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn training_compresses() {
        let text = "the cat sat on the mat. the cat sat on the hat. the cat ran.";
        let t = ByteTokenizer::train(text, 20);
        let ids = t.encode(text);
        assert!(ids.len() < text.len(), "{} !< {}", ids.len(), text.len());
        assert_eq!(t.decode(&ids), text);
        assert!(t.vocab_size() > 256);
    }

    #[test]
    fn roundtrip_after_training_on_unseen_text() {
        let t = ByteTokenizer::train("abcabcabcabc", 5);
        let s = "xyz abc unseen ábc";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn deterministic_training() {
        let a = ByteTokenizer::train("banana bandana", 8);
        let b = ByteTokenizer::train("banana bandana", 8);
        assert_eq!(a.encode("banana"), b.encode("banana"));
    }
}
