//! PJRT engine: one CPU client + a compile-once executable cache.
//!
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` (pattern from /opt/xla-example/load_hlo). Artifacts
//! compile lazily on first use and are cached for the rest of the run;
//! `warmup` precompiles a named set so the training loop never stalls.

use super::manifest::Manifest;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// A compiled artifact.
pub struct Executable {
    pub name: String,
    inner: PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns the flattened tuple outputs.
    pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let result = self.inner.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT engine: client + manifest + executable cache.
pub struct Engine {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// compile-time accounting (seconds per artifact), for §Perf
    compile_times: RefCell<HashMap<String, f64>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_times: RefCell::new(HashMap::new()),
        })
    }

    /// Fetch (compiling if needed) an executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let secs = t0.elapsed().as_secs_f64();
        crate::log_debug!("compiled {name} in {secs:.2}s");
        self.compile_times.borrow_mut().insert(name.to_string(), secs);
        let rc = Rc::new(Executable { name: name.to_string(), inner: exe });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Precompile a list of artifacts (training-loop warmup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Run an artifact by name with host literals.
    pub fn run(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.executable(name)?.run(inputs)
    }

    /// Total compile seconds (for the perf report).
    pub fn total_compile_s(&self) -> f64 {
        self.compile_times.borrow().values().sum()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

// Integration tests for the engine live in rust/tests/runtime_pjrt.rs
// (they require built artifacts and the PJRT runtime).
