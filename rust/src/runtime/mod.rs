//! Runtime substrate: the shared-nothing worker [`pool`] used by the
//! Rust-native linalg engine, plus (behind the `pjrt` feature) the PJRT
//! engine that loads HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them from the training hot path. Python never runs here.
//!
//! The pool and the artifact [`manifest`] are always available; the
//! XLA-backed executor ([`exec`]) and literal conversion ([`convert`])
//! need the vendored `xla` crate and are gated behind `--features pjrt`.

pub mod manifest;
pub mod pool;

#[cfg(feature = "pjrt")]
pub mod convert;
#[cfg(feature = "pjrt")]
pub mod exec;

#[cfg(feature = "pjrt")]
pub use convert::{literal_scalar_f32, literal_to_matrix, matrix_to_literal, tokens_to_literal};
#[cfg(feature = "pjrt")]
pub use exec::{Engine, Executable};
pub use manifest::{ArtifactSpec, Manifest, ModelManifest};
pub use pool::Pool;
