//! PJRT runtime: load HLO-text artifacts produced by `python/compile/aot.py`,
//! compile them once on the CPU PJRT client, and execute them from the
//! training hot path. Python never runs here.

pub mod manifest;
pub mod exec;
pub mod convert;

pub use convert::{literal_scalar_f32, literal_to_matrix, matrix_to_literal, tokens_to_literal};
pub use exec::{Engine, Executable};
pub use manifest::{ArtifactSpec, Manifest, ModelManifest};
