//! Artifact manifest: the contract between `aot.py` and the Rust
//! coordinator (names, files, shapes, side rules, model configs).

use crate::models::LlamaConfig;
use crate::util::json::{parse, JsonValue};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor spec in the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// lowrank_adam / rsvd extras
    pub side_left: Option<bool>,
    pub m: Option<usize>,
    pub n: Option<usize>,
    pub rank: Option<usize>,
}

/// Per-config model info mirrored from aot.py.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub config: LlamaConfig,
    pub rank: usize,
    pub batch: usize,
    /// Flat parameter layout (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub configs: BTreeMap<String, ModelManifest>,
}

fn tensor_specs(v: &JsonValue) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|s| {
            let shape = s
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = s.get("dtype").as_str().unwrap_or("f32").to_string();
            Ok(TensorSpec { shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let doc = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;

        let mut artifacts = BTreeMap::new();
        for a in doc.get("artifacts").as_arr().ok_or_else(|| anyhow!("missing artifacts"))? {
            let name =
                a.get("name").as_str().ok_or_else(|| anyhow!("artifact missing name"))?.to_string();
            let file = dir.join(a.get("file").as_str().ok_or_else(|| anyhow!("missing file"))?);
            if !file.exists() {
                bail!("artifact file {file:?} missing");
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    file,
                    inputs: tensor_specs(a.get("inputs"))?,
                    outputs: tensor_specs(a.get("outputs"))?,
                    side_left: match a.get("side_left") {
                        JsonValue::Bool(b) => Some(*b),
                        _ => None,
                    },
                    m: a.get("m").as_usize(),
                    n: a.get("n").as_usize(),
                    rank: a.get("rank").as_usize(),
                },
            );
        }

        let mut configs = BTreeMap::new();
        if let Some(cfgs) = doc.get("configs").as_obj() {
            for (name, c) in cfgs {
                let get = |k: &str| -> Result<usize> {
                    c.get(k).as_usize().ok_or_else(|| anyhow!("config {name} missing {k}"))
                };
                let params = c
                    .get("params")
                    .as_arr()
                    .ok_or_else(|| anyhow!("config {name} missing params"))?
                    .iter()
                    .map(|p| {
                        let pname = p.get("name").as_str().unwrap_or_default().to_string();
                        let shape: Vec<usize> = p
                            .get("shape")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect();
                        (pname, shape)
                    })
                    .collect();
                configs.insert(
                    name.clone(),
                    ModelManifest {
                        name: name.clone(),
                        config: LlamaConfig {
                            vocab: get("vocab")?,
                            d_model: get("d_model")?,
                            n_layers: get("n_layers")?,
                            n_heads: get("n_heads")?,
                            d_ff: get("d_ff")?,
                            seq_len: get("seq_len")?,
                        },
                        rank: get("rank")?,
                        batch: get("batch")?,
                        params,
                    },
                );
            }
        }

        Ok(Manifest { dir, artifacts, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest ({} present)", self.artifacts.len()))
    }

    pub fn config(&self, name: &str) -> Result<&ModelManifest> {
        self.configs.get(name).ok_or_else(|| anyhow!("config '{name}' not in manifest"))
    }

    /// Find the lowrank_adam artifact for a layer shape under a config.
    pub fn lowrank_adam_for(&self, cfg: &str, m: usize, n: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| {
                a.name.starts_with(&format!("lowrank_adam_{cfg}_")) && a.m == Some(m) && a.n == Some(n)
            })
            .ok_or_else(|| anyhow!("no lowrank_adam artifact for {cfg} {m}x{n}"))
    }

    /// Find the rsvd artifact for a layer shape under a config.
    pub fn rsvd_for(&self, cfg: &str, m: usize, n: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .values()
            .find(|a| a.name.starts_with(&format!("rsvd_{cfg}_")) && a.m == Some(m) && a.n == Some(n))
            .ok_or_else(|| anyhow!("no rsvd artifact for {cfg} {m}x{n}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let man = Manifest::load(&dir).unwrap();
        assert!(man.artifacts.contains_key("fwdbwd_tiny"));
        let tiny = man.config("tiny").unwrap();
        assert_eq!(tiny.config.d_model, 128);
        // fwdbwd i/o mirror the param list
        let fb = man.artifact("fwdbwd_tiny").unwrap();
        assert_eq!(fb.inputs.len(), tiny.params.len() + 2);
        assert_eq!(fb.outputs.len(), tiny.params.len() + 1);
        // shape lookups work
        let d = tiny.config.d_model;
        let la = man.lowrank_adam_for("tiny", d, d).unwrap();
        assert_eq!(la.side_left, Some(true));
        assert!(man.rsvd_for("tiny", d, tiny.config.d_ff).is_ok());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent-dir").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
