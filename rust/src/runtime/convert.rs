//! Literal ⇄ Matrix conversion helpers for the PJRT boundary.

use crate::tensor::Matrix;
use anyhow::{bail, Result};
use xla::Literal;

/// Matrix → rank-2 f32 literal.
pub fn matrix_to_literal(m: &Matrix) -> Result<Literal> {
    Ok(Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// Flat slice → rank-1 f32 literal.
pub fn vec_to_literal(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

/// Scalar f32 literal.
pub fn literal_scalar_f32(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Scalar i32 literal.
pub fn literal_scalar_i32(x: i32) -> Literal {
    Literal::scalar(x)
}

/// Token batch (u32 ids) → (batch, seq) i32 literal (aot.py lowers token
/// inputs as i32).
pub fn tokens_to_literal(tokens: &[u32], batch: usize, seq: usize) -> Result<Literal> {
    if tokens.len() != batch * seq {
        bail!("token count {} != {batch}x{seq}", tokens.len());
    }
    let as_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    Ok(Literal::vec1(&as_i32).reshape(&[batch as i64, seq as i64])?)
}

/// Literal (any rank) → Matrix with the given logical (rows, cols).
/// Rank-1 literals become 1×n; scalars 1×1.
pub fn literal_to_matrix(lit: &Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let data: Vec<f32> = lit.to_vec()?;
    if data.len() != rows * cols {
        bail!("literal has {} elements, expected {rows}x{cols}", data.len());
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Literal scalar → f32.
pub fn literal_to_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matrix_roundtrip() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(7, 5, 1.0, &mut rng);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit, 7, 5).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn token_literal_shape() {
        let lit = tokens_to_literal(&[1, 2, 3, 4, 5, 6], 2, 3).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert!(tokens_to_literal(&[1, 2], 2, 3).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = literal_scalar_f32(3.5);
        assert_eq!(literal_to_f32(&lit).unwrap(), 3.5);
    }
}
