//! Shared-nothing worker pool for the Rust-native hot path.
//!
//! Built on `std::thread::scope` — zero external dependencies. A [`Pool`]
//! is just a thread-count policy: each parallel region spawns scoped
//! workers, hands every worker a *disjoint* slice of the output, and
//! joins before returning. There is no shared mutable state, no channel,
//! and no unsafe code; determinism therefore does not depend on the
//! thread count (each output element is produced by exactly one worker,
//! with the same per-element arithmetic order as the serial kernel — see
//! `EXPERIMENTS.md §Perf`).
//!
//! The global pool ([`global`]) sizes itself from the `LOTUS_THREADS`
//! environment variable, falling back to `available_parallelism`. Set
//! `LOTUS_THREADS=1` to force fully serial execution.

use std::cell::Cell;
use std::sync::OnceLock;

thread_local! {
    /// True while this thread is executing a shard of a pool region.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Run a shard with the in-worker marker set (restoring the previous
/// value, so nested regions on the caller thread stay marked).
fn run_marked<F: FnOnce()>(f: F) {
    let prev = IN_WORKER.replace(true);
    f();
    IN_WORKER.set(prev);
}

/// True when called from inside a pool worker shard. Used by
/// [`effective`] so nested parallel regions degrade to serial instead of
/// oversubscribing the machine (e.g. a subspace refit running inside the
/// trainer's per-layer fan-out).
pub fn in_worker() -> bool {
    IN_WORKER.get()
}

/// A worker-pool handle: a thread-count policy for scoped parallel
/// regions. Cheap to copy around; carries no OS resources.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit worker count (clamped to at least 1).
    pub fn with_threads(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Serial pool (used inside outer parallel regions to avoid
    /// oversubscription).
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    /// Pool sized from `LOTUS_THREADS`, else `available_parallelism`.
    pub fn from_env() -> Pool {
        let threads = std::env::var("LOTUS_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Pool::with_threads(threads)
    }

    /// Number of workers this pool fans out to.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Split `data` — logically `rows` rows of `width` contiguous
    /// elements — into one row band per worker and run `f(row_offset,
    /// band)` on every band in parallel. The final band runs on the
    /// calling thread, so a 1-thread pool never spawns.
    ///
    /// Bands partition the rows: every row belongs to exactly one call,
    /// and `row_offset` is the index of the band's first row.
    pub fn par_row_bands<F>(&self, data: &mut [f32], rows: usize, width: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert_eq!(data.len(), rows * width, "band split: bad data length");
        let bands = self.threads.min(rows.max(1));
        if bands <= 1 || width == 0 {
            f(0, data);
            return;
        }
        let base = rows / bands;
        let rem = rows % bands;
        std::thread::scope(|s| {
            let mut rest = data;
            let mut row0 = 0usize;
            for b in 0..bands {
                let band_rows = base + usize::from(b < rem);
                let tmp = std::mem::take(&mut rest);
                let (band, tail) = tmp.split_at_mut(band_rows * width);
                rest = tail;
                let r0 = row0;
                row0 += band_rows;
                if b + 1 == bands {
                    run_marked(|| f(r0, band));
                } else {
                    let fr = &f;
                    s.spawn(move || run_marked(|| fr(r0, band)));
                }
            }
        });
    }

    /// Run `f(index, &mut item)` for every item, distributing contiguous
    /// chunks of items across the workers. Items are shared-nothing: each
    /// is visited exactly once by exactly one worker, so per-item state
    /// (e.g. a per-layer RNG stream) keeps results deterministic at any
    /// thread count.
    pub fn par_items_mut<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n.max(1));
        if workers <= 1 {
            for (i, it) in items.iter_mut().enumerate() {
                f(i, it);
            }
            return;
        }
        let base = n / workers;
        let rem = n % workers;
        std::thread::scope(|s| {
            let mut rest = items;
            let mut idx0 = 0usize;
            for w in 0..workers {
                let take = base + usize::from(w < rem);
                let tmp = std::mem::take(&mut rest);
                let (chunk, tail) = tmp.split_at_mut(take);
                rest = tail;
                let i0 = idx0;
                idx0 += take;
                if w + 1 == workers {
                    run_marked(|| {
                        for (j, it) in chunk.iter_mut().enumerate() {
                            f(i0 + j, it);
                        }
                    });
                } else {
                    let fr = &f;
                    s.spawn(move || {
                        run_marked(|| {
                            for (j, it) in chunk.iter_mut().enumerate() {
                                fr(i0 + j, it);
                            }
                        })
                    });
                }
            }
        });
    }
}

impl Default for Pool {
    fn default() -> Self {
        Pool::from_env()
    }
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool, sized once from the environment
/// (`LOTUS_THREADS`, else `available_parallelism`).
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(Pool::from_env)
}

/// The pool a nested computation should use: the global pool from the
/// main thread, a serial pool from inside a worker shard (so e.g. a
/// subspace refit running under the trainer's per-layer fan-out does not
/// oversubscribe the machine with pool-of-pools threads). Results are
/// unaffected either way — pooled kernels are bit-deterministic at any
/// thread count.
pub fn effective() -> Pool {
    if in_worker() {
        Pool::serial()
    } else {
        *global()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bands_partition_rows_exactly() {
        for threads in [1usize, 2, 3, 8, 64] {
            let pool = Pool::with_threads(threads);
            let (rows, width) = (13usize, 5usize);
            let mut data = vec![0.0f32; rows * width];
            pool.par_row_bands(&mut data, rows, width, |r0, band| {
                let band_rows = band.len() / width;
                for (i, row) in band.chunks_mut(width).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + i + 1) as f32; // += catches double visits
                    }
                }
                assert_eq!(band.len(), band_rows * width);
            });
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(data[r * width + c], (r + 1) as f32, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn items_visited_exactly_once_in_global_index_order() {
        for threads in [1usize, 2, 5, 16] {
            let pool = Pool::with_threads(threads);
            let mut items: Vec<u64> = vec![0; 11];
            pool.par_items_mut(&mut items, |i, it| {
                *it += i as u64 + 100;
            });
            for (i, it) in items.iter().enumerate() {
                assert_eq!(*it, i as u64 + 100, "threads={threads}");
            }
        }
    }

    #[test]
    fn more_threads_than_work_is_fine() {
        let pool = Pool::with_threads(32);
        let mut data = vec![0.0f32; 2];
        pool.par_row_bands(&mut data, 1, 2, |r0, band| {
            assert_eq!(r0, 0);
            band.fill(3.0);
        });
        assert_eq!(data, vec![3.0, 3.0]);
        let mut none: Vec<u32> = Vec::new();
        pool.par_items_mut(&mut none, |_, _| panic!("no items"));
    }

    #[test]
    fn nested_regions_degrade_to_serial() {
        let pool = Pool::with_threads(4);
        let mut flags = vec![false; 6];
        pool.par_items_mut(&mut flags, |_, flag| {
            *flag = in_worker();
            // a nested computation asks for the effective pool
            assert_eq!(effective().threads(), 1);
        });
        assert!(flags.iter().all(|&f| f), "shards must be marked as workers");
        assert!(!in_worker(), "marker must be restored on the caller thread");
    }

    #[test]
    fn env_override_parses() {
        // Can't mutate the process env safely in tests; just exercise the
        // constructors.
        assert_eq!(Pool::with_threads(0).threads(), 1);
        assert!(Pool::from_env().threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(global().threads() >= 1);
    }
}
