//! # Lotus
//!
//! A production-grade reproduction of *"Lotus: Efficient LLM Training by
//! Randomized Low-Rank Gradient Projection with Adaptive Subspace
//! Switching"* (Miao, Bao & Zhang, 2026).
//!
//! Lotus trains large models with GaLore-style low-rank gradient
//! projection, but replaces the exact SVD of the gradient with a
//! power-iteration randomized SVD ([`linalg::rsvd`]) and replaces the
//! fixed subspace-refresh interval with an *adaptive* switching policy
//! ([`subspace::LotusAdaSS`]) driven by the displacement of the unit
//! gradient inside the current subspace (Algorithm 1 of the paper).
//!
//! ## Architecture (three layers)
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`)
//!   implement the projection hot path; they are lowered together with
//! * **L2** — JAX compute graphs (model fwd/bwd, projected optimizer
//!   steps) into HLO-text artifacts, which
//! * **L3** — this crate — loads through PJRT ([`runtime`]) and drives
//!   from the training coordinator ([`train`]). Python never runs on the
//!   training path.
//!
//! A Rust-native simulator ([`sim`]) re-implements every optimizer on the
//! in-crate [`linalg`] substrate; it powers the paper-table benches and
//! cross-checks the PJRT path.
//!
//! ## Quick start
//!
//! ```no_run
//! use lotus::config::presets;
//! use lotus::sim::trainer::{Method, SimTrainer};
//!
//! let cfg = presets::llama_tiny();
//! let mut t = SimTrainer::new(&cfg, Method::lotus_default(), 42);
//! let report = t.train(200);
//! println!("final ppl = {:.2}", report.final_ppl);
//! ```

// Style policy for `cargo clippy -- -D warnings` (CI): the numeric
// kernels index raw buffers on purpose (explicit bounds keep the
// f64-accumulation order auditable and match the JAX reference graphs),
// and the trainer plumbing passes wide argument lists / slice-of-tuple
// jobs by design. These lints fight that style; everything else is
// denied.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::new_without_default,
    clippy::large_enum_variant
)]

pub mod util;
pub mod tensor;
pub mod quant;
pub mod linalg;
pub mod projection;
pub mod subspace;
pub mod optim;
pub mod memcount;
pub mod data;
pub mod models;
pub mod config;
pub mod eval;
pub mod sim;
pub mod runtime;
pub mod train;
pub mod serve;
pub mod faults;
pub mod dist;
pub mod proptest;
pub mod cli;
pub mod bench;
pub mod telemetry;
