//! Deterministic fault injection for the training runtime.
//!
//! A [`FaultPlan`] is a seeded, schedule-driven list of faults: payload
//! faults (drop / delay / bit-flip / duplicate) fired against specific
//! cross-worker tree-reduce transfers, and step faults (worker death,
//! NaN gradient, silent weight corruption) fired at the top of a
//! training step. The [`FaultInjector`] walks the schedule exactly once
//! per event, so a retried or rolled-back trajectory re-executes the
//! faulted region *clean* — which is what makes bit-identity with a
//! fault-free oracle run a meaningful recovery test.
//!
//! The only source of randomness is the plan seed (used to pick which
//! word/bit a `BitFlip` corrupts); everything else is a deterministic
//! schedule, so two runs of the same plan inject byte-identical faults.

use crate::util::rng::Rng;
use std::collections::VecDeque;

/// One kind of injected fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Payload never arrives; the receiver times out and requests a resend.
    Drop,
    /// Payload arrives late; costs one backoff unit, no retry needed.
    Delay,
    /// One word of the payload has one bit flipped in flight (caught by
    /// the checksum; the seeded RNG picks word and bit).
    BitFlip,
    /// Payload arrives twice; the receiver de-duplicates by sequence id.
    Duplicate,
    /// Worker `w` dies at the top of the step; the engine re-shards onto
    /// the survivors.
    KillWorker(usize),
    /// Poison one gradient entry with NaN after the gradient fan-out
    /// (models an SDC in the backward pass).
    NanGrad,
    /// Silently scale one weight matrix at the top of the step (models a
    /// corrupted parameter update), producing a loss spike.
    CorruptWeights,
    /// Serving lane `k` dies mid-decode at the top of the serve step; the
    /// engine requeues its in-flight request for a token-identical retry.
    LaneKill(usize),
    /// Deadline storm: the serve clock jumps forward at the top of the
    /// step, expiring every over-deadline queued request at once.
    Stall,
    /// The checkpoint container is mangled on the next reload. Fired at
    /// load time, not at a step (`ckpt_corrupt@load`).
    CkptCorrupt,
    /// Shard `s` casts a false-positive rollback vote at the step — no
    /// arithmetic perturbation, exercising quorum rejection.
    FalseVote(usize),
}

impl FaultKind {
    /// Payload faults target tree-reduce transfers; step faults target
    /// the training step itself.
    pub fn is_payload(&self) -> bool {
        matches!(
            self,
            FaultKind::Drop | FaultKind::Delay | FaultKind::BitFlip | FaultKind::Duplicate
        )
    }

    /// Serve-path faults target the serving engine's step loop.
    pub fn is_serve(&self) -> bool {
        matches!(self, FaultKind::LaneKill(_) | FaultKind::Stall)
    }

    /// Load-scoped faults fire when a checkpoint is (re)loaded.
    pub fn is_load(&self) -> bool {
        matches!(self, FaultKind::CkptCorrupt)
    }
}

/// One scheduled fault: a kind, the step it fires at, and (for payload
/// faults) which cross-worker transfer within that step it targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// Step the fault fires at (1-based). Step `0` is reserved for
    /// load-scoped events (`@load`).
    pub step: u64,
    /// Index of the cross-worker payload within the step (payload faults
    /// only; the `#k` suffix in the spec, default 0).
    pub edge: u64,
}

impl FaultEvent {
    /// Render this event back to its compact spec form; parsing the
    /// result reproduces the event exactly (round-trip).
    pub fn to_spec(&self) -> String {
        let head = match self.kind {
            FaultKind::Drop => "drop".to_string(),
            FaultKind::Delay => "delay".to_string(),
            FaultKind::BitFlip => "flip".to_string(),
            FaultKind::Duplicate => "dup".to_string(),
            FaultKind::KillWorker(w) => format!("kill{w}"),
            FaultKind::NanGrad => "nan".to_string(),
            FaultKind::CorruptWeights => "spike".to_string(),
            FaultKind::LaneKill(l) => format!("lane{l}"),
            FaultKind::Stall => "stall".to_string(),
            FaultKind::CkptCorrupt => "ckpt_corrupt".to_string(),
            FaultKind::FalseVote(s) => format!("vote{s}"),
        };
        let mut out = if self.kind.is_load() {
            format!("{head}@load")
        } else {
            format!("{head}@{}", self.step)
        };
        if self.kind.is_payload() && self.edge != 0 {
            out.push_str(&format!("#{}", self.edge));
        }
        out
    }
}

/// Typed parse error for one `--fault-plan` / `[faults]` spec entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlanError {
    /// Entry has no `@step` part.
    MissingStep { entry: String },
    /// Step is not an unsigned integer.
    BadStep { entry: String, step: String },
    /// Steps are 1-based.
    ZeroStep { entry: String },
    /// `#edge` suffix is not an unsigned integer.
    BadEdge { entry: String, edge: String },
    /// `kill`/`lane`/`vote` index is not an unsigned integer.
    BadIndex { entry: String, kind: String },
    /// Unrecognised fault kind.
    UnknownKind { entry: String, kind: String },
    /// `#edge` on a fault that is not payload-scoped.
    EdgeOnNonPayload { entry: String },
    /// `@load` on a step-scoped fault, or a numeric step on a
    /// load-scoped one.
    BadLoadStep { entry: String },
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::MissingStep { entry } => {
                write!(f, "fault entry '{entry}' is missing '@step'")
            }
            PlanError::BadStep { entry, step } => {
                write!(f, "fault entry '{entry}': bad step '{step}'")
            }
            PlanError::ZeroStep { entry } => write!(f, "fault entry '{entry}': steps are 1-based"),
            PlanError::BadEdge { entry, edge } => {
                write!(f, "fault entry '{entry}': bad edge '{edge}'")
            }
            PlanError::BadIndex { entry, kind } => {
                write!(f, "fault entry '{entry}': bad index in '{kind}'")
            }
            PlanError::UnknownKind { entry, kind } => {
                write!(f, "unknown fault kind '{kind}' in '{entry}'")
            }
            PlanError::EdgeOnNonPayload { entry } => {
                write!(f, "fault entry '{entry}': '#edge' only applies to payload faults")
            }
            PlanError::BadLoadStep { entry } => write!(
                f,
                "fault entry '{entry}': 'ckpt_corrupt' fires '@load', other kinds need '@step'"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// A seeded fault schedule, parsed from `--fault-plan` / `[faults]`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a compact spec string: comma-separated `kind@step` entries.
    ///
    /// Kinds: `drop`, `delay`, `flip`, `dup`, `nan`, `spike`, `stall`,
    /// `killW` (W = worker index, e.g. `kill0`), `laneK` (K = serve lane
    /// slot), `voteS` (S = shard casting a false rollback vote), and
    /// `ckpt_corrupt@load` (fires on the next checkpoint reload instead
    /// of at a step). Payload kinds accept an optional `#k` suffix
    /// selecting the k-th cross-worker transfer of the step.
    ///
    /// Example: `"flip@2,drop@3#1,kill0@6,nan@8,lane1@5,ckpt_corrupt@load"`.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, PlanError> {
        let mut events = Vec::new();
        for raw in spec.split(',') {
            let entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            events.push(Self::parse_entry(entry)?);
        }
        Ok(FaultPlan { seed, events })
    }

    fn parse_entry(entry: &str) -> Result<FaultEvent, PlanError> {
        let owned = || entry.to_string();
        let (head, tail) = entry
            .split_once('@')
            .ok_or_else(|| PlanError::MissingStep { entry: owned() })?;
        let (step_str, edge_str) = match tail.split_once('#') {
            Some((s, e)) => (s, Some(e)),
            None => (tail, None),
        };
        let kind = match head {
            "drop" => FaultKind::Drop,
            "delay" => FaultKind::Delay,
            "flip" => FaultKind::BitFlip,
            "dup" => FaultKind::Duplicate,
            "nan" => FaultKind::NanGrad,
            "spike" => FaultKind::CorruptWeights,
            "stall" => FaultKind::Stall,
            "ckpt_corrupt" => FaultKind::CkptCorrupt,
            k if k.starts_with("kill") => FaultKind::KillWorker(Self::parse_index(entry, k)?),
            k if k.starts_with("lane") => FaultKind::LaneKill(Self::parse_index(entry, k)?),
            k if k.starts_with("vote") => FaultKind::FalseVote(Self::parse_index(entry, k)?),
            other => {
                return Err(PlanError::UnknownKind { entry: owned(), kind: other.to_string() })
            }
        };
        let step: u64 = if step_str == "load" {
            if !kind.is_load() {
                return Err(PlanError::BadLoadStep { entry: owned() });
            }
            0
        } else if kind.is_load() {
            return Err(PlanError::BadLoadStep { entry: owned() });
        } else {
            let step = step_str
                .parse()
                .map_err(|_| PlanError::BadStep { entry: owned(), step: step_str.to_string() })?;
            if step == 0 {
                return Err(PlanError::ZeroStep { entry: owned() });
            }
            step
        };
        let edge: u64 = match edge_str {
            Some(e) => {
                if !kind.is_payload() {
                    return Err(PlanError::EdgeOnNonPayload { entry: owned() });
                }
                e.parse().map_err(|_| PlanError::BadEdge { entry: owned(), edge: e.to_string() })?
            }
            None => 0,
        };
        Ok(FaultEvent { kind, step, edge })
    }

    /// Numeric tail of a `kill{W}` / `lane{K}` / `vote{S}` head (the
    /// first four chars are the kind word).
    fn parse_index(entry: &str, head: &str) -> Result<usize, PlanError> {
        head[4..].parse().map_err(|_| PlanError::BadIndex {
            entry: entry.to_string(),
            kind: head.to_string(),
        })
    }

    /// Render the plan back to its compact spec form (see
    /// [`FaultEvent::to_spec`]); `parse(to_spec(p), p.seed) == p`.
    pub fn to_spec(&self) -> String {
        self.events.iter().map(|e| e.to_spec()).collect::<Vec<_>>().join(",")
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Counters for faults actually injected (vs merely scheduled).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub drops: u64,
    pub delays: u64,
    pub bit_flips: u64,
    pub duplicates: u64,
    pub worker_kills: u64,
    pub nan_grads: u64,
    pub weight_corruptions: u64,
    pub lane_kills: u64,
    pub stalls: u64,
    pub ckpt_corruptions: u64,
    pub false_votes: u64,
}

impl FaultStats {
    pub fn total(&self) -> u64 {
        self.drops
            + self.delays
            + self.bit_flips
            + self.duplicates
            + self.worker_kills
            + self.nan_grads
            + self.weight_corruptions
            + self.lane_kills
            + self.stalls
            + self.ckpt_corruptions
            + self.false_votes
    }
}

/// Walks a [`FaultPlan`], firing each event exactly once.
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    fired: Vec<bool>,
    rng: Rng,
    step: u64,
    payload_seq: u64,
    pub stats: FaultStats,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let n = plan.events.len();
        let rng = Rng::new(plan.seed ^ 0xFA_017);
        FaultInjector { plan, fired: vec![false; n], rng, step: 0, payload_seq: 0, stats: FaultStats::default() }
    }

    /// Arm the injector for a new training step (resets the per-step
    /// payload sequence counter).
    pub fn begin_step(&mut self, step: u64) {
        self.step = step;
        self.payload_seq = 0;
    }

    /// Step-scoped faults (kill / NaN / weight corruption / false vote)
    /// scheduled for the current step. Each fires once.
    pub fn step_faults(&mut self) -> Vec<FaultKind> {
        let mut out = Vec::new();
        for (i, ev) in self.plan.events.iter().enumerate() {
            if self.fired[i]
                || ev.is_payload_event()
                || ev.kind.is_serve()
                || ev.kind.is_load()
                || ev.step != self.step
            {
                continue;
            }
            self.fired[i] = true;
            match ev.kind {
                FaultKind::KillWorker(_) => self.stats.worker_kills += 1,
                FaultKind::NanGrad => self.stats.nan_grads += 1,
                FaultKind::CorruptWeights => self.stats.weight_corruptions += 1,
                FaultKind::FalseVote(_) => self.stats.false_votes += 1,
                _ => unreachable!(),
            }
            out.push(ev.kind);
        }
        out
    }

    /// Serve-path faults (lane kill / stall) scheduled for the current
    /// step. Each fires once.
    pub fn serve_faults(&mut self) -> Vec<FaultKind> {
        let mut out = Vec::new();
        for (i, ev) in self.plan.events.iter().enumerate() {
            if self.fired[i] || !ev.kind.is_serve() || ev.step != self.step {
                continue;
            }
            self.fired[i] = true;
            match ev.kind {
                FaultKind::LaneKill(_) => self.stats.lane_kills += 1,
                FaultKind::Stall => self.stats.stalls += 1,
                _ => unreachable!(),
            }
            out.push(ev.kind);
        }
        out
    }

    /// Load-scoped fault (checkpoint container corruption): fires once on
    /// the next checkpoint reload, regardless of the current step.
    pub fn load_fault(&mut self) -> bool {
        for (i, ev) in self.plan.events.iter().enumerate() {
            if self.fired[i] || !ev.kind.is_load() {
                continue;
            }
            self.fired[i] = true;
            self.stats.ckpt_corruptions += 1;
            return true;
        }
        false
    }

    /// Payload fault targeting the next cross-worker transfer of this
    /// step, if one is scheduled. Call once per transfer with
    /// `first_attempt = true`; retries pass `false` so resent payloads
    /// travel clean and the sequence numbering stays stable.
    pub fn payload_fault(&mut self, first_attempt: bool) -> Option<FaultKind> {
        if !first_attempt {
            return None;
        }
        let seq = self.payload_seq;
        self.payload_seq += 1;
        for (i, ev) in self.plan.events.iter().enumerate() {
            if self.fired[i] || !ev.is_payload_event() || ev.step != self.step || ev.edge != seq {
                continue;
            }
            self.fired[i] = true;
            match ev.kind {
                FaultKind::Drop => self.stats.drops += 1,
                FaultKind::Delay => self.stats.delays += 1,
                FaultKind::BitFlip => self.stats.bit_flips += 1,
                FaultKind::Duplicate => self.stats.duplicates += 1,
                _ => unreachable!(),
            }
            return Some(ev.kind);
        }
        None
    }

    /// Corrupt one word of a payload in flight: the seeded RNG picks the
    /// word and the bit. Guaranteed to change the bit pattern.
    pub fn flip_word(&mut self, data: &mut [f32]) {
        if data.is_empty() {
            return;
        }
        let idx = self.rng.below(data.len() as u64) as usize;
        let bit = self.rng.below(32) as u32;
        data[idx] = f32::from_bits(data[idx].to_bits() ^ (1u32 << bit));
    }

    /// Corrupt one byte of an encoded (quantized-wire) payload in
    /// flight. Same two RNG draws as [`FaultInjector::flip_word`], so a
    /// fault plan consumes the injector stream identically whichever
    /// wire dtype carries the payload.
    pub fn flip_byte(&mut self, data: &mut [u8]) {
        if data.is_empty() {
            return;
        }
        let idx = self.rng.below(data.len() as u64) as usize;
        let bit = self.rng.below(8) as u32;
        data[idx] ^= 1u8 << bit;
    }
}

impl FaultEvent {
    fn is_payload_event(&self) -> bool {
        self.kind.is_payload()
    }
}

/// Numerical-guard configuration for the recovery layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GuardCfg {
    /// Window length for the loss-spike detector (detection starts once
    /// the window is full).
    pub spike_window: usize,
    /// A loss is a spike when it exceeds `spike_factor` x window mean.
    pub spike_factor: f64,
    /// Give up rolling back after this many rollbacks (prevents a
    /// genuine divergence from looping forever).
    pub max_rollbacks: u32,
    /// Global gradient-norm clip threshold, applied per shard after the
    /// non-finite guard and *before* the spike detector's loss signal
    /// (0.0 = off, the bit-exact default). Clipping canonical per-shard
    /// gradients keeps the result worker-invariant, and a 1-shard run
    /// clips exactly like the sim trainer.
    pub clip_norm: f64,
}

impl Default for GuardCfg {
    fn default() -> Self {
        GuardCfg { spike_window: 8, spike_factor: 2.5, max_rollbacks: 4, clip_norm: 0.0 }
    }
}

/// Windowed loss-spike detector: flags a loss that exceeds
/// `factor x mean(window)` once the window is full. Spiky losses are
/// *not* folded into the window, so a rollback that replays the same
/// region sees the same history.
#[derive(Clone, Debug)]
pub struct SpikeDetector {
    window: VecDeque<f64>,
    cfg: GuardCfg,
}

impl SpikeDetector {
    pub fn new(cfg: GuardCfg) -> SpikeDetector {
        SpikeDetector { window: VecDeque::with_capacity(cfg.spike_window.max(1)), cfg }
    }

    /// Observe one loss. Returns `true` (and leaves the window untouched)
    /// when the loss is a spike; otherwise folds it into the window.
    pub fn observe(&mut self, loss: f64) -> bool {
        let full = self.window.len() >= self.cfg.spike_window;
        if full && loss.is_finite() {
            let mean: f64 = self.window.iter().sum::<f64>() / self.window.len() as f64;
            if loss > self.cfg.spike_factor * mean.max(1e-12) {
                return true;
            }
        }
        if full {
            self.window.pop_front();
        }
        self.window.push_back(loss);
        false
    }

    /// Forget all history (call after a rollback).
    pub fn reset(&mut self) {
        self.window.clear();
    }
}

/// Recovery-layer counters surfaced in `DistReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryStats {
    /// Steps abandoned because of a non-finite loss/gradient with no
    /// checkpoint to roll back to.
    pub skipped_steps: u64,
    /// Rollbacks to the last good periodic checkpoint.
    pub rollbacks: u64,
    /// Workers declared dead and re-sharded away.
    pub worker_deaths: u64,
    /// Loss spikes flagged by the windowed detector.
    pub loss_spikes: u64,
    /// Steps on which global-norm clipping rescaled at least one shard
    /// gradient (`clip_norm > 0` only).
    pub clipped_steps: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let p = FaultPlan::parse("flip@2,drop@3#1, dup@4 ,delay@5,kill1@6,nan@8,spike@10", 7)
            .unwrap();
        assert_eq!(p.events.len(), 7);
        assert_eq!(p.events[0], FaultEvent { kind: FaultKind::BitFlip, step: 2, edge: 0 });
        assert_eq!(p.events[1], FaultEvent { kind: FaultKind::Drop, step: 3, edge: 1 });
        assert_eq!(p.events[4], FaultEvent { kind: FaultKind::KillWorker(1), step: 6, edge: 0 });
        assert_eq!(p.events[6], FaultEvent { kind: FaultKind::CorruptWeights, step: 10, edge: 0 });
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("flip", 0).is_err());
        assert!(FaultPlan::parse("flip@x", 0).is_err());
        assert!(FaultPlan::parse("flip@0", 0).is_err());
        assert!(FaultPlan::parse("zap@3", 0).is_err());
        assert!(FaultPlan::parse("kill@3", 0).is_err());
        assert!(FaultPlan::parse("nan@3#2", 0).is_err());
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
    }

    #[test]
    fn malformed_entries_yield_the_expected_typed_error() {
        let err = |s: &str| FaultPlan::parse(s, 0).unwrap_err();
        assert!(matches!(err("flip"), PlanError::MissingStep { .. }));
        assert!(matches!(err("flip@x"), PlanError::BadStep { .. }));
        assert!(matches!(err("flip@-1"), PlanError::BadStep { .. }));
        assert!(matches!(err("flip@0"), PlanError::ZeroStep { .. }));
        assert!(matches!(err("flip@2#y"), PlanError::BadEdge { .. }));
        assert!(matches!(err("kill@3"), PlanError::BadIndex { .. }));
        assert!(matches!(err("lane@3"), PlanError::BadIndex { .. }));
        assert!(matches!(err("votex@3"), PlanError::BadIndex { .. }));
        assert!(matches!(err("zap@3"), PlanError::UnknownKind { .. }));
        assert!(matches!(err("nan@3#2"), PlanError::EdgeOnNonPayload { .. }));
        assert!(matches!(err("lane0@3#1"), PlanError::EdgeOnNonPayload { .. }));
        assert!(matches!(err("nan@load"), PlanError::BadLoadStep { .. }));
        assert!(matches!(err("ckpt_corrupt@5"), PlanError::BadLoadStep { .. }));
        // Errors render through Display without panicking.
        for s in ["flip", "flip@x", "flip@0", "flip@2#y", "kill@3", "zap@3", "nan@load"] {
            assert!(!err(s).to_string().is_empty());
        }
    }

    #[test]
    fn parses_serve_and_load_kinds() {
        let p =
            FaultPlan::parse("lane2@5,stall@7,ckpt_corrupt@load,vote1@9", 3).unwrap();
        assert_eq!(p.events[0], FaultEvent { kind: FaultKind::LaneKill(2), step: 5, edge: 0 });
        assert_eq!(p.events[1], FaultEvent { kind: FaultKind::Stall, step: 7, edge: 0 });
        assert_eq!(p.events[2], FaultEvent { kind: FaultKind::CkptCorrupt, step: 0, edge: 0 });
        assert_eq!(p.events[3], FaultEvent { kind: FaultKind::FalseVote(1), step: 9, edge: 0 });
    }

    #[test]
    fn every_event_kind_round_trips_through_to_spec() {
        let spec = "drop@1,delay@2,flip@3#2,dup@4#1,kill1@6,nan@8,spike@10,\
                    lane0@5,stall@7,ckpt_corrupt@load,vote2@9";
        let p = FaultPlan::parse(spec, 11).unwrap();
        assert_eq!(p.events.len(), 11);
        let rendered = p.to_spec();
        let q = FaultPlan::parse(&rendered, 11).unwrap();
        assert_eq!(p, q, "parse(to_spec(p)) must reproduce the plan");
        // And to_spec of the reparse is a fixed point.
        assert_eq!(rendered, q.to_spec());
    }

    #[test]
    fn serve_and_load_events_fire_exactly_once() {
        let plan = FaultPlan::parse("lane1@2,stall@2,ckpt_corrupt@load,nan@2", 1).unwrap();
        let mut inj = FaultInjector::new(plan);
        inj.begin_step(2);
        // Step faults do not leak serve/load events.
        assert_eq!(inj.step_faults(), vec![FaultKind::NanGrad]);
        let serve = inj.serve_faults();
        assert_eq!(serve, vec![FaultKind::LaneKill(1), FaultKind::Stall]);
        assert!(inj.serve_faults().is_empty(), "serve events fire once");
        assert!(inj.load_fault(), "load event pending");
        assert!(!inj.load_fault(), "load event fires once");
        assert_eq!(inj.stats.lane_kills, 1);
        assert_eq!(inj.stats.stalls, 1);
        assert_eq!(inj.stats.ckpt_corruptions, 1);
        assert_eq!(inj.stats.total(), 4);
    }

    #[test]
    fn events_fire_exactly_once() {
        let plan = FaultPlan::parse("flip@2,kill0@2", 1).unwrap();
        let mut inj = FaultInjector::new(plan);
        inj.begin_step(1);
        assert!(inj.step_faults().is_empty());
        assert_eq!(inj.payload_fault(true), None);
        inj.begin_step(2);
        assert_eq!(inj.step_faults(), vec![FaultKind::KillWorker(0)]);
        assert_eq!(inj.payload_fault(true), Some(FaultKind::BitFlip));
        // Re-entering the same step (rollback replay) injects nothing.
        inj.begin_step(2);
        assert!(inj.step_faults().is_empty());
        assert_eq!(inj.payload_fault(true), None);
        assert_eq!(inj.stats.bit_flips, 1);
        assert_eq!(inj.stats.worker_kills, 1);
    }

    #[test]
    fn payload_edge_index_selects_transfer() {
        let plan = FaultPlan::parse("drop@1#2", 0).unwrap();
        let mut inj = FaultInjector::new(plan);
        inj.begin_step(1);
        assert_eq!(inj.payload_fault(true), None); // seq 0
        assert_eq!(inj.payload_fault(false), None); // retry: no seq advance
        assert_eq!(inj.payload_fault(true), None); // seq 1
        assert_eq!(inj.payload_fault(true), Some(FaultKind::Drop)); // seq 2
    }

    #[test]
    fn flip_word_changes_exactly_one_word() {
        let plan = FaultPlan { seed: 3, events: vec![] };
        let mut inj = FaultInjector::new(plan);
        let mut data = vec![1.0f32; 16];
        inj.flip_word(&mut data);
        let changed = data.iter().filter(|&&x| x.to_bits() != 1.0f32.to_bits()).count();
        assert_eq!(changed, 1);
    }

    #[test]
    fn spike_detector_needs_full_window_and_spares_spikes() {
        let cfg = GuardCfg { spike_window: 4, spike_factor: 2.0, ..GuardCfg::default() };
        let mut d = SpikeDetector::new(cfg);
        // Window not full yet: even a huge loss is not flagged.
        assert!(!d.observe(1.0));
        assert!(!d.observe(1.0));
        assert!(!d.observe(1.0));
        assert!(!d.observe(100.0));
        // Window mean is now ~25.75; 10.0 is fine, 100.0 again is spiky.
        assert!(!d.observe(10.0));
        assert!(d.observe(1000.0));
        // The spike was not folded in: same value still spikes.
        assert!(d.observe(1000.0));
        d.reset();
        assert!(!d.observe(1000.0));
    }
}
