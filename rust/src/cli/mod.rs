//! Hand-rolled CLI argument parsing (offline stand-in for `clap`):
//! subcommands, `--flag value` options, `--switch` booleans, positional
//! arguments, and generated help text.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // first non-flag token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    // "--" ends option parsing
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{s}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Top-level help text for the `lotus` binary.
pub fn help() -> &'static str {
    "lotus — efficient LLM training via randomized low-rank gradient projection\n\
     \n\
     USAGE: lotus <COMMAND> [OPTIONS]\n\
     \n\
     COMMANDS:\n\
       train      pre-train on the synthetic C4-like corpus (PJRT path)\n\
       sim        pre-train with the Rust-native simulator (no artifacts)\n\
       finetune   run the GLUE-sim fine-tuning suite\n\
       generate   one-shot decoding from a trained checkpoint (KV cache)\n\
       serve      continuous-batching engine over a synthetic request\n\
                  trace; prints throughput + latency percentiles\n\
       inspect    print config / artifact manifest / HLO stats\n\
       sweep      sweep methods × sizes and print a paper-style table\n\
       methods    print the optimizer registry (projector, policy,\n\
                  checkpoint/dist/pjrt support, analytic state bytes)\n\
       faults     fault-injection demo: run a seeded fault schedule\n\
                  against a dist training run and verify the recovered\n\
                  weights match the fault-free oracle bit-for-bit\n\
       report     digest a --metrics-out JSONL stream: per-phase time\n\
                  breakdown + switch-cadence table (--check validates\n\
                  trace/metrics files instead)\n\
       analyze    cross-run diagnostics over JSONL streams: switch-quality\n\
                  and cadence tables, anomaly flags, run-vs-run deltas\n\
                  (--baseline), bench trend checks (--bench)\n\
       top        live per-layer dashboard tailing a --prom-out snapshot\n\
                  (capture ratio, subspace age, loss, comm bytes, serve\n\
                  queue depth)\n\
     \n\
     COMMON OPTIONS:\n\
       --config <file.toml>   load a run configuration\n\
       --preset <name>        named preset (pretrain-20m, pretrain-100m, tiny)\n\
       --method <name>        full|galore|lowrank|lora|relora|adarankgrad|apollo|lotus|rsvd-fixed\n\
                              (adopts the registry's per-method lr/scale\n\
                              defaults unless --config/--preset chose them;\n\
                              --lr/--galore-scale override either way)\n\
       --rank <r>             projection rank\n\
       --steps <n>            training steps\n\
       --batch <n>            batch size\n\
       --lr <f>               learning rate\n\
       --galore-scale <f>     scale of the lifted low-rank update\n\
       --gamma <f>            Lotus displacement threshold (default 0.01)\n\
       --eta <n>              Lotus verifying gap (default 50)\n\
       --interval <n>         fixed switch interval (GaLore et al.)\n\
       --decay <f>            AdaRankGrad rank-decay factor (default 0.85)\n\
       --workers <n>          data-parallel worker count (sim path; low-rank\n\
                              gradient exchange + subspace consensus)\n\
       --shards <n>           canonical data shards (default: = workers; fixes\n\
                              the arithmetic so worker counts are comparable)\n\
       --quorum <f>           consensus quorum fraction in (0,1] (default 0.5)\n\
       --wire-dtype <d>       f32|bf16|int8: quantize dist all-reduce payloads\n\
                              on the wire (accumulation stays f32; int8 is\n\
                              blockwise symmetric with per-block scales)\n\
       --kv-dtype <d>         f32|bf16: K/V cache storage for generate/serve\n\
                              (bf16 halves the cache footprint)\n\
       --state-dtype <d>      f32|bf16|int8: Adam moment storage (8-bit via\n\
                              the blockwise codec; checkpoints still\n\
                              round-trip bit-exactly)\n\
       --int8-block <n>       int8 codec block size (default 64)\n\
       --seed <n>             RNG seed\n\
       --out <dir>            output directory (default runs/)\n\
       --artifacts <dir>      artifact directory (default artifacts/)\n\
       --verbose              debug logging\n\
     \n\
     TELEMETRY:\n\
       --trace-out <file>     write a Chrome trace_event JSON of the run's\n\
                              phase spans (chrome://tracing, Perfetto)\n\
       --metrics-out <file>   write a structured JSONL event stream: per-step\n\
                              loss/grad-norm/displacement, switch events,\n\
                              comm bytes, serve queue depth, log lines\n\
       lotus report --metrics <file> [--trace <file>] [--check]\n\
                              render phase/switch tables from those files\n\
       lotus report --metrics <file> --registry\n\
                              render the trailing instrument snapshot\n\
                              (counters/gauges/histograms + comm/wire bytes)\n\
       --trace-mode <m>       full (default) keeps every trace event; ring\n\
                              keeps only the newest --trace-cap complete\n\
                              events (bounded memory on long runs)\n\
       --trace-cap <n>        ring capacity in events (default 4096)\n\
       --prom-out <file>      atomically rewrite a Prometheus text-format\n\
                              snapshot of every counter/gauge/histogram at\n\
                              each flush (scrape it, or `lotus top` it)\n\
       --probe-every <k>      sample subspace-quality probes every k steps:\n\
                              per-matrix capture ratio, residual energy,\n\
                              switch margin, subspace age, gradient-noise\n\
                              scale (0 = off, one atomic load per step)\n\
       lotus analyze <run.jsonl> [--baseline <other.jsonl>]\n\
                              switch-quality + cadence + probe tables,\n\
                              anomaly flags, and run-vs-run deltas\n\
       lotus analyze --bench <BENCH.json> --baseline <BENCH.json>\n\
                              bench trend table + regression flags\n\
       lotus top --prom <file> [--once] [--refresh <secs>]\n\
                              live dashboard over the prom snapshot\n\
     \n\
     SIM CHECKPOINTING:\n\
       --resume <ckpt>        resume a `sim` run from a full checkpoint\n\
                              (continues to --steps total, bit-identical\n\
                              to the uninterrupted run)\n\
       --ckpt-out <file>      write the full training checkpoint at the end\n\
       --weights-out <file>   write a weights-only checkpoint (serving)\n\
     \n\
     GENERATE / SERVE:\n\
       --ckpt <file>          checkpoint to serve (full or weights-only)\n\
       --prompt \"t0 t1 ...\"   generate: prompt token ids (default: sampled\n\
                              corpus text; serve draws its own trace)\n\
       --prompt-len <n>       prompt length (generate: 8, serve: max 16)\n\
       --max-new <n>          tokens to generate per request (default 32/16)\n\
       --top-k <k>            sample from the top k logits (0 = greedy)\n\
       --temperature <f>      top-k temperature (default 1.0)\n\
       --sample-seed <n>      generate: sampling stream seed (default 0)\n\
       --slots <n>            serve: concurrent decode slots (default 8)\n\
       --requests <n>         serve: synthetic trace size (default 32)\n\
       --max-queue <n>        serve: bound on queued requests; overflow is\n\
                              shed with a typed status (default 1024)\n\
       --deadline <n>         serve: per-request deadline in engine steps;\n\
                              expired requests retire as timed-out\n\
     \n\
     FAULT TOLERANCE (sim --workers N, faults):\n\
       --fault-plan <spec>    seeded fault schedule, comma-separated\n\
                              kind@step entries: flip@S[#k] (bit-flip a\n\
                              payload), drop@S[#k], dup@S[#k], delay@S[#k],\n\
                              killW@S (dead worker W), nan@S (poison a\n\
                              gradient), spike@S (corrupt weights),\n\
                              voteS@N (shard S casts a false rollback vote\n\
                              — quorum outvotes a lone false positive),\n\
                              laneK@S (serve lane K dies mid-decode; its\n\
                              request requeues token-identically),\n\
                              stall@S (serve clock jump, deadline storm),\n\
                              ckpt_corrupt@load (mangled container on the\n\
                              next reload; the CRC chain falls back)\n\
       lotus faults --serve   serve-path drill: replay a trace against a\n\
                              fault-free oracle (token-identity verdict)\n\
                              and exercise the corrupt-reload chain\n\
       --fault-seed <n>       injector RNG stream (default 0xFA017)\n\
       --spike-window <n>     loss-spike detector window (default 8)\n\
       --spike-factor <f>     spike threshold over windowed mean (2.5)\n\
       --max-rollbacks <n>    rollback budget before log-and-continue (4)\n\
       --clip-norm <f>        global gradient-norm clip threshold, applied\n\
                              after the non-finite guard and upstream of\n\
                              the spike detector (0 = off; dist clips each\n\
                              canonical shard, so results are\n\
                              worker-invariant)\n\
     \n\
     EXAMPLES:\n\
       lotus sim --preset tiny --method lotus --steps 200 --ckpt-out runs/tiny.ckpt\n\
       lotus sim --preset tiny --steps 60 --trace-out runs/trace.json --metrics-out runs/m.jsonl\n\
       lotus report --metrics runs/m.jsonl\n\
       lotus sim --steps 200 --metrics-out runs/m.jsonl --probe-every 5 --prom-out runs/m.prom\n\
       lotus top --prom runs/m.prom --once\n\
       lotus analyze runs/m.jsonl --baseline runs/old.jsonl\n\
       lotus sim --resume runs/tiny.ckpt --steps 400 --ckpt-out runs/tiny.ckpt\n\
       lotus generate --preset tiny --ckpt runs/tiny.ckpt --max-new 32\n\
       lotus serve --preset tiny --ckpt runs/tiny.ckpt --slots 8 --requests 64\n\
       lotus sim --workers 4 --steps 100        # N-worker data parallel\n\
       lotus sim --workers 4 --ckpt-every 5 --fault-plan \"flip@3,kill1@6,nan@9\"\n\
       lotus faults --workers 2 --ckpt-every 3 --spike-window 4 --fault-plan \"drop@2,spike@7,vote1@9\"\n\
       lotus faults --serve --fault-plan \"lane0@3,stall@5,ckpt_corrupt@load\"\n\
       lotus train --preset pretrain-20m\n\
       lotus finetune --method lotus --rank 8\n\
       lotus sweep --table 1\n"
}

/// Apply common CLI overrides onto a RunConfig.
pub fn apply_overrides(
    cfg: &mut crate::config::RunConfig,
    args: &Args,
) -> Result<(), String> {
    use crate::optim::registry::{self, MethodOverrides};
    // method first: `--method` resolves through the registry catalog and
    // adopts its per-method hyper defaults, which the explicit
    // --lr/--galore-scale flags below then override
    if let Some(name) = args.opt("method") {
        let name = if name == "full-rank" { "full" } else { name };
        let overrides = MethodOverrides {
            interval: args.opt_parse::<u64>("interval")?,
            gamma: args.opt_parse::<f64>("gamma")?,
            eta: args.opt_parse::<u64>("eta")?,
            t_min: args.opt_parse::<u64>("t_min")?,
            decay: args.opt_parse::<f64>("decay")?,
        };
        let (method, hyper) = registry::method_from_cli(name, overrides)?;
        cfg.method.method = method;
        // adopt the registry's per-method lr/scale only when no explicit
        // config source (--config/--preset) chose the hypers; the
        // --lr/--galore-scale flags below override either way, and the
        // non-method knobs (betas, eps, weight decay) are never touched
        if args.opt("config").is_none() && args.opt("preset").is_none() {
            cfg.hyper.lr = hyper.lr;
            cfg.hyper.galore_scale = hyper.galore_scale;
        }
    }
    if let Some(steps) = args.opt_parse::<u64>("steps")? {
        cfg.steps = steps;
    }
    if let Some(batch) = args.opt_parse::<usize>("batch")? {
        cfg.batch = batch;
    }
    if let Some(lr) = args.opt_parse::<f32>("lr")? {
        cfg.hyper.lr = lr;
    }
    if let Some(scale) = args.opt_parse::<f32>("galore-scale")? {
        cfg.hyper.galore_scale = scale;
    }
    if let Some(seed) = args.opt_parse::<u64>("seed")? {
        cfg.seed = seed;
    }
    if let Some(rank) = args.opt_parse::<usize>("rank")? {
        cfg.method.rank = rank;
    }
    if let Some(workers) = args.opt_parse::<usize>("workers")? {
        cfg.dist.workers = workers;
    }
    if let Some(shards) = args.opt_parse::<usize>("shards")? {
        cfg.dist.shards = shards;
    }
    if let Some(quorum) = args.opt_parse::<f64>("quorum")? {
        cfg.dist.quorum = quorum;
    }
    if let Some(d) = args.opt("wire-dtype") {
        cfg.quant.wire = d.parse().map_err(|e| format!("--wire-dtype: {e}"))?;
    }
    if let Some(d) = args.opt("kv-dtype") {
        cfg.quant.kv = d.parse().map_err(|e| format!("--kv-dtype: {e}"))?;
    }
    if let Some(d) = args.opt("state-dtype") {
        cfg.quant.state = d.parse().map_err(|e| format!("--state-dtype: {e}"))?;
    }
    if let Some(block) = args.opt_parse::<usize>("int8-block")? {
        cfg.quant.int8_block = block;
    }
    if let Some(out) = args.opt("out") {
        cfg.out_dir = out.to_string();
    }
    if let Some(a) = args.opt("artifacts") {
        cfg.artifacts = a.to_string();
    }
    if let Some(every) = args.opt_parse::<u64>("ckpt-every")? {
        cfg.ckpt_every = every;
    }
    if let Some(plan) = args.opt("fault-plan") {
        cfg.faults.plan = plan.to_string();
    }
    if let Some(seed) = args.opt_parse::<u64>("fault-seed")? {
        cfg.faults.seed = seed;
    }
    if let Some(w) = args.opt_parse::<usize>("spike-window")? {
        cfg.faults.spike_window = w;
    }
    if let Some(f) = args.opt_parse::<f64>("spike-factor")? {
        cfg.faults.spike_factor = f;
    }
    if let Some(r) = args.opt_parse::<u32>("max-rollbacks")? {
        cfg.faults.max_rollbacks = r;
    }
    if let Some(c) = args.opt_parse::<f64>("clip-norm")? {
        cfg.faults.clip_norm = c;
    }
    if let Some(p) = args.opt("trace-out") {
        cfg.telemetry.trace_out = p.to_string();
    }
    if let Some(p) = args.opt("metrics-out") {
        cfg.telemetry.metrics_out = p.to_string();
    }
    if let Some(p) = args.opt("prom-out") {
        cfg.telemetry.prom_out = p.to_string();
    }
    if let Some(m) = args.opt("trace-mode") {
        cfg.telemetry.trace_mode = m.to_string();
    }
    if let Some(c) = args.opt_parse::<u64>("trace-cap")? {
        cfg.telemetry.trace_cap = c;
    }
    if let Some(k) = args.opt_parse::<u64>("probe-every")? {
        cfg.telemetry.probe_every = k;
    }
    cfg.validate()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--steps", "100", "--verbose", "--lr=0.01", "file.toml"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt("lr"), Some("0.01"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["file.toml"]);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["sim", "--verbose"]);
        assert!(a.has("verbose"));
        assert!(a.opt("verbose").is_none());
    }

    #[test]
    fn opt_parse_errors() {
        let a = parse(&["sim", "--steps", "abc"]);
        assert!(a.opt_parse::<u64>("steps").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--method", "galore", "--interval", "77", "--rank", "8", "--steps", "5"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.steps, 5);
        assert_eq!(cfg.method.rank, 8);
        assert_eq!(
            cfg.method.method,
            crate::sim::trainer::Method::GaLore { interval: 77 }
        );
    }

    #[test]
    fn method_selection_adopts_registry_hyper_defaults() {
        // adapters pick up the registry's lr/scale defaults…
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--method", "lora"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert!((cfg.hyper.lr - 2e-3).abs() < 1e-9);
        assert!((cfg.hyper.galore_scale - 2.0).abs() < 1e-9);
        // …and explicit flags override them
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--method", "lora", "--lr", "0.01", "--galore-scale", "0.5"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert!((cfg.hyper.lr - 0.01).abs() < 1e-9);
        assert!((cfg.hyper.galore_scale - 0.5).abs() < 1e-9);
        // an explicit config source wins over the registry defaults
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--preset", "tiny", "--method", "lora"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert!((cfg.hyper.lr - 3e-3).abs() < 1e-9, "preset hyper must survive --method");
        // the legacy alias still resolves
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--method", "full-rank"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.method.method, crate::sim::trainer::Method::FullRank);
        // unknown methods still error
        let a = parse(&["sim", "--method", "nope"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
    }

    #[test]
    fn quant_overrides_apply_and_validate() {
        use crate::quant::QuantDtype;
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&[
            "sim",
            "--wire-dtype",
            "int8",
            "--kv-dtype",
            "bf16",
            "--state-dtype",
            "bf16",
            "--int8-block",
            "32",
        ]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.quant.wire, QuantDtype::Int8);
        assert_eq!(cfg.quant.kv, QuantDtype::Bf16);
        assert_eq!(cfg.quant.state, QuantDtype::Bf16);
        assert_eq!(cfg.quant.int8_block, 32);
        // bad dtypes and invalid combos fail at parse/validate
        let a = parse(&["sim", "--wire-dtype", "fp8"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
        let a = parse(&["sim", "--kv-dtype", "int8"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
    }

    #[test]
    fn telemetry_overrides_apply() {
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--trace-out", "t.json", "--metrics-out", "m.jsonl"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.telemetry.trace_out, "t.json");
        assert_eq!(cfg.telemetry.metrics_out, "m.jsonl");
        // absent flags leave the config's values alone
        let mut cfg = crate::config::RunConfig::default();
        cfg.telemetry.metrics_out = "keep.jsonl".into();
        let a = parse(&["sim", "--steps", "5"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.telemetry.metrics_out, "keep.jsonl");
    }

    #[test]
    fn diagnostics_overrides_apply_and_validate() {
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&[
            "sim",
            "--prom-out",
            "m.prom",
            "--trace-mode",
            "ring",
            "--trace-cap",
            "128",
            "--probe-every",
            "5",
            "--clip-norm",
            "3.0",
        ]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.telemetry.prom_out, "m.prom");
        assert_eq!(cfg.telemetry.trace_mode, "ring");
        assert_eq!(cfg.telemetry.trace_cap, 128);
        assert_eq!(cfg.telemetry.probe_every, 5);
        assert!((cfg.faults.clip_norm - 3.0).abs() < 1e-12);
        // unknown trace modes and negative thresholds fail validate()
        let a = parse(&["sim", "--trace-mode", "laser"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
        let a = parse(&["sim", "--clip-norm", "-2"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
    }

    #[test]
    fn fault_overrides_apply_and_validate() {
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&[
            "sim",
            "--fault-plan",
            "flip@3#0,kill0@6",
            "--fault-seed",
            "7",
            "--spike-window",
            "4",
            "--max-rollbacks",
            "2",
        ]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.faults.plan, "flip@3#0,kill0@6");
        assert_eq!(cfg.faults.seed, 7);
        assert_eq!(cfg.faults.spike_window, 4);
        assert_eq!(cfg.faults.max_rollbacks, 2);
        assert_eq!(cfg.faults.plan().unwrap().unwrap().events.len(), 2);
        // a malformed plan fails at validate, not deep inside a trainer
        let a = parse(&["sim", "--fault-plan", "warp@x"]);
        assert!(apply_overrides(&mut crate::config::RunConfig::default(), &a).is_err());
    }

    #[test]
    fn dist_overrides_apply_and_validate() {
        let mut cfg = crate::config::RunConfig::default();
        let a = parse(&["sim", "--workers", "4", "--quorum", "0.75"]);
        apply_overrides(&mut cfg, &a).unwrap();
        assert_eq!(cfg.dist.workers, 4);
        assert_eq!(cfg.dist.shard_count(), 4);
        assert!((cfg.dist.quorum - 0.75).abs() < 1e-12);
        // invalid shapes are rejected by validate()
        let mut bad = crate::config::RunConfig::default();
        let a = parse(&["sim", "--workers", "3"]); // batch 8 % 3 != 0
        assert!(apply_overrides(&mut bad, &a).is_err());
    }
}
