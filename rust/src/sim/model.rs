//! Decoder-only transformer LM with manual backprop.
//!
//! Architecture (LLaMA-flavoured, adapted for a CPU simulator):
//! tied embedding → N × [RMSNorm → causal MHA (ALiBi bias) → residual →
//! RMSNorm → SwiGLU FFN → residual] → RMSNorm → tied logits → CE loss.
//!
//! ALiBi replaces RoPE: identical role (relative position), zero
//! parameters and a trivial backward, which keeps the hand-written
//! gradients auditable. The JAX model (`python/compile/model.py`) uses
//! the same choice so the two paths match numerically.

use crate::linalg::par::{
    matmul_into_pooled, matmul_nt_into_pooled, matmul_nt_pooled, matmul_pooled, matmul_tn_pooled,
};
use crate::models::LlamaConfig;
use crate::quant::QuantDtype;
use crate::runtime::pool;
use crate::tensor::bf16::{bf16_to_f32, f32_to_bf16};
use crate::tensor::{init, Matrix, Workspace};
use crate::util::Rng;

/// C = A · B over the effective pool (full pool from the main thread,
/// serial inside an outer fan-out); results are bit-identical to the
/// serial kernel at any thread count, and small products fall back to
/// the serial kernel automatically.
fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_pooled(&pool::effective(), a, b)
}

/// C = Aᵀ · B over the effective pool.
fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_tn_pooled(&pool::effective(), a, b)
}

/// C = A · Bᵀ over the effective pool.
fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_nt_pooled(&pool::effective(), a, b)
}

const RMS_EPS: f32 = 1e-5;

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub w1: Matrix, // gate  (d × f)
    pub w3: Matrix, // up    (d × f)
    pub w2: Matrix, // down  (f × d)
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
}

/// All model parameters.
#[derive(Clone, Debug)]
pub struct Params {
    pub embed: Matrix, // V × d (tied with output head)
    pub layers: Vec<LayerParams>,
    pub final_norm: Vec<f32>,
}

impl Params {
    /// Zero-weight skeleton with the shapes `cfg` prescribes (norm gains
    /// at their identity value 1). Checkpoint loaders overwrite every
    /// tensor, so this avoids paying a full random init just to discard
    /// it ([`crate::train::checkpoint::load_weights`]).
    pub fn zeros(cfg: &LlamaConfig) -> Params {
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let layers = (0..cfg.n_layers)
            .map(|_| LayerParams {
                wq: Matrix::zeros(d, d),
                wk: Matrix::zeros(d, d),
                wv: Matrix::zeros(d, d),
                wo: Matrix::zeros(d, d),
                w1: Matrix::zeros(d, f),
                w3: Matrix::zeros(d, f),
                w2: Matrix::zeros(f, d),
                norm1: vec![1.0; d],
                norm2: vec![1.0; d],
            })
            .collect();
        Params { embed: Matrix::zeros(cfg.vocab, d), layers, final_norm: vec![1.0; d] }
    }

    /// Checkpoint view of the weights: `(synthesized, borrowed)` named
    /// tensors. Large matrices are *borrowed* (checkpointing never
    /// doubles peak weight memory); the norm vectors are synthesized as
    /// owned 1×d rows. The naming (`model/embed`, `model/L{li}/wq`, …)
    /// is shared by the sim and dist checkpoint writers.
    pub fn export_tensors(&self) -> (Vec<(String, Matrix)>, Vec<(String, &Matrix)>) {
        let mut synth: Vec<(String, Matrix)> = Vec::new();
        for (li, lp) in self.layers.iter().enumerate() {
            synth.push((
                format!("model/L{li}/norm1"),
                Matrix::from_vec(1, lp.norm1.len(), lp.norm1.clone()),
            ));
            synth.push((
                format!("model/L{li}/norm2"),
                Matrix::from_vec(1, lp.norm2.len(), lp.norm2.clone()),
            ));
        }
        synth.push((
            "model/final_norm".into(),
            Matrix::from_vec(1, self.final_norm.len(), self.final_norm.clone()),
        ));
        let mut refs: Vec<(String, &Matrix)> = vec![("model/embed".into(), &self.embed)];
        for (li, lp) in self.layers.iter().enumerate() {
            for (name, m) in [
                ("wq", &lp.wq),
                ("wk", &lp.wk),
                ("wv", &lp.wv),
                ("wo", &lp.wo),
                ("w1", &lp.w1),
                ("w3", &lp.w3),
                ("w2", &lp.w2),
            ] {
                refs.push((format!("model/L{li}/{name}"), m));
            }
        }
        (synth, refs)
    }

    /// Restore weights from a loaded tensor list (the inverse of
    /// [`Params::export_tensors`]).
    pub fn restore_from_tensors(
        &mut self,
        tensors: &[(String, Matrix)],
    ) -> Result<(), String> {
        use crate::optim::state::find_tensor as find;
        self.embed = find(tensors, "model/embed")?.clone();
        for (li, lp) in self.layers.iter_mut().enumerate() {
            lp.wq = find(tensors, &format!("model/L{li}/wq"))?.clone();
            lp.wk = find(tensors, &format!("model/L{li}/wk"))?.clone();
            lp.wv = find(tensors, &format!("model/L{li}/wv"))?.clone();
            lp.wo = find(tensors, &format!("model/L{li}/wo"))?.clone();
            lp.w1 = find(tensors, &format!("model/L{li}/w1"))?.clone();
            lp.w3 = find(tensors, &format!("model/L{li}/w3"))?.clone();
            lp.w2 = find(tensors, &format!("model/L{li}/w2"))?.clone();
            lp.norm1 = find(tensors, &format!("model/L{li}/norm1"))?.data.clone();
            lp.norm2 = find(tensors, &format!("model/L{li}/norm2"))?.data.clone();
        }
        self.final_norm = find(tensors, "model/final_norm")?.data.clone();
        Ok(())
    }
}

/// Gradients, mirroring [`Params`].
#[derive(Clone, Debug)]
pub struct Gradients {
    pub embed: Matrix,
    pub layers: Vec<LayerGrads>,
    pub final_norm: Vec<f32>,
}

impl Gradients {
    /// True when any gradient entry is NaN or infinite — the skip-step
    /// guard's probe (a single poisoned entry would otherwise contaminate
    /// the optimizer moments forever).
    pub fn has_non_finite(&self) -> bool {
        if self.embed.has_non_finite() || self.final_norm.iter().any(|x| !x.is_finite()) {
            return true;
        }
        self.layers.iter().any(|lg| {
            lg.wq.has_non_finite()
                || lg.wk.has_non_finite()
                || lg.wv.has_non_finite()
                || lg.wo.has_non_finite()
                || lg.w1.has_non_finite()
                || lg.w3.has_non_finite()
                || lg.w2.has_non_finite()
                || lg.norm1.iter().any(|x| !x.is_finite())
                || lg.norm2.iter().any(|x| !x.is_finite())
        })
    }
}

#[derive(Clone, Debug)]
pub struct LayerGrads {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub w1: Matrix,
    pub w3: Matrix,
    pub w2: Matrix,
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
}

/// The simulator model: config + parameters.
pub struct SimModel {
    pub cfg: LlamaConfig,
    pub params: Params,
}

// ---------------------------------------------------------------------
// building blocks
// ---------------------------------------------------------------------

/// RMSNorm of one row: out = g ⊙ row / rms(row). Returns the rms. Shared
/// by the full-context forward and the incremental decode path
/// ([`SimModel::forward_step`]) so the two are bit-identical per row.
#[inline]
fn rmsnorm_row(row: &[f32], g: &[f32], out: &mut [f32]) -> f32 {
    let d = row.len();
    let ms: f64 = row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / d as f64;
    let r = (ms + RMS_EPS as f64).sqrt() as f32;
    for j in 0..d {
        out[j] = g[j] * row[j] / r;
    }
    r
}

/// RMSNorm forward: y[i,:] = g ⊙ x[i,:] / rms(x[i,:]). Returns (y, rms)
/// with per-row rms cached for backward.
fn rmsnorm_fwd(x: &Matrix, g: &[f32]) -> (Matrix, Vec<f32>) {
    let mut y = Matrix::zeros(x.rows, x.cols);
    let mut rms = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        rms[i] = rmsnorm_row(x.row(i), g, y.row_mut(i));
    }
    (y, rms)
}

/// RMSNorm backward: given dy, produce dx and accumulate dg.
fn rmsnorm_bwd(x: &Matrix, g: &[f32], rms: &[f32], dy: &Matrix, dg: &mut [f32]) -> Matrix {
    let d = x.cols;
    let mut dx = Matrix::zeros(x.rows, d);
    for i in 0..x.rows {
        let r = rms[i];
        let xrow = x.row(i);
        let dyrow = dy.row(i);
        // s = Σ_j dy_j g_j x_j
        let mut s = 0.0f64;
        for j in 0..d {
            s += dyrow[j] as f64 * g[j] as f64 * xrow[j] as f64;
            dg[j] += dyrow[j] * xrow[j] / r;
        }
        let k = (s / (d as f64 * (r as f64).powi(3))) as f32;
        let dxrow = dx.row_mut(i);
        for j in 0..d {
            dxrow[j] = g[j] * dyrow[j] / r - xrow[j] * k;
        }
    }
    dx
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// ALiBi slope for head h of H (per the ALiBi paper: 2^(-8h/H)).
fn alibi_slope(h: usize, n_heads: usize) -> f32 {
    (2.0f32).powf(-8.0 * (h as f32 + 1.0) / n_heads as f32)
}

// ---------------------------------------------------------------------
// caches
// ---------------------------------------------------------------------

/// Per-layer forward cache retained for backward.
struct LayerCache {
    x_in: Matrix,   // residual input
    xn1: Matrix,    // post-norm1
    rms1: Vec<f32>, // norm1 rms
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// softmax probabilities per (batch, head): vec of T×T matrices
    probs: Vec<Matrix>,
    att_concat: Matrix, // pre-Wo concat of head outputs
    x_mid: Matrix,      // after attention residual
    xn2: Matrix,
    rms2: Vec<f32>,
    a: Matrix,  // xn2 · w1 (gate pre-activation)
    b3: Matrix, // xn2 · w3 (up)
    h: Matrix,  // silu(a) ⊙ b3
}

/// Full forward cache.
struct Cache {
    x0: Matrix,
    layers: Vec<LayerCache>,
    xf: Matrix, // post final-norm
    rms_f: Vec<f32>,
    x_last: Matrix, // pre final-norm
    probs_out: Matrix, // softmax over vocab (B*T × V)
}

/// Per-layer K/V cache rows for one sequence (capacity × d_model each;
/// rows at and beyond the sequence length are dead storage). Storage is
/// either exact f32 (default) or bf16 at 2 bytes/element
/// (`--kv-dtype bf16`): rows are rounded on write and dequantized into
/// caller scratch on read, so the bf16 mode allocates nothing extra in
/// steady state.
#[derive(Clone, Debug)]
pub enum KvLayerCache {
    F32 { k: Matrix, v: Matrix },
    Bf16 { k: Vec<u16>, v: Vec<u16> },
}

impl KvLayerCache {
    /// Append one position's K/V rows (rounding to bf16 when quantized).
    #[inline]
    fn write_row(&mut self, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let d = k_row.len();
        match self {
            KvLayerCache::F32 { k, v } => {
                k.row_mut(pos).copy_from_slice(k_row);
                v.row_mut(pos).copy_from_slice(v_row);
            }
            KvLayerCache::Bf16 { k, v } => {
                for (dst, &x) in k[pos * d..(pos + 1) * d].iter_mut().zip(k_row) {
                    *dst = f32_to_bf16(x);
                }
                for (dst, &x) in v[pos * d..(pos + 1) * d].iter_mut().zip(v_row) {
                    *dst = f32_to_bf16(x);
                }
            }
        }
    }

    /// The `[lo..hi)` segment of cached K row `row` as f32 (row stride
    /// `d`). F32 storage returns the slice in place; bf16 dequantizes
    /// into `scratch` and returns it.
    #[inline]
    fn k_seg<'a>(
        &'a self,
        row: usize,
        d: usize,
        lo: usize,
        hi: usize,
        scratch: &'a mut [f32],
    ) -> &'a [f32] {
        match self {
            KvLayerCache::F32 { k, .. } => &k.row(row)[lo..hi],
            KvLayerCache::Bf16 { k, .. } => {
                let src = &k[row * d + lo..row * d + hi];
                for (o, &b) in scratch.iter_mut().zip(src) {
                    *o = bf16_to_f32(b);
                }
                scratch
            }
        }
    }

    /// [`Self::k_seg`] for the V rows.
    #[inline]
    fn v_seg<'a>(
        &'a self,
        row: usize,
        d: usize,
        lo: usize,
        hi: usize,
        scratch: &'a mut [f32],
    ) -> &'a [f32] {
        match self {
            KvLayerCache::F32 { v, .. } => &v.row(row)[lo..hi],
            KvLayerCache::Bf16 { v, .. } => {
                let src = &v[row * d + lo..row * d + hi];
                for (o, &b) in scratch.iter_mut().zip(src) {
                    *o = bf16_to_f32(b);
                }
                scratch
            }
        }
    }

    /// Bytes of K/V storage this layer holds.
    fn bytes(&self) -> usize {
        match self {
            KvLayerCache::F32 { k, v } => (k.len() + v.len()) * 4,
            KvLayerCache::Bf16 { k, v } => (k.len() + v.len()) * 2,
        }
    }
}

/// Per-sequence key/value cache for incremental decoding
/// ([`SimModel::forward_step`]). Holds one [`KvLayerCache`] per
/// transformer layer at a fixed token capacity, so steady-state decode
/// never reallocates; [`KvCache::clear`] recycles the storage for the
/// next request (a slot reuse in the serving engine).
#[derive(Clone, Debug)]
pub struct KvCache {
    layers: Vec<KvLayerCache>,
    len: usize,
    cap: usize,
}

impl KvCache {
    /// Cache for one sequence of up to `cap` tokens under `cfg`, with
    /// exact f32 storage (the historical, bit-exact default).
    pub fn new(cfg: &LlamaConfig, cap: usize) -> Self {
        Self::with_dtype(cfg, cap, QuantDtype::F32)
    }

    /// Cache with explicit K/V storage dtype. Int8 K/V is rejected at
    /// config validation; this constructor only sees f32/bf16.
    pub fn with_dtype(cfg: &LlamaConfig, cap: usize, dtype: QuantDtype) -> Self {
        assert!(dtype != QuantDtype::Int8, "int8 K/V cache storage is unsupported");
        let d = cfg.d_model;
        let layers = (0..cfg.n_layers)
            .map(|_| match dtype {
                QuantDtype::Bf16 => {
                    KvLayerCache::Bf16 { k: vec![0u16; cap * d], v: vec![0u16; cap * d] }
                }
                _ => KvLayerCache::F32 { k: Matrix::zeros(cap, d), v: Matrix::zeros(cap, d) },
            })
            .collect();
        KvCache { layers, len: 0, cap }
    }

    /// Tokens currently cached (the sequence length so far).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum sequence length this cache can hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Reset for a new sequence, keeping the allocated storage. Rows at
    /// or beyond the sequence length are never read, so no zeroing is
    /// needed.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Bytes of cached K/V storage (diagnostics; dtype-aware, so bf16
    /// caches report half the f32 footprint).
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes()).sum()
    }
}

impl SimModel {
    /// Initialize with LLaMA-style scaling.
    pub fn new(cfg: LlamaConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(LayerParams {
                wq: init::lecun_normal(d, d, d, &mut rng),
                wk: init::lecun_normal(d, d, d, &mut rng),
                wv: init::lecun_normal(d, d, d, &mut rng),
                wo: init::residual_out(d, d, d, cfg.n_layers, &mut rng),
                w1: init::lecun_normal(d, f, d, &mut rng),
                w3: init::lecun_normal(d, f, d, &mut rng),
                w2: init::residual_out(f, d, f, cfg.n_layers, &mut rng),
                norm1: vec![1.0; d],
                norm2: vec![1.0; d],
            });
        }
        let params = Params {
            embed: init::lecun_normal(cfg.vocab, d, d, &mut rng),
            layers,
            final_norm: vec![1.0; d],
        };
        SimModel { cfg, params }
    }

    /// Total parameter count (matches `models::LlamaConfig::param_count`
    /// up to the vector-param bookkeeping).
    pub fn param_count(&self) -> u64 {
        let p = &self.params;
        let mut n = p.embed.len() as u64 + p.final_norm.len() as u64;
        for l in &p.layers {
            n += (l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len()) as u64;
            n += (l.w1.len() + l.w2.len() + l.w3.len()) as u64;
            n += (l.norm1.len() + l.norm2.len()) as u64;
        }
        n
    }

    // -----------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------

    fn forward_cached(&self, tokens: &[u32], batch: usize, seq: usize) -> Cache {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = cfg.head_dim();
        let rows = batch * seq;
        assert_eq!(tokens.len(), rows);

        // embedding lookup
        let mut x = Matrix::zeros(rows, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.params.embed.row(t as usize));
        }
        let x0 = x.clone();

        let scale = 1.0 / (hd as f32).sqrt();
        let mut layer_caches = Vec::with_capacity(cfg.n_layers);

        for lp in &self.params.layers {
            let x_in = x.clone();
            let (xn1, rms1) = rmsnorm_fwd(&x, &lp.norm1);
            let q = matmul(&xn1, &lp.wq);
            let k = matmul(&xn1, &lp.wk);
            let v = matmul(&xn1, &lp.wv);

            // attention: (batch, head) pairs are fully independent, so
            // fan batch elements across the pool — each job owns its
            // batch's rows of att_concat and its `heads` prob matrices,
            // and the per-(b,h) arithmetic is exactly the serial kernel,
            // so results are bit-identical at any thread count.
            let mut att_concat = Matrix::zeros(rows, d);
            let mut probs: Vec<Matrix> =
                (0..batch * heads).map(|_| Matrix::zeros(seq, seq)).collect();
            {
                let (q, k, v) = (&q, &k, &v);
                let mut jobs: Vec<(usize, &mut [f32], &mut [Matrix])> = Vec::with_capacity(batch);
                let mut att_rest: &mut [f32] = &mut att_concat.data;
                let mut probs_rest: &mut [Matrix] = &mut probs;
                for b in 0..batch {
                    let (att_b, ar) = std::mem::take(&mut att_rest).split_at_mut(seq * d);
                    att_rest = ar;
                    let (pb, pr) = std::mem::take(&mut probs_rest).split_at_mut(heads);
                    probs_rest = pr;
                    jobs.push((b, att_b, pb));
                }
                pool::effective().par_items_mut(&mut jobs, |_ji, job| {
                    let (b, att_b, probs_b) = job;
                    let b = *b;
                    for h in 0..heads {
                        let slope = alibi_slope(h, heads);
                        // scores S (T×T), causal + alibi
                        let p = &mut probs_b[h];
                        for i in 0..seq {
                            let qrow = &q.row(b * seq + i)[h * hd..(h + 1) * hd];
                            // causal: j <= i
                            let mut maxv = f32::NEG_INFINITY;
                            for j in 0..=i {
                                let krow = &k.row(b * seq + j)[h * hd..(h + 1) * hd];
                                let mut s = 0.0f32;
                                for t in 0..hd {
                                    s += qrow[t] * krow[t];
                                }
                                let val = s * scale - slope * (i - j) as f32;
                                *p.at_mut(i, j) = val;
                                maxv = maxv.max(val);
                            }
                            // softmax over j<=i
                            let mut denom = 0.0f32;
                            for j in 0..=i {
                                let e = (p.at(i, j) - maxv).exp();
                                *p.at_mut(i, j) = e;
                                denom += e;
                            }
                            let inv = 1.0 / denom;
                            for j in 0..=i {
                                *p.at_mut(i, j) *= inv;
                            }
                        }
                        // O = P V_head (T×hd), write into this batch's rows
                        for i in 0..seq {
                            let orow = &mut att_b[i * d..(i + 1) * d];
                            for j in 0..=i {
                                let pij = p.at(i, j);
                                if pij == 0.0 {
                                    continue;
                                }
                                let vrow = &v.row(b * seq + j)[h * hd..(h + 1) * hd];
                                for t in 0..hd {
                                    orow[h * hd + t] += pij * vrow[t];
                                }
                            }
                        }
                    }
                });
            }
            let att_out = matmul(&att_concat, &lp.wo);
            let mut x_mid = x_in.clone();
            x_mid.axpy(1.0, &att_out);

            let (xn2, rms2) = rmsnorm_fwd(&x_mid, &lp.norm2);
            let a = matmul(&xn2, &lp.w1);
            let b3 = matmul(&xn2, &lp.w3);
            let mut h = Matrix::zeros(rows, cfg.d_ff);
            for idx in 0..h.data.len() {
                let av = a.data[idx];
                h.data[idx] = av * sigmoid(av) * b3.data[idx];
            }
            let f_out = matmul(&h, &lp.w2);
            let mut x_next = x_mid.clone();
            x_next.axpy(1.0, &f_out);

            layer_caches.push(LayerCache {
                x_in,
                xn1,
                rms1,
                q,
                k,
                v,
                probs,
                att_concat,
                x_mid,
                xn2,
                rms2,
                a,
                b3,
                h,
            });
            x = x_next;
        }

        let x_last = x.clone();
        let (xf, rms_f) = rmsnorm_fwd(&x, &self.params.final_norm);

        Cache {
            x0,
            layers: layer_caches,
            xf,
            rms_f,
            x_last,
            probs_out: Matrix::zeros(0, 0),
        }
    }

    /// Forward only: mean cross-entropy over all positions.
    pub fn loss(&self, tokens: &[u32], targets: &[u32], batch: usize, seq: usize) -> f64 {
        let cache = self.forward_cached(tokens, batch, seq);
        self.ce_loss(&cache.xf, targets).0
    }

    /// Full-context forward returning the logits of every position
    /// (`batch*seq × vocab` rows, position-major within each batch
    /// element) — the serving oracle: prefill + incremental decode
    /// through [`SimModel::forward_step`] must reproduce these rows
    /// bit-for-bit.
    pub fn forward_logits(&self, tokens: &[u32], batch: usize, seq: usize) -> Matrix {
        let cache = self.forward_cached(tokens, batch, seq);
        matmul_nt(&cache.xf, &self.params.embed)
    }

    /// Incremental decode: append `tokens` (≥ 1 of them — a whole prompt
    /// on prefill, one token per step afterwards) to `cache` and write
    /// the logits row of the *last* appended position into `logits`
    /// (reshaped to 1 × vocab).
    ///
    /// Bit-determinism contract: every kernel here is per-row identical
    /// to the full-context forward (the GEMM band kernels fix the
    /// k-accumulation order per output row, RMSNorm and attention are
    /// per-row/per-(position, head) loops with the same arithmetic
    /// order), so the logits equal the matching row of
    /// [`SimModel::forward_logits`] over the whole sequence *exactly*,
    /// at any `LOTUS_THREADS`, any prefill/decode split, and regardless
    /// of what other sequences share a serving batch. Enforced by
    /// `rust/tests/serve.rs`.
    ///
    /// All scratch comes from `ws`, so after one warm-up pass at a given
    /// shape a decode step performs no heap allocations (size `scores`
    /// reuse by taking the full `cache.capacity()` row once per call).
    /// Inside a pool worker the GEMMs degrade to serial automatically
    /// ([`pool::effective`]), which is what lets a serving engine fan
    /// whole sequences across the pool.
    pub fn forward_step(
        &self,
        tokens: &[u32],
        cache: &mut KvCache,
        ws: &mut Workspace,
        logits: &mut Matrix,
    ) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = cfg.head_dim();
        let n = tokens.len();
        let p0 = cache.len;
        assert!(n >= 1, "forward_step needs at least one token");
        assert!(
            p0 + n <= cache.cap,
            "kv cache overflow: {} cached + {n} new > capacity {}",
            p0,
            cache.cap
        );
        assert_eq!(cache.layers.len(), cfg.n_layers, "kv cache built for a different model");
        let pool = pool::effective();
        let scale = 1.0 / (hd as f32).sqrt();

        // embedding lookup
        let mut x = ws.take(n, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.params.embed.row(t as usize));
        }
        let mut xn = ws.take(n, d);
        // softmax scratch sized to capacity so its shape is step-invariant
        // (constant-shape takes are what keep steady-state decode
        // allocation-free as the sequence grows)
        let mut scores = ws.take(1, cache.cap);
        // per-head K/V dequantization scratch for bf16 caches (unused by
        // the f32 path, but taken unconditionally so the take sequence —
        // and therefore workspace reuse — is dtype-invariant)
        let mut kvseg = ws.take(2, hd);

        for (li, lp) in self.params.layers.iter().enumerate() {
            // ---- attention ----
            for i in 0..n {
                rmsnorm_row(x.row(i), &lp.norm1, xn.row_mut(i));
            }
            let mut q = ws.take(n, d);
            let mut kn = ws.take(n, d);
            let mut vn = ws.take(n, d);
            matmul_into_pooled(&pool, &xn, &lp.wq, &mut q);
            matmul_into_pooled(&pool, &xn, &lp.wk, &mut kn);
            matmul_into_pooled(&pool, &xn, &lp.wv, &mut vn);
            let lc = &mut cache.layers[li];
            for i in 0..n {
                lc.write_row(p0 + i, kn.row(i), vn.row(i));
            }
            let lc = &cache.layers[li];
            ws.give(kn);
            ws.give(vn);
            // per-(position, head) scores/softmax/O with the exact
            // arithmetic order of the full-context forward
            let mut att = ws.take(n, d);
            for h in 0..heads {
                let slope = alibi_slope(h, heads);
                for i in 0..n {
                    let pos = p0 + i;
                    let qrow = &q.row(i)[h * hd..(h + 1) * hd];
                    let mut maxv = f32::NEG_INFINITY;
                    for j in 0..=pos {
                        let krow = lc.k_seg(j, d, h * hd, (h + 1) * hd, kvseg.row_mut(0));
                        let mut s = 0.0f32;
                        for t in 0..hd {
                            s += qrow[t] * krow[t];
                        }
                        let val = s * scale - slope * (pos - j) as f32;
                        scores.data[j] = val;
                        maxv = maxv.max(val);
                    }
                    let mut denom = 0.0f32;
                    for j in 0..=pos {
                        let e = (scores.data[j] - maxv).exp();
                        scores.data[j] = e;
                        denom += e;
                    }
                    let inv = 1.0 / denom;
                    for j in 0..=pos {
                        scores.data[j] *= inv;
                    }
                    let orow = att.row_mut(i);
                    for j in 0..=pos {
                        let pij = scores.data[j];
                        if pij == 0.0 {
                            continue;
                        }
                        let vrow = lc.v_seg(j, d, h * hd, (h + 1) * hd, kvseg.row_mut(1));
                        for t in 0..hd {
                            orow[h * hd + t] += pij * vrow[t];
                        }
                    }
                }
            }
            ws.give(q);
            let mut att_out = ws.take(n, d);
            matmul_into_pooled(&pool, &att, &lp.wo, &mut att_out);
            ws.give(att);
            x.axpy(1.0, &att_out);
            ws.give(att_out);

            // ---- SwiGLU FFN ----
            for i in 0..n {
                rmsnorm_row(x.row(i), &lp.norm2, xn.row_mut(i));
            }
            let mut a = ws.take(n, cfg.d_ff);
            let mut b3 = ws.take(n, cfg.d_ff);
            matmul_into_pooled(&pool, &xn, &lp.w1, &mut a);
            matmul_into_pooled(&pool, &xn, &lp.w3, &mut b3);
            let mut hbuf = ws.take(n, cfg.d_ff);
            for idx in 0..hbuf.data.len() {
                let av = a.data[idx];
                hbuf.data[idx] = av * sigmoid(av) * b3.data[idx];
            }
            ws.give(a);
            ws.give(b3);
            let mut f_out = ws.take(n, d);
            matmul_into_pooled(&pool, &hbuf, &lp.w2, &mut f_out);
            ws.give(hbuf);
            x.axpy(1.0, &f_out);
            ws.give(f_out);
        }
        ws.give(scores);
        ws.give(kvseg);

        // final norm + logits for the last appended position only
        let mut xf = ws.take(1, d);
        rmsnorm_row(x.row(n - 1), &self.params.final_norm, xf.row_mut(0));
        ws.give(x);
        ws.give(xn);
        logits.ensure_shape(1, cfg.vocab);
        matmul_nt_into_pooled(&pool, &xf, &self.params.embed, logits);
        ws.give(xf);
        cache.len = p0 + n;
    }

    /// Softmax CE against the tied embedding head. Returns (loss, probs).
    fn ce_loss(&self, xf: &Matrix, targets: &[u32]) -> (f64, Matrix) {
        let logits = matmul_nt(xf, &self.params.embed); // rows × V
        let rows = logits.rows;
        let v = logits.cols;
        let mut probs = logits;
        let mut total = 0.0f64;
        for i in 0..rows {
            let row = probs.row_mut(i);
            let maxv = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
            let mut denom = 0.0f64;
            for x in row.iter_mut() {
                *x = (*x - maxv).exp();
                denom += *x as f64;
            }
            let inv = (1.0 / denom) as f32;
            for x in row.iter_mut() {
                *x *= inv;
            }
            let t = targets[i] as usize;
            debug_assert!(t < v);
            total -= (row[t].max(1e-30) as f64).ln();
        }
        (total / rows as f64, probs)
    }

    /// Full forward + backward. Returns (mean loss, gradients).
    pub fn loss_and_grad(
        &self,
        tokens: &[u32],
        targets: &[u32],
        batch: usize,
        seq: usize,
    ) -> (f64, Gradients) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = cfg.head_dim();
        let rows = batch * seq;
        let mut cache = self.forward_cached(tokens, batch, seq);
        let (loss, probs) = self.ce_loss(&cache.xf, targets);
        cache.probs_out = probs;

        // dlogits = (p − onehot)/rows ; logits = Xf Embᵀ
        let mut dlogits = cache.probs_out.clone();
        let invn = 1.0 / rows as f32;
        for i in 0..rows {
            let t = targets[i] as usize;
            *dlogits.at_mut(i, t) -= 1.0;
        }
        dlogits.scale(invn);

        // dXf = dlogits · Emb ; dEmb(head) = dlogitsᵀ · Xf
        let mut d_embed = matmul_tn(&dlogits, &cache.xf); // V × d
        let dxf = matmul(&dlogits, &self.params.embed); // rows × d

        // final norm backward
        let mut d_final_norm = vec![0.0f32; d];
        let mut dx = rmsnorm_bwd(
            &cache.x_last,
            &self.params.final_norm,
            &cache.rms_f,
            &dxf,
            &mut d_final_norm,
        );

        let scale = 1.0 / (hd as f32).sqrt();
        let mut layer_grads: Vec<LayerGrads> = Vec::with_capacity(cfg.n_layers);

        for (li, lp) in self.params.layers.iter().enumerate().rev() {
            let lc = &cache.layers[li];
            // ---- FFN backward ----
            // x_next = x_mid + h · w2
            let dh_out = &dx; // gradient of f_out (residual passthrough keeps dx for x_mid)
            let dw2 = matmul_tn(&lc.h, dh_out);
            let dh = matmul_nt(dh_out, &lp.w2); // rows × f
            // h = silu(a) ⊙ b3
            let mut da = Matrix::zeros(rows, cfg.d_ff);
            let mut db3 = Matrix::zeros(rows, cfg.d_ff);
            for idx in 0..dh.data.len() {
                let av = lc.a.data[idx];
                let s = sigmoid(av);
                let silu = av * s;
                let dsilu = s * (1.0 + av * (1.0 - s));
                da.data[idx] = dh.data[idx] * lc.b3.data[idx] * dsilu;
                db3.data[idx] = dh.data[idx] * silu;
            }
            let dw1 = matmul_tn(&lc.xn2, &da);
            let dw3 = matmul_tn(&lc.xn2, &db3);
            let mut dxn2 = matmul_nt(&da, &lp.w1);
            dxn2.axpy(1.0, &matmul_nt(&db3, &lp.w3));
            let mut dnorm2 = vec![0.0f32; d];
            let dx_mid_from_ffn =
                rmsnorm_bwd(&lc.x_mid, &lp.norm2, &lc.rms2, &dxn2, &mut dnorm2);
            // total gradient at x_mid = residual passthrough + ffn path
            let mut dx_mid = dx.clone();
            dx_mid.axpy(1.0, &dx_mid_from_ffn);

            // ---- attention backward ----
            // x_mid = x_in + att_concat · wo
            let datt_out = &dx_mid;
            let dwo = matmul_tn(&lc.att_concat, datt_out);
            let datt_concat = matmul_nt(datt_out, &lp.wo); // rows × d

            // attention backward: like the forward, (batch, head) pairs
            // are independent and dq/dk/dv rows are disjoint per batch
            // element, so fan batch elements across the pool with the
            // serial per-(b,h) kernel — bit-identical at any thread count.
            let mut dq = Matrix::zeros(rows, d);
            let mut dk = Matrix::zeros(rows, d);
            let mut dv = Matrix::zeros(rows, d);
            {
                let datt = &datt_concat;
                let (cq, ck, cv, cprobs) = (&lc.q, &lc.k, &lc.v, &lc.probs);
                let mut jobs: Vec<(usize, &mut [f32], &mut [f32], &mut [f32])> =
                    Vec::with_capacity(batch);
                let mut dq_rest: &mut [f32] = &mut dq.data;
                let mut dk_rest: &mut [f32] = &mut dk.data;
                let mut dv_rest: &mut [f32] = &mut dv.data;
                for b in 0..batch {
                    let (dqb, qr) = std::mem::take(&mut dq_rest).split_at_mut(seq * d);
                    dq_rest = qr;
                    let (dkb, kr) = std::mem::take(&mut dk_rest).split_at_mut(seq * d);
                    dk_rest = kr;
                    let (dvb, vr) = std::mem::take(&mut dv_rest).split_at_mut(seq * d);
                    dv_rest = vr;
                    jobs.push((b, dqb, dkb, dvb));
                }
                pool::effective().par_items_mut(&mut jobs, |_ji, job| {
                    let (b, dqb, dkb, dvb) = job;
                    let b = *b;
                    for h in 0..heads {
                        let p = &cprobs[b * heads + h];
                        // dO slice (T×hd) is datt_concat[:, h*hd..]
                        // dV += Pᵀ dO ; dP = dO Vᵀ
                        for i in 0..seq {
                            // dP row i (only j<=i nonzero)
                            let dorow = &datt.row(b * seq + i)[h * hd..(h + 1) * hd];
                            // softmax backward needs rowsum(dP ⊙ P)
                            let mut dp = vec![0.0f32; i + 1];
                            let mut dot = 0.0f64;
                            for j in 0..=i {
                                let vrow = &cv.row(b * seq + j)[h * hd..(h + 1) * hd];
                                let mut acc = 0.0f32;
                                for t in 0..hd {
                                    acc += dorow[t] * vrow[t];
                                }
                                dp[j] = acc;
                                dot += (acc * p.at(i, j)) as f64;
                            }
                            // dS = P ⊙ (dP − dot)
                            for j in 0..=i {
                                let ds = p.at(i, j) * (dp[j] - dot as f32);
                                if ds == 0.0 {
                                    continue;
                                }
                                // S = (Q Kᵀ) scale + alibi ⇒
                                // dQ[i] += ds·scale·K[j]; dK[j] += ds·scale·Q[i]
                                let krow = &ck.row(b * seq + j)[h * hd..(h + 1) * hd];
                                let qrow = &cq.row(b * seq + i)[h * hd..(h + 1) * hd];
                                let dqrow = &mut dqb[i * d..(i + 1) * d];
                                for t in 0..hd {
                                    dqrow[h * hd + t] += ds * scale * krow[t];
                                }
                                let dkrow = &mut dkb[j * d..(j + 1) * d];
                                for t in 0..hd {
                                    dkrow[h * hd + t] += ds * scale * qrow[t];
                                }
                                // dV[j] += P[i,j] · dO[i]
                            }
                            for j in 0..=i {
                                let pij = p.at(i, j);
                                if pij == 0.0 {
                                    continue;
                                }
                                let dvrow = &mut dvb[j * d..(j + 1) * d];
                                for t in 0..hd {
                                    dvrow[h * hd + t] += pij * dorow[t];
                                }
                            }
                        }
                    }
                });
            }

            let dwq = matmul_tn(&lc.xn1, &dq);
            let dwk = matmul_tn(&lc.xn1, &dk);
            let dwv = matmul_tn(&lc.xn1, &dv);
            let mut dxn1 = matmul_nt(&dq, &lp.wq);
            dxn1.axpy(1.0, &matmul_nt(&dk, &lp.wk));
            dxn1.axpy(1.0, &matmul_nt(&dv, &lp.wv));
            let mut dnorm1 = vec![0.0f32; d];
            let dx_in_from_attn =
                rmsnorm_bwd(&lc.x_in, &lp.norm1, &lc.rms1, &dxn1, &mut dnorm1);

            // total gradient into the layer input
            let mut dx_in = dx_mid;
            dx_in.axpy(1.0, &dx_in_from_attn);
            dx = dx_in;

            layer_grads.push(LayerGrads {
                wq: dwq,
                wk: dwk,
                wv: dwv,
                wo: dwo,
                w1: dw1,
                w3: dw3,
                w2: dw2,
                norm1: dnorm1,
                norm2: dnorm2,
            });
        }
        layer_grads.reverse();

        // embedding lookup backward (input side)
        let _ = &cache.x0;
        for (i, &t) in tokens.iter().enumerate() {
            let drow = dx.row(i);
            let erow = d_embed.row_mut(t as usize);
            for j in 0..d {
                erow[j] += drow[j];
            }
        }

        (
            loss,
            Gradients { embed: d_embed, layers: layer_grads, final_norm: d_final_norm },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LlamaConfig;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig { vocab: 16, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12, seq_len: 4 }
    }

    fn sample_batch(cfg: &LlamaConfig, batch: usize, seq: usize, seed: u64) -> (Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let toks: Vec<u32> = (0..batch * seq).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let tgts: Vec<u32> = (0..batch * seq).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        (toks, tgts)
    }

    #[test]
    fn loss_is_near_uniform_at_init() {
        let cfg = tiny_cfg();
        let m = SimModel::new(cfg, 1);
        let (toks, tgts) = sample_batch(&cfg, 2, 4, 2);
        let loss = m.loss(&toks, &tgts, 2, 4);
        let uniform = (cfg.vocab as f64).ln();
        assert!((loss - uniform).abs() < 0.5, "loss={loss} uniform={uniform}");
    }

    #[test]
    fn grad_matches_finite_differences() {
        let cfg = tiny_cfg();
        let mut m = SimModel::new(cfg, 3);
        let (toks, tgts) = sample_batch(&cfg, 2, 4, 4);
        let (_, grads) = m.loss_and_grad(&toks, &tgts, 2, 4);

        let eps = 1e-3f32;
        // check a selection of entries across every parameter tensor
        let checks: Vec<(&str, usize, usize)> = vec![
            ("wq", 3, 5),
            ("wk", 1, 2),
            ("wv", 0, 7),
            ("wo", 4, 4),
            ("w1", 2, 9),
            ("w3", 7, 3),
            ("w2", 10, 1),
            ("embed", 5, 2),
        ];
        for (name, i, j) in checks {
            let analytic = match name {
                "wq" => grads.layers[0].wq.at(i, j),
                "wk" => grads.layers[0].wk.at(i, j),
                "wv" => grads.layers[0].wv.at(i, j),
                "wo" => grads.layers[0].wo.at(i, j),
                "w1" => grads.layers[0].w1.at(i, j),
                "w3" => grads.layers[0].w3.at(i, j),
                "w2" => grads.layers[0].w2.at(i, j),
                "embed" => grads.embed.at(i, j),
                _ => unreachable!(),
            } as f64;
            let get = |m: &mut SimModel| -> *mut f32 {
                match name {
                    "wq" => m.params.layers[0].wq.at_mut(i, j),
                    "wk" => m.params.layers[0].wk.at_mut(i, j),
                    "wv" => m.params.layers[0].wv.at_mut(i, j),
                    "wo" => m.params.layers[0].wo.at_mut(i, j),
                    "w1" => m.params.layers[0].w1.at_mut(i, j),
                    "w3" => m.params.layers[0].w3.at_mut(i, j),
                    "w2" => m.params.layers[0].w2.at_mut(i, j),
                    "embed" => m.params.embed.at_mut(i, j),
                    _ => unreachable!(),
                }
            };
            unsafe {
                let p = get(&mut m);
                let orig = *p;
                *p = orig + eps;
                let lp = m.loss(&toks, &tgts, 2, 4);
                *p = orig - eps;
                let lm = m.loss(&toks, &tgts, 2, 4);
                *p = orig;
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let denom = numeric.abs().max(analytic.abs()).max(1e-4);
                let rel = (numeric - analytic).abs() / denom;
                assert!(rel < 0.05, "{name}[{i},{j}]: analytic={analytic} numeric={numeric}");
            }
        }
    }

    #[test]
    fn norm_grads_match_finite_differences() {
        let cfg = tiny_cfg();
        let mut m = SimModel::new(cfg, 5);
        let (toks, tgts) = sample_batch(&cfg, 1, 4, 6);
        let (_, grads) = m.loss_and_grad(&toks, &tgts, 1, 4);
        let eps = 1e-3f32;
        for j in [0usize, 3, 7] {
            let analytic = grads.layers[0].norm1[j] as f64;
            let orig = m.params.layers[0].norm1[j];
            m.params.layers[0].norm1[j] = orig + eps;
            let lp = m.loss(&toks, &tgts, 1, 4);
            m.params.layers[0].norm1[j] = orig - eps;
            let lm = m.loss(&toks, &tgts, 1, 4);
            m.params.layers[0].norm1[j] = orig;
            let numeric = (lp - lm) / (2.0 * eps as f64);
            let rel = (numeric - analytic).abs() / numeric.abs().max(analytic.abs()).max(1e-4);
            assert!(rel < 0.05, "norm1[{j}]: analytic={analytic} numeric={numeric}");
            // final norm too
            let analytic_f = grads.final_norm[j] as f64;
            let orig_f = m.params.final_norm[j];
            m.params.final_norm[j] = orig_f + eps;
            let lpf = m.loss(&toks, &tgts, 1, 4);
            m.params.final_norm[j] = orig_f - eps;
            let lmf = m.loss(&toks, &tgts, 1, 4);
            m.params.final_norm[j] = orig_f;
            let numeric_f = (lpf - lmf) / (2.0 * eps as f64);
            let rel_f =
                (numeric_f - analytic_f).abs() / numeric_f.abs().max(analytic_f.abs()).max(1e-4);
            assert!(rel_f < 0.05, "final_norm[{j}]");
        }
    }

    #[test]
    fn incremental_decode_matches_full_forward_bitwise() {
        // any prefill/decode split of the same token stream must yield
        // the exact bits of the full-context forward's last-position row
        let cfg = tiny_cfg();
        let m = SimModel::new(cfg, 11);
        let mut rng = Rng::new(12);
        let toks: Vec<u32> = (0..10).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let full = m.forward_logits(&toks, 1, toks.len());
        for split in [1usize, 4, 10] {
            let mut cache = KvCache::new(&cfg, 16);
            let mut ws = Workspace::new();
            let mut logits = Matrix::zeros(0, 0);
            m.forward_step(&toks[..split], &mut cache, &mut ws, &mut logits);
            for p in split..toks.len() {
                m.forward_step(&toks[p..p + 1], &mut cache, &mut ws, &mut logits);
            }
            assert_eq!(cache.len(), toks.len());
            assert_eq!(logits.row(0), full.row(toks.len() - 1), "split={split}");
        }
    }

    #[test]
    fn cleared_cache_decodes_like_a_fresh_one() {
        let cfg = tiny_cfg();
        let m = SimModel::new(cfg, 13);
        let mut cache = KvCache::new(&cfg, 8);
        let mut ws = Workspace::new();
        let mut logits = Matrix::zeros(0, 0);
        m.forward_step(&[3, 1, 4, 1, 5], &mut cache, &mut ws, &mut logits);
        cache.clear();
        assert!(cache.is_empty());
        m.forward_step(&[2, 7], &mut cache, &mut ws, &mut logits);
        let mut fresh = KvCache::new(&cfg, 8);
        let mut logits2 = Matrix::zeros(0, 0);
        m.forward_step(&[2, 7], &mut fresh, &mut ws, &mut logits2);
        assert_eq!(logits, logits2, "slot reuse leaked state");
    }

    #[test]
    fn bf16_kv_cache_halves_bytes_and_decodes_deterministically() {
        let cfg = tiny_cfg();
        let m = SimModel::new(cfg, 11);
        let mut rng = Rng::new(12);
        let toks: Vec<u32> = (0..10).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
        let f32_cache = KvCache::new(&cfg, 16);
        let mut decode = |dtype: QuantDtype| {
            let mut cache = KvCache::with_dtype(&cfg, 16, dtype);
            let mut ws = Workspace::new();
            let mut logits = Matrix::zeros(0, 0);
            m.forward_step(&toks[..4], &mut cache, &mut ws, &mut logits);
            for p in 4..toks.len() {
                m.forward_step(&toks[p..p + 1], &mut cache, &mut ws, &mut logits);
            }
            (cache.bytes(), logits)
        };
        let (b_f32, l_f32) = decode(QuantDtype::F32);
        let (b_bf16, l_bf16) = decode(QuantDtype::Bf16);
        let (b_bf16_again, l_bf16_again) = decode(QuantDtype::Bf16);
        assert_eq!(b_f32, f32_cache.bytes(), "default constructor is the f32 footprint");
        assert_eq!(b_bf16 * 2, b_f32, "bf16 K/V is exactly half the bytes");
        assert_eq!(l_bf16, l_bf16_again, "bf16 decode is deterministic");
        assert_ne!(l_f32.data, l_bf16.data, "rounding is real, not a no-op");
        // bf16 keeps 8 mantissa bits; tiny-model logits stay close
        for (a, b) in l_f32.data.iter().zip(&l_bf16.data) {
            assert!((a - b).abs() < 0.15, "bf16 drift too large: {a} vs {b}");
        }
    }

    #[test]
    fn causality_future_tokens_do_not_affect_loss() {
        // changing a future input token must not change the loss at
        // earlier positions; we test via per-position loss on position 0
        let cfg = tiny_cfg();
        let m = SimModel::new(cfg, 7);
        let (mut toks, tgts) = sample_batch(&cfg, 1, 4, 8);
        // per-position NLL of position 0 extracted by a 1-token target trick:
        // compute full loss with only position 0 contributing via target
        // comparison across perturbed runs
        let cache0 = m.forward_cached(&toks, 1, 4);
        toks[3] = (toks[3] + 1) % cfg.vocab as u32;
        let cache1 = m.forward_cached(&toks, 1, 4);
        // logits at position 0..2 must be identical
        for pos in 0..3 {
            let a = cache0.xf.row(pos);
            let b = cache1.xf.row(pos);
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-6, "pos {pos} leaked future info");
            }
        }
        let _ = tgts;
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        // 50 Adam steps on one batch must overfit it substantially
        let cfg = tiny_cfg();
        let mut m = SimModel::new(cfg, 9);
        let (toks, tgts) = sample_batch(&cfg, 2, 4, 10);
        let l0 = m.loss(&toks, &tgts, 2, 4);
        use crate::optim::{Adam, Hyper, Optimizer};
        let hyper = Hyper { lr: 5e-3, ..Default::default() };
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut opts: Vec<Adam> = Vec::new();
        for _ in 0..cfg.n_layers {
            for (r, c) in [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)] {
                opts.push(Adam::new(r, c));
            }
        }
        let mut emb_opt = Adam::new(cfg.vocab, d);
        for t in 1..=60 {
            let (_, g) = m.loss_and_grad(&toks, &tgts, 2, 4);
            let mut oi = 0;
            for (li, lg) in g.layers.iter().enumerate() {
                let lp = &mut m.params.layers[li];
                for (w, gw) in [
                    (&mut lp.wq, &lg.wq),
                    (&mut lp.wk, &lg.wk),
                    (&mut lp.wv, &lg.wv),
                    (&mut lp.wo, &lg.wo),
                    (&mut lp.w1, &lg.w1),
                    (&mut lp.w3, &lg.w3),
                    (&mut lp.w2, &lg.w2),
                ] {
                    opts[oi].step(w, gw, &hyper, t);
                    oi += 1;
                }
            }
            emb_opt.step(&mut m.params.embed, &g.embed, &hyper, t);
        }
        let l1 = m.loss(&toks, &tgts, 2, 4);
        assert!(l1 < l0 * 0.7, "l0={l0} l1={l1}");
    }
}
