//! Rust-native pre-training loop sweeping the paper's methods over the
//! [`SimModel`] transformer — the engine behind `benches/table1.rs`,
//! `benches/table3.rs`, `benches/table4.rs` and `benches/fig2_time.rs`.
//!
//! Per-matrix optimizers are `Box<dyn Optimizer>` built by the single
//! registry ([`crate::optim::registry`]); subspace switches, adapter
//! merges and diagnostics arrive as uniform [`StepEvent`]s, and the
//! whole trainer state (weights + every optimizer's [`OptState`])
//! checkpoints through [`SimTrainer::save_checkpoint`] for any method.

use super::model::{Gradients, LayerGrads, LayerParams, Params, SimModel};
use crate::data::batch::SyncBatcher;
use crate::data::corpus::CorpusGen;
use crate::models::LlamaConfig;
use crate::optim::registry::{self, TrainPhase};
use crate::optim::{Adam, Hyper, OptState, Optimizer, StepEvent};
use crate::quant::QuantCfg;
use crate::runtime::pool;
use crate::subspace::SubspaceStats;
use crate::telemetry::{self, diag, span, SpanKind, SPAN_KINDS};
use crate::tensor::Matrix;
use crate::train::checkpoint::{self, push_u64, read_u64_limbs};
use crate::util::json::JsonValue;
use crate::util::timer::PhaseTimer;
use crate::util::Rng;
use anyhow::{anyhow, Context, Result};

pub use crate::optim::Method;

/// Per-matrix optimizer seed — one formula shared by [`SimTrainer`] and
/// the dist engine ([`crate::dist`]) so their per-matrix projector RNG
/// streams coincide bit-for-bit (`mi` is the global matrix index,
/// layer-major, 7 per layer).
pub fn mat_seed(run_seed: u64, li: usize, mi: usize) -> u64 {
    run_seed ^ ((li as u64) << 8) ^ mi as u64
}

/// The seven projected matrix shapes of one transformer layer, in the
/// canonical wq, wk, wv, wo, w1, w3, w2 order — the single source of
/// truth shared by [`SimTrainer`], the dist engine and the dist tests
/// (their bit-identity depends on this table staying in lockstep).
pub fn layer_matrix_shapes(cfg: &LlamaConfig) -> [(usize, usize); 7] {
    let (d, f) = (cfg.d_model, cfg.d_ff);
    [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)]
}

/// Canonical names of the seven projected matrices, index-aligned with
/// [`layer_matrix_shapes`] (telemetry records label switch events with
/// these).
pub const MAT_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "w1", "w3", "w2"];

/// Global gradient norm over the projected matrices + embedding (what
/// the telemetry step records report as `grad_norm`). Read-only — the
/// update path is untouched.
pub fn grad_global_norm(grads: &Gradients) -> f64 {
    let mut s = 0.0f64;
    for lg in &grads.layers {
        for m in [&lg.wq, &lg.wk, &lg.wv, &lg.wo, &lg.w1, &lg.w3, &lg.w2] {
            let n = m.fro_norm() as f64;
            s += n * n;
        }
    }
    let e = grads.embed.fro_norm() as f64;
    s += e * e;
    s.sqrt()
}

/// Global gradient norm over *every* trained tensor — the projected
/// matrices, both per-layer norm vectors, the final norm and the
/// embedding. This is the quantity `--clip-norm` bounds (a strict
/// superset of [`grad_global_norm`], which reports only the matrices).
pub fn grad_full_norm(grads: &Gradients) -> f64 {
    let mut s = 0.0f64;
    for lg in &grads.layers {
        for m in [&lg.wq, &lg.wk, &lg.wv, &lg.wo, &lg.w1, &lg.w3, &lg.w2] {
            let n = m.fro_norm() as f64;
            s += n * n;
        }
        for v in [&lg.norm1, &lg.norm2] {
            s += v.iter().map(|x| *x as f64 * *x as f64).sum::<f64>();
        }
    }
    let e = grads.embed.fro_norm() as f64;
    s += e * e;
    s += grads.final_norm.iter().map(|x| *x as f64 * *x as f64).sum::<f64>();
    s.sqrt()
}

/// Scale every gradient tensor in place — the apply half of global-norm
/// clipping, shared with the dist engine's per-shard clip so a 1-shard
/// dist run clips bit-identically to this trainer.
pub fn scale_gradients(grads: &mut Gradients, s: f32) {
    for lg in &mut grads.layers {
        for m in [
            &mut lg.wq,
            &mut lg.wk,
            &mut lg.wv,
            &mut lg.wo,
            &mut lg.w1,
            &mut lg.w3,
            &mut lg.w2,
        ] {
            m.scale(s);
        }
        for x in lg.norm1.iter_mut() {
            *x *= s;
        }
        for x in lg.norm2.iter_mut() {
            *x *= s;
        }
    }
    grads.embed.scale(s);
    for x in grads.final_norm.iter_mut() {
        *x *= s;
    }
}

/// Full-Adam update of the tensors every method trains densely (norm
/// vectors + embedding) — a single code path shared by [`SimTrainer`]
/// and the dist engine, which makes the S=1 dist run structurally
/// bit-identical to this trainer. `scale` folds the data-parallel 1/S
/// gradient averaging (pass 1.0 for an already-averaged full-batch
/// gradient; multiplying by 1.0 is bit-exact).
pub fn dense_tail_update(
    params: &mut Params,
    grads: &mut Gradients,
    norm_opts: &mut [Adam],
    emb_opt: &mut Adam,
    hyper: &Hyper,
    t: u64,
    scale: f32,
) {
    for (li, lg) in grads.layers.iter().enumerate() {
        let lp = &mut params.layers[li];
        let mut n1 = Matrix::from_vec(1, lp.norm1.len(), lp.norm1.clone());
        let g1 =
            Matrix::from_vec(1, lg.norm1.len(), lg.norm1.iter().map(|x| x * scale).collect());
        norm_opts[2 * li].step(&mut n1, &g1, hyper, t);
        lp.norm1.copy_from_slice(&n1.data);
        let mut n2 = Matrix::from_vec(1, lp.norm2.len(), lp.norm2.clone());
        let g2 =
            Matrix::from_vec(1, lg.norm2.len(), lg.norm2.iter().map(|x| x * scale).collect());
        norm_opts[2 * li + 1].step(&mut n2, &g2, hyper, t);
        lp.norm2.copy_from_slice(&n2.data);
    }
    let mut fnorm = Matrix::from_vec(1, params.final_norm.len(), params.final_norm.clone());
    let gf = Matrix::from_vec(
        1,
        grads.final_norm.len(),
        grads.final_norm.iter().map(|x| x * scale).collect(),
    );
    let last = norm_opts.len() - 1;
    norm_opts[last].step(&mut fnorm, &gf, hyper, t);
    params.final_norm.copy_from_slice(&fnorm.data);
    if scale != 1.0 {
        grads.embed.scale(scale);
    }
    emb_opt.step(&mut params.embed, &grads.embed, hyper, t);
}

/// Training report: everything the paper tables need.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub method: &'static str,
    pub steps: u64,
    pub final_ppl: f64,
    pub loss_curve: Vec<(u64, f64)>,
    pub eval_curve: Vec<(u64, f64)>,
    pub stats: SubspaceStats,
    /// Measured persistent optimizer-state bytes at the end of training.
    pub state_bytes: u64,
    /// Wall-clock totals by phase.
    pub time_grad_s: f64,
    pub time_update_s: f64,
    pub total_s: f64,
    /// Diagnostic traces (layer 0's policy diagnostic per step), for Fig 1.
    pub diag_trace: Vec<(u64, f64)>,
    /// Switch-event steps for layer 0, for Fig 1.
    pub switch_steps: Vec<u64>,
    /// Steps withheld by the non-finite guard (no weight or moment was
    /// touched on those steps).
    pub skipped_steps: u64,
    /// Steps whose gradient was rescaled by global-norm clipping
    /// (`clip_norm > 0` only).
    pub clipped_steps: u64,
}

/// Configuration for a sim training run.
#[derive(Clone, Copy, Debug)]
pub struct SimRunCfg {
    pub model: LlamaConfig,
    pub rank: usize,
    pub batch: usize,
    pub steps: u64,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub hyper: Hyper,
    pub seed: u64,
    pub coherence: f64,
    /// Quantization surfaces (`[quant]` block): dist wire dtype, KV
    /// cache dtype, optimizer-moment dtype. All-f32 default keeps every
    /// legacy path bit-exact.
    pub quant: QuantCfg,
    /// Global gradient-norm clip threshold (0.0 = off, the default —
    /// bit-exact legacy behaviour). Applied after the non-finite guard
    /// and before any moment sees the gradient, so a clipped spike never
    /// reaches the optimizer state or the loss-spike detector downstream.
    pub clip_norm: f64,
}

impl SimRunCfg {
    pub fn quick(model: LlamaConfig, rank: usize, steps: u64) -> Self {
        SimRunCfg {
            model,
            rank,
            batch: 8,
            steps,
            eval_every: (steps / 10).max(1),
            eval_batches: 4,
            hyper: Hyper { lr: 3e-3, galore_scale: 1.0, ..Default::default() },
            seed: 42,
            coherence: 0.75,
            quant: QuantCfg::default(),
            clip_norm: 0.0,
        }
    }
}

/// The simulator trainer: one model + one method.
pub struct SimTrainer {
    pub cfg: SimRunCfg,
    pub method: Method,
    model: SimModel,
    opts: Vec<Box<dyn Optimizer>>, // one per projected matrix, layer-major
    emb_opt: Adam,
    norm_opts: Vec<Adam>, // norm1, norm2 per layer + final (as 1×d)
    batcher: SyncBatcher,
    eval_batcher: SyncBatcher,
    /// Steps executed so far ([`SimTrainer::train`] continues from here,
    /// which is what lets a checkpoint resume mid-run).
    step: u64,
    eval_batches_drawn: u64,
    /// EMA of the pre-clip gradient norm, feeding the clip record's
    /// anomaly score. Diagnostic-only — deliberately not checkpointed
    /// (it re-seeds from the first post-resume step).
    clip_ema: f64,
}

const SIM_META: &str = "sim/meta";

impl SimTrainer {
    pub fn new(cfg: &SimRunCfg, method: Method, seed: u64) -> Self {
        let model = SimModel::new(cfg.model, seed);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let d = cfg.model.d_model;
        let mut opts: Vec<Box<dyn Optimizer>> = Vec::new();
        for li in 0..cfg.model.n_layers {
            for (rows, cols) in layer_matrix_shapes(&cfg.model) {
                let s = mat_seed(seed, li, opts.len());
                opts.push(registry::build_with_state(
                    method,
                    cfg.rank,
                    rows,
                    cols,
                    s,
                    &mut rng,
                    TrainPhase::Pretrain,
                    cfg.quant.state_quant(),
                ));
            }
        }
        let emb_opt = Adam::new(cfg.model.vocab, d);
        let mut norm_opts = Vec::new();
        for _ in 0..(2 * cfg.model.n_layers + 1) {
            norm_opts.push(Adam::new(1, d));
        }
        let batcher = SyncBatcher::new(
            CorpusGen::new(cfg.model.vocab, cfg.seed, cfg.coherence),
            cfg.batch,
            cfg.model.seq_len,
        );
        let eval_batcher = SyncBatcher::new(
            CorpusGen::new(cfg.model.vocab, cfg.seed ^ 0xEEEE, cfg.coherence),
            cfg.batch,
            cfg.model.seq_len,
        );
        SimTrainer {
            cfg: *cfg,
            method,
            model,
            opts,
            emb_opt,
            norm_opts,
            batcher,
            eval_batcher,
            step: 0,
            eval_batches_drawn: 0,
            clip_ema: 0.0,
        }
    }

    /// The trained model (read access — the dist engine's equivalence
    /// tests compare replica weights against this path bit-for-bit).
    pub fn model(&self) -> &SimModel {
        &self.model
    }

    /// Steps executed so far.
    pub fn current_step(&self) -> u64 {
        self.step
    }

    /// Held-out perplexity over `n` fresh eval batches.
    pub fn eval_ppl(&mut self, n: usize) -> f64 {
        let mut total = 0.0;
        for _ in 0..n {
            let b = self.eval_batcher.next();
            total += self.model.loss(&b.tokens, &b.targets, b.batch, b.seq);
        }
        self.eval_batches_drawn += n as u64;
        (total / n as f64).exp()
    }

    /// Returns the step's switch events as telemetry JSON (empty when
    /// no metrics sink is installed).
    fn apply_update(
        &mut self,
        grads: &mut Gradients,
        t: u64,
        stats: &mut SubspaceStats,
        report: &mut TrainReport,
    ) -> Vec<JsonValue> {
        let hyper = self.cfg.hyper;
        // ---- projected matrices: fan layers out across the pool ----
        // Layers are independent (disjoint weights, per-optimizer RNG
        // streams), so the update — including any subspace refresh — is
        // deterministic at any thread count. Events are collected into
        // per-matrix slots and folded into stats after the join.
        let n_mat = self.opts.len();
        let mut events: Vec<StepEvent> = vec![StepEvent::None; n_mat];
        {
            let mut jobs: Vec<(
                &mut LayerParams,
                &LayerGrads,
                &mut [Box<dyn Optimizer>],
                &mut [StepEvent],
            )> = Vec::with_capacity(grads.layers.len());
            let mut opts_rest: &mut [Box<dyn Optimizer>] = &mut self.opts;
            let mut ev_rest: &mut [StepEvent] = &mut events;
            for (lp, lg) in self.model.params.layers.iter_mut().zip(&grads.layers) {
                let (o, orest) = std::mem::take(&mut opts_rest).split_at_mut(7);
                opts_rest = orest;
                let (e, erest) = std::mem::take(&mut ev_rest).split_at_mut(7);
                ev_rest = erest;
                jobs.push((lp, lg, o, e));
            }
            pool::global().par_items_mut(&mut jobs, |_li, job| {
                let (lp, lg, opts, evs) = job;
                for (slot, (w, g)) in [
                    (&mut lp.wq, &lg.wq),
                    (&mut lp.wk, &lg.wk),
                    (&mut lp.wv, &lg.wv),
                    (&mut lp.wo, &lg.wo),
                    (&mut lp.w1, &lg.w1),
                    (&mut lp.w3, &lg.w3),
                    (&mut lp.w2, &lg.w2),
                ]
                .into_iter()
                .enumerate()
                {
                    evs[slot] = opts[slot].step(w, g, &hyper, t);
                }
            });
        }
        let emit = telemetry::metrics_enabled();
        let mut switches = Vec::new();
        for (oi, ev) in events.iter().enumerate() {
            stats.record_observation();
            match *ev {
                StepEvent::Switched { reason, lifetime, rank } => {
                    stats.record_switch(reason, lifetime);
                    if oi == 0 {
                        report.switch_steps.push(t);
                    }
                    if emit {
                        switches.push(JsonValue::obj(vec![
                            ("layer", JsonValue::num((oi / 7) as f64)),
                            ("mat", JsonValue::str(MAT_NAMES[oi % 7])),
                            ("reason", JsonValue::str(telemetry::reason_str(reason))),
                            ("lifetime", JsonValue::num(lifetime as f64)),
                            ("rank", JsonValue::num(rank as f64)),
                        ]));
                    }
                }
                StepEvent::Merged { .. } => stats.record_merge(),
                StepEvent::None | StepEvent::SkippedNonFinite => {}
            }
        }
        if let Some(d) = self.opts[0].diagnostic() {
            report.diag_trace.push((t, d));
        }
        // ---- norm vectors + embedding: tiny, serial full Adam (shared
        // with the dist engine; 1.0 scale = already-averaged gradient) ----
        dense_tail_update(
            &mut self.model.params,
            grads,
            &mut self.norm_opts,
            &mut self.emb_opt,
            &hyper,
            t,
            1.0,
        );
        switches
    }

    /// Run `steps` training steps (continuing from the current step
    /// counter, so a loaded checkpoint resumes exactly).
    pub fn train(&mut self, steps: u64) -> TrainReport {
        let mut report = TrainReport {
            method: self.method.name(),
            steps,
            final_ppl: f64::NAN,
            loss_curve: Vec::new(),
            eval_curve: Vec::new(),
            stats: SubspaceStats::default(),
            state_bytes: 0,
            time_grad_s: 0.0,
            time_update_s: 0.0,
            total_s: 0.0,
            diag_trace: Vec::new(),
            switch_steps: Vec::new(),
            skipped_steps: 0,
            clipped_steps: 0,
        };
        let mut stats = SubspaceStats::default();
        let mut timer = PhaseTimer::new();
        let t_total = std::time::Instant::now();
        for _ in 0..steps {
            self.step += 1;
            let t = self.step;
            let emit = telemetry::metrics_enabled();
            let (ns0, c0) = if emit {
                (telemetry::phase_totals_ns(), telemetry::phase_counts())
            } else {
                ([0u64; SPAN_KINDS], [0u64; SPAN_KINDS])
            };
            let step_sp = span(SpanKind::Step);
            let b = self.batcher.next();
            let (loss, mut grads) = timer.time("grad", || {
                let _sp = span(SpanKind::Grad);
                self.model.loss_and_grad(&b.tokens, &b.targets, b.batch, b.seq)
            });
            // skip-step guard: a non-finite loss/gradient must not reach
            // the moments (it used to contaminate them silently)
            if !loss.is_finite() || grads.has_non_finite() {
                report.skipped_steps += 1;
                crate::log_info!("step {t}: non-finite loss/gradient — update skipped");
                continue;
            }
            // global-norm clipping (off at 0.0): bounds the *full*
            // gradient — matrices, norm vectors and embedding — after
            // the non-finite guard and upstream of the spike detector,
            // so a survivable spike is tamed instead of tripping it
            if self.cfg.clip_norm > 0.0 {
                let pre = grad_full_norm(&grads);
                let anomaly = if self.clip_ema > 0.0 { pre / self.clip_ema } else { 1.0 };
                self.clip_ema =
                    if self.clip_ema > 0.0 { 0.9 * self.clip_ema + 0.1 * pre } else { pre };
                if pre > self.cfg.clip_norm {
                    report.clipped_steps += 1;
                    scale_gradients(&mut grads, (self.cfg.clip_norm / pre) as f32);
                    if emit {
                        telemetry::emit_record(&JsonValue::obj(vec![
                            ("type", JsonValue::str("clipped")),
                            ("step", JsonValue::num(t as f64)),
                            ("grad_norm", JsonValue::num(pre)),
                            ("clip_norm", JsonValue::num(self.cfg.clip_norm)),
                            ("anomaly", JsonValue::num(anomaly)),
                        ]));
                    }
                }
            }
            let grad_norm = if emit { grad_global_norm(&grads) } else { 0.0 };
            let switches = timer.time("update", || {
                let _sp = span(SpanKind::Update);
                self.apply_update(&mut grads, t, &mut stats, &mut report)
            });
            if t % 10 == 0 || t == 1 {
                report.loss_curve.push((t, loss));
            }
            if t % self.cfg.eval_every == 0 {
                let _sp = span(SpanKind::Eval);
                let ppl = self.eval_ppl(self.cfg.eval_batches);
                report.eval_curve.push((t, ppl));
            }
            drop(step_sp);
            // subspace-quality probes: per-matrix capture/residual/noise
            // samples every probe_every steps. Records flow to the JSONL
            // stream, gauges to the registry (and from there to the
            // Prometheus snapshot); with probes off this whole block is
            // one relaxed atomic load.
            let prom = diag::prom_enabled();
            if (emit || prom) && diag::probe_step(t) {
                let _sp = span(SpanKind::Probe);
                for (oi, opt) in self.opts.iter().enumerate() {
                    if let Some(s) = opt.probe_sample() {
                        let (li, mat) = (oi / 7, MAT_NAMES[oi % 7]);
                        if emit {
                            telemetry::emit_record(&s.to_record(t, li, mat));
                        }
                        s.set_gauges(li, mat);
                    }
                }
            }
            if prom {
                telemetry::REGISTRY.gauge("train.step").set(t);
                telemetry::REGISTRY.gauge("train.loss_micro").set(diag::micro(loss));
            }
            if emit {
                let (ns1, c1) = (telemetry::phase_totals_ns(), telemetry::phase_counts());
                let mut disp = Vec::with_capacity(self.cfg.model.n_layers);
                for li in 0..self.cfg.model.n_layers {
                    let mut sum = 0.0f64;
                    let mut n = 0u32;
                    for k in 0..7 {
                        if let Some(d) = self.opts[li * 7 + k].diagnostic() {
                            sum += d;
                            n += 1;
                        }
                    }
                    disp.push(if n > 0 { JsonValue::num(sum / n as f64) } else { JsonValue::Null });
                }
                telemetry::emit_record(&JsonValue::obj(vec![
                    ("type", JsonValue::str("step")),
                    ("step", JsonValue::num(t as f64)),
                    ("loss", JsonValue::num(loss)),
                    ("grad_norm", JsonValue::num(grad_norm)),
                    ("displacement", JsonValue::arr(disp)),
                    ("switches", JsonValue::arr(switches)),
                    ("wall", telemetry::phase_delta_json(&ns0, &c0, &ns1, &c1)),
                ]));
            }
            if prom {
                diag::flush_prom();
            }
        }
        report.final_ppl = {
            let _sp = span(SpanKind::Eval);
            self.eval_ppl(self.cfg.eval_batches * 2)
        };
        report.stats = stats;
        report.state_bytes = self.opts.iter().map(|o| o.state_bytes() as u64).sum::<u64>()
            + self.emb_opt.state_bytes() as u64
            + self.norm_opts.iter().map(|o| o.state_bytes() as u64).sum::<u64>();
        report.time_grad_s = timer.total("grad").as_secs_f64();
        report.time_update_s = timer.total("update").as_secs_f64();
        report.total_s = t_total.elapsed().as_secs_f64();
        report
    }

    /// Save the full training state — weights (borrowed, never copied)
    /// plus every per-matrix optimizer's typed [`OptState`] (any
    /// registered method, not just the projected ones; exporting makes
    /// a transient copy of the optimizer state) and the data cursors.
    /// The container is the same named-f32-tensor format the dist and
    /// PJRT paths write.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let _sp = span(SpanKind::Checkpoint);
        let (mut synth, refs) = self.model.params.export_tensors();
        for (mi, opt) in self.opts.iter().enumerate() {
            opt.export_state().to_tensors(&format!("opt/m{mi}"), &mut synth);
        }
        self.emb_opt.export_state().to_tensors("opt/emb", &mut synth);
        for (i, o) in self.norm_opts.iter().enumerate() {
            o.export_state().to_tensors(&format!("opt/norm{i}"), &mut synth);
        }
        let mut meta = Vec::with_capacity(4);
        push_u64(&mut meta, self.eval_batches_drawn);
        let cols = meta.len();
        synth.push((SIM_META.into(), Matrix::from_vec(1, cols, meta)));

        let mut tensors: Vec<(String, &Matrix)> = refs;
        tensors.extend(synth.iter().map(|(n, m)| (n.clone(), m)));
        checkpoint::save_refs(path, self.step, &tensors)
    }

    /// Restore a [`SimTrainer::save_checkpoint`] file; subsequent steps
    /// are bit-identical to the uninterrupted run (data streams are
    /// replayed to the saved cursor).
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let (step, tensors) = checkpoint::load(path)?;
        self.model.params.restore_from_tensors(&tensors).map_err(|e| anyhow!("{e}"))?;
        for (mi, opt) in self.opts.iter_mut().enumerate() {
            let prefix = format!("opt/m{mi}");
            let state = OptState::from_tensors(&prefix, &tensors).map_err(|e| anyhow!("{e}"))?;
            opt.restore_state(state)
                .map_err(|e| anyhow!("{e}"))
                .with_context(|| format!("restoring optimizer state for matrix {mi}"))?;
        }
        let emb = OptState::from_tensors("opt/emb", &tensors).map_err(|e| anyhow!("{e}"))?;
        self.emb_opt.restore_state(emb).map_err(|e| anyhow!("{e}"))?;
        for (i, o) in self.norm_opts.iter_mut().enumerate() {
            let s = OptState::from_tensors(&format!("opt/norm{i}"), &tensors)
                .map_err(|e| anyhow!("{e}"))?;
            o.restore_state(s).map_err(|e| anyhow!("{e}"))?;
        }
        let meta = tensors
            .iter()
            .find(|(n, _)| n == SIM_META)
            .map(|(_, m)| m)
            .with_context(|| format!("checkpoint missing tensor '{SIM_META}'"))?;
        let eval_drawn = read_u64_limbs(&meta.data, 0);
        // rebuild the deterministic data streams from scratch and replay
        // them to the saved cursor — correct even when this trainer has
        // already stepped (loading is a rollback, not a continuation)
        self.batcher = SyncBatcher::new(
            CorpusGen::new(self.cfg.model.vocab, self.cfg.seed, self.cfg.coherence),
            self.cfg.batch,
            self.cfg.model.seq_len,
        );
        self.eval_batcher = SyncBatcher::new(
            CorpusGen::new(self.cfg.model.vocab, self.cfg.seed ^ 0xEEEE, self.cfg.coherence),
            self.cfg.batch,
            self.cfg.model.seq_len,
        );
        for _ in 0..step {
            let _ = self.batcher.next();
        }
        for _ in 0..eval_drawn {
            let _ = self.eval_batcher.next();
        }
        self.eval_batches_drawn = eval_drawn;
        self.step = step;
        Ok(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::llama_tiny_cfg;

    fn quick_cfg() -> SimRunCfg {
        let mut cfg = SimRunCfg::quick(llama_tiny_cfg(), 16, 60);
        cfg.batch = 4;
        cfg
    }

    #[test]
    fn full_rank_learns_corpus_structure() {
        let cfg = quick_cfg();
        let mut t = SimTrainer::new(&cfg, Method::FullRank, 1);
        let ppl0 = t.eval_ppl(2);
        let report = t.train(60);
        assert!(report.final_ppl < ppl0 * 0.85, "ppl0={ppl0} final={}", report.final_ppl);
        assert!(report.final_ppl.is_finite());
    }

    #[test]
    fn lotus_learns_and_switches() {
        let cfg = quick_cfg();
        let mut t = SimTrainer::new(&cfg, Method::Lotus { gamma: 0.02, eta: 10, t_min: 10 }, 2);
        let ppl0 = t.eval_ppl(2);
        let report = t.train(60);
        assert!(report.final_ppl < ppl0, "no learning: {ppl0} -> {}", report.final_ppl);
        // init switches at minimum (one per projected matrix)
        assert!(report.stats.subspace_count >= 14, "{:?}", report.stats.subspace_count);
    }

    #[test]
    fn galore_switches_on_schedule() {
        let cfg = quick_cfg();
        let mut t = SimTrainer::new(&cfg, Method::GaLore { interval: 20 }, 3);
        let report = t.train(60);
        // 14 matrices × (1 init + 2 interval switches) = 42
        assert_eq!(report.stats.subspace_count, 42, "{}", report.stats.subspace_count);
        // interval switches report their true lifetimes now (not 0)
        assert!(report.stats.mean_lifetime() > 0.0);
    }

    #[test]
    fn relora_merges_are_recorded() {
        let cfg = quick_cfg();
        let mut t = SimTrainer::new(&cfg, Method::ReLoRA { merge_every: 10 }, 6);
        let report = t.train(25);
        // 14 adapters × merges at t=10 and t=20
        assert_eq!(report.stats.merges, 28, "{}", report.stats.merges);
        assert!(report.final_ppl.is_finite());
    }

    #[test]
    fn non_finite_steps_are_skipped_not_propagated() {
        // An absurd learning rate overflows the FFN product within a few
        // steps; the guard must withhold those updates instead of letting
        // NaN into the moments, and training must complete without panic.
        let mut cfg = quick_cfg();
        cfg.hyper.lr = 1e20;
        let mut t = SimTrainer::new(&cfg, Method::FullRank, 7);
        let report = t.train(12);
        assert!(report.skipped_steps > 0, "divergence should trip the guard");
    }

    #[test]
    fn clip_norm_bounds_the_full_gradient_and_counts_steps() {
        let mut cfg = quick_cfg();
        cfg.clip_norm = 1e-3; // far below any real gradient norm
        let mut t = SimTrainer::new(&cfg, Method::FullRank, 5);
        let report = t.train(10);
        assert_eq!(report.clipped_steps, 10, "every step should clip at this threshold");
        assert!(report.final_ppl.is_finite());
        // off by default: the zero threshold never rescales anything
        let cfg2 = quick_cfg();
        assert_eq!(cfg2.clip_norm, 0.0);
        let report2 = SimTrainer::new(&cfg2, Method::FullRank, 5).train(10);
        assert_eq!(report2.clipped_steps, 0);
    }

    #[test]
    fn scale_gradients_halves_the_full_norm() {
        let cfg = quick_cfg();
        let mut t = SimTrainer::new(&cfg, Method::FullRank, 9);
        let b = t.batcher.next();
        let (_, mut grads) = t.model.loss_and_grad(&b.tokens, &b.targets, b.batch, b.seq);
        let n0 = grad_full_norm(&grads);
        assert!(n0 > 0.0 && n0.is_finite());
        assert!(n0 >= grad_global_norm(&grads), "full norm includes the norm vectors");
        scale_gradients(&mut grads, 0.5);
        let n1 = grad_full_norm(&grads);
        assert!((n1 - 0.5 * n0).abs() <= 1e-6 * n0, "n0={n0} n1={n1}");
    }

    #[test]
    fn state_bytes_ordering_matches_paper() {
        let cfg = quick_cfg();
        let full = SimTrainer::new(&cfg, Method::FullRank, 4).train(8).state_bytes;
        let galore = SimTrainer::new(&cfg, Method::GaLore { interval: 50 }, 4).train(8).state_bytes;
        assert!(galore < full, "galore={galore} full={full}");
    }
}
