//! Bidirectional encoder (RoBERTa-like) with manual backprop, for the
//! GLUE-sim fine-tuning experiments (Table 2).
//!
//! token embed + learned positions → N × [RMSNorm → full MHA → residual
//! → RMSNorm → SwiGLU FFN → residual] → final RMSNorm → mean-pool →
//! classifier head. Classification uses softmax CE; regression (STS-B)
//! a sigmoid + MSE head. Backward formulas mirror `model.rs` (which is
//! finite-difference checked); the encoder adds full attention, the
//! pooling head and the positional table — each FD-checked below.

use crate::linalg::matmul::{matmul, matmul_nt, matmul_tn};
use crate::models::EncoderConfig;
use crate::tensor::{init, Matrix};
use crate::util::Rng;

const RMS_EPS: f32 = 1e-5;

#[derive(Clone, Debug)]
pub struct EncLayerParams {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ff1: Matrix, // d×f (gate)
    pub ff3: Matrix, // d×f (up)
    pub ff2: Matrix, // f×d
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct EncParams {
    pub embed: Matrix, // V×d
    pub pos: Matrix,   // T×d
    pub layers: Vec<EncLayerParams>,
    pub final_norm: Vec<f32>,
    pub head: Matrix, // d×C (C=1 for regression)
}

#[derive(Clone, Debug)]
pub struct EncGrads {
    pub embed: Matrix,
    pub pos: Matrix,
    pub layers: Vec<EncLayerGrads>,
    pub final_norm: Vec<f32>,
    pub head: Matrix,
}

#[derive(Clone, Debug)]
pub struct EncLayerGrads {
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ff1: Matrix,
    pub ff3: Matrix,
    pub ff2: Matrix,
    pub norm1: Vec<f32>,
    pub norm2: Vec<f32>,
}

impl EncGrads {
    /// True when any gradient component is NaN or ±Inf — the trigger for
    /// the fine-tune loop's skip-step guard (PR 6).
    pub fn has_non_finite(&self) -> bool {
        if self.embed.has_non_finite()
            || self.pos.has_non_finite()
            || self.head.has_non_finite()
            || self.final_norm.iter().any(|v| !v.is_finite())
        {
            return true;
        }
        self.layers.iter().any(|lg| {
            lg.wq.has_non_finite()
                || lg.wk.has_non_finite()
                || lg.wv.has_non_finite()
                || lg.wo.has_non_finite()
                || lg.ff1.has_non_finite()
                || lg.ff3.has_non_finite()
                || lg.ff2.has_non_finite()
                || lg.norm1.iter().any(|v| !v.is_finite())
                || lg.norm2.iter().any(|v| !v.is_finite())
        })
    }
}

/// Task head type.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    Classify(usize),
    Regress,
}

pub struct EncoderModel {
    pub cfg: EncoderConfig,
    pub params: EncParams,
    pub head_kind: HeadKind,
}

fn rmsnorm_fwd(x: &Matrix, g: &[f32]) -> (Matrix, Vec<f32>) {
    let d = x.cols;
    let mut y = Matrix::zeros(x.rows, d);
    let mut rms = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        let row = x.row(i);
        let ms: f64 = row.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / d as f64;
        let r = (ms + RMS_EPS as f64).sqrt() as f32;
        rms[i] = r;
        let yrow = y.row_mut(i);
        for j in 0..d {
            yrow[j] = g[j] * row[j] / r;
        }
    }
    (y, rms)
}

fn rmsnorm_bwd(x: &Matrix, g: &[f32], rms: &[f32], dy: &Matrix, dg: &mut [f32]) -> Matrix {
    let d = x.cols;
    let mut dx = Matrix::zeros(x.rows, d);
    for i in 0..x.rows {
        let r = rms[i];
        let xrow = x.row(i);
        let dyrow = dy.row(i);
        let mut s = 0.0f64;
        for j in 0..d {
            s += dyrow[j] as f64 * g[j] as f64 * xrow[j] as f64;
            dg[j] += dyrow[j] * xrow[j] / r;
        }
        let k = (s / (d as f64 * (r as f64).powi(3))) as f32;
        let dxrow = dx.row_mut(i);
        for j in 0..d {
            dxrow[j] = g[j] * dyrow[j] / r - xrow[j] * k;
        }
    }
    dx
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

struct EncLayerCache {
    x_in: Matrix,
    xn1: Matrix,
    rms1: Vec<f32>,
    q: Matrix,
    k: Matrix,
    v: Matrix,
    probs: Vec<Matrix>,
    att_concat: Matrix,
    x_mid: Matrix,
    xn2: Matrix,
    rms2: Vec<f32>,
    a: Matrix,
    b3: Matrix,
    h: Matrix,
}

struct EncCache {
    layers: Vec<EncLayerCache>,
    x_last: Matrix,
    xf: Matrix,
    rms_f: Vec<f32>,
    pooled: Matrix, // B×d
    out: Matrix,    // B×C logits (or B×1 pre-sigmoid)
}

impl EncoderModel {
    pub fn new(cfg: EncoderConfig, head_kind: HeadKind, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let n_out = match head_kind {
            HeadKind::Classify(c) => c,
            HeadKind::Regress => 1,
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for _ in 0..cfg.n_layers {
            layers.push(EncLayerParams {
                wq: init::lecun_normal(d, d, d, &mut rng),
                wk: init::lecun_normal(d, d, d, &mut rng),
                wv: init::lecun_normal(d, d, d, &mut rng),
                wo: init::residual_out(d, d, d, cfg.n_layers, &mut rng),
                ff1: init::lecun_normal(d, f, d, &mut rng),
                ff3: init::lecun_normal(d, f, d, &mut rng),
                ff2: init::residual_out(f, d, f, cfg.n_layers, &mut rng),
                norm1: vec![1.0; d],
                norm2: vec![1.0; d],
            });
        }
        let params = EncParams {
            embed: init::lecun_normal(cfg.vocab, d, d, &mut rng),
            pos: init::lecun_normal(cfg.seq_len, d, d, &mut rng),
            layers,
            final_norm: vec![1.0; d],
            head: init::lecun_normal(d, n_out, d, &mut rng),
        };
        EncoderModel { cfg, params, head_kind }
    }

    fn forward_cached(&self, tokens: &[u32], batch: usize, seq: usize) -> EncCache {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = d / heads;
        let rows = batch * seq;
        let scale = 1.0 / (hd as f32).sqrt();

        let mut x = Matrix::zeros(rows, d);
        for (i, &t) in tokens.iter().enumerate() {
            let pos = i % seq;
            let xrow = x.row_mut(i);
            let erow = self.params.embed.row(t as usize);
            let prow = self.params.pos.row(pos);
            for j in 0..d {
                xrow[j] = erow[j] + prow[j];
            }
        }

        let mut layer_caches = Vec::with_capacity(cfg.n_layers);
        for lp in &self.params.layers {
            let x_in = x.clone();
            let (xn1, rms1) = rmsnorm_fwd(&x, &lp.norm1);
            let q = matmul(&xn1, &lp.wq);
            let k = matmul(&xn1, &lp.wk);
            let v = matmul(&xn1, &lp.wv);
            let mut att_concat = Matrix::zeros(rows, d);
            let mut probs = Vec::with_capacity(batch * heads);
            for b in 0..batch {
                for h in 0..heads {
                    let mut p = Matrix::zeros(seq, seq);
                    for i in 0..seq {
                        let qrow = &q.row(b * seq + i)[h * hd..(h + 1) * hd];
                        let mut maxv = f32::NEG_INFINITY;
                        for j in 0..seq {
                            let krow = &k.row(b * seq + j)[h * hd..(h + 1) * hd];
                            let mut s = 0.0f32;
                            for t in 0..hd {
                                s += qrow[t] * krow[t];
                            }
                            let val = s * scale;
                            *p.at_mut(i, j) = val;
                            maxv = maxv.max(val);
                        }
                        let mut denom = 0.0f32;
                        for j in 0..seq {
                            let e = (p.at(i, j) - maxv).exp();
                            *p.at_mut(i, j) = e;
                            denom += e;
                        }
                        let inv = 1.0 / denom;
                        for j in 0..seq {
                            *p.at_mut(i, j) *= inv;
                        }
                    }
                    for i in 0..seq {
                        let orow = att_concat.row_mut(b * seq + i);
                        for j in 0..seq {
                            let pij = p.at(i, j);
                            let vrow = &v.row(b * seq + j)[h * hd..(h + 1) * hd];
                            for t in 0..hd {
                                orow[h * hd + t] += pij * vrow[t];
                            }
                        }
                    }
                    probs.push(p);
                }
            }
            let att_out = matmul(&att_concat, &lp.wo);
            let mut x_mid = x_in.clone();
            x_mid.axpy(1.0, &att_out);
            let (xn2, rms2) = rmsnorm_fwd(&x_mid, &lp.norm2);
            let a = matmul(&xn2, &lp.ff1);
            let b3 = matmul(&xn2, &lp.ff3);
            let mut h = Matrix::zeros(rows, cfg.d_ff);
            for idx in 0..h.data.len() {
                let av = a.data[idx];
                h.data[idx] = av * sigmoid(av) * b3.data[idx];
            }
            let f_out = matmul(&h, &lp.ff2);
            let mut x_next = x_mid.clone();
            x_next.axpy(1.0, &f_out);
            layer_caches.push(EncLayerCache {
                x_in,
                xn1,
                rms1,
                q,
                k,
                v,
                probs,
                att_concat,
                x_mid,
                xn2,
                rms2,
                a,
                b3,
                h,
            });
            x = x_next;
        }

        let x_last = x.clone();
        let (xf, rms_f) = rmsnorm_fwd(&x, &self.params.final_norm);
        // mean pool per example
        let mut pooled = Matrix::zeros(batch, d);
        for b in 0..batch {
            let prow = pooled.row_mut(b);
            for i in 0..seq {
                let xrow = xf.row(b * seq + i);
                for j in 0..d {
                    prow[j] += xrow[j];
                }
            }
            let inv = 1.0 / seq as f32;
            for v in prow.iter_mut() {
                *v *= inv;
            }
        }
        let out = matmul(&pooled, &self.params.head);
        EncCache { layers: layer_caches, x_last, xf, rms_f, pooled, out }
    }

    /// Forward loss on a batch (labels: class ids, or [0,1] targets).
    pub fn loss(&self, tokens: &[u32], labels: &[f32], batch: usize, seq: usize) -> f64 {
        let cache = self.forward_cached(tokens, batch, seq);
        self.head_loss(&cache.out, labels).0
    }

    /// Predictions: argmax class ids (classification) or sigmoid scores.
    pub fn predict(&self, tokens: &[u32], batch: usize, seq: usize) -> Vec<f32> {
        let cache = self.forward_cached(tokens, batch, seq);
        match self.head_kind {
            HeadKind::Classify(c) => (0..batch)
                .map(|b| {
                    let row = cache.out.row(b);
                    let mut best = 0usize;
                    for j in 1..c {
                        if row[j] > row[best] {
                            best = j;
                        }
                    }
                    best as f32
                })
                .collect(),
            HeadKind::Regress => (0..batch).map(|b| sigmoid(cache.out.at(b, 0))).collect(),
        }
    }

    /// Loss + dOut for the head.
    fn head_loss(&self, out: &Matrix, labels: &[f32]) -> (f64, Matrix) {
        let batch = out.rows;
        let mut dout = Matrix::zeros(out.rows, out.cols);
        let mut total = 0.0f64;
        match self.head_kind {
            HeadKind::Classify(c) => {
                for b in 0..batch {
                    let row = out.row(b);
                    let maxv = row.iter().fold(f32::NEG_INFINITY, |m, x| m.max(*x));
                    let exps: Vec<f64> = row.iter().map(|x| ((x - maxv) as f64).exp()).collect();
                    let denom: f64 = exps.iter().sum();
                    let t = labels[b] as usize;
                    debug_assert!(t < c);
                    total -= (exps[t] / denom).max(1e-30).ln();
                    let drow = dout.row_mut(b);
                    for j in 0..c {
                        let p = (exps[j] / denom) as f32;
                        drow[j] = (p - if j == t { 1.0 } else { 0.0 }) / batch as f32;
                    }
                }
            }
            HeadKind::Regress => {
                for b in 0..batch {
                    let z = out.at(b, 0);
                    let p = sigmoid(z);
                    let y = labels[b];
                    total += ((p - y) as f64).powi(2);
                    // d/dz (p−y)² = 2(p−y)p(1−p)
                    *dout.at_mut(b, 0) = 2.0 * (p - y) * p * (1.0 - p) / batch as f32;
                }
                total /= batch as f64;
                return (total, dout);
            }
        }
        (total / batch as f64, dout)
    }

    /// Full forward+backward.
    pub fn loss_and_grad(
        &self,
        tokens: &[u32],
        labels: &[f32],
        batch: usize,
        seq: usize,
    ) -> (f64, EncGrads) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let heads = cfg.n_heads;
        let hd = d / heads;
        let rows = batch * seq;
        let scale = 1.0 / (hd as f32).sqrt();
        let cache = self.forward_cached(tokens, batch, seq);
        let (loss, dout) = self.head_loss(&cache.out, labels);

        // head backward
        let d_head = matmul_tn(&cache.pooled, &dout);
        let dpooled = matmul_nt(&dout, &self.params.head);
        // un-pool
        let mut dxf = Matrix::zeros(rows, d);
        let inv = 1.0 / seq as f32;
        for b in 0..batch {
            let prow = dpooled.row(b);
            for i in 0..seq {
                let drow = dxf.row_mut(b * seq + i);
                for j in 0..d {
                    drow[j] = prow[j] * inv;
                }
            }
        }
        let mut d_final_norm = vec![0.0f32; d];
        let mut dx = rmsnorm_bwd(
            &cache.x_last,
            &self.params.final_norm,
            &cache.rms_f,
            &dxf,
            &mut d_final_norm,
        );

        let mut layer_grads: Vec<EncLayerGrads> = Vec::with_capacity(cfg.n_layers);
        for (li, lp) in self.params.layers.iter().enumerate().rev() {
            let lc = &cache.layers[li];
            let dh_out = &dx;
            let dff2 = matmul_tn(&lc.h, dh_out);
            let dh = matmul_nt(dh_out, &lp.ff2);
            let mut da = Matrix::zeros(rows, cfg.d_ff);
            let mut db3 = Matrix::zeros(rows, cfg.d_ff);
            for idx in 0..dh.data.len() {
                let av = lc.a.data[idx];
                let s = sigmoid(av);
                let silu = av * s;
                let dsilu = s * (1.0 + av * (1.0 - s));
                da.data[idx] = dh.data[idx] * lc.b3.data[idx] * dsilu;
                db3.data[idx] = dh.data[idx] * silu;
            }
            let dff1 = matmul_tn(&lc.xn2, &da);
            let dff3 = matmul_tn(&lc.xn2, &db3);
            let mut dxn2 = matmul_nt(&da, &lp.ff1);
            dxn2.axpy(1.0, &matmul_nt(&db3, &lp.ff3));
            let mut dnorm2 = vec![0.0f32; d];
            let dx_mid_ffn = rmsnorm_bwd(&lc.x_mid, &lp.norm2, &lc.rms2, &dxn2, &mut dnorm2);
            let mut dx_mid = dx.clone();
            dx_mid.axpy(1.0, &dx_mid_ffn);

            let datt_out = &dx_mid;
            let dwo = matmul_tn(&lc.att_concat, datt_out);
            let datt_concat = matmul_nt(datt_out, &lp.wo);
            let mut dq = Matrix::zeros(rows, d);
            let mut dk = Matrix::zeros(rows, d);
            let mut dv = Matrix::zeros(rows, d);
            for b in 0..batch {
                for h in 0..heads {
                    let p = &lc.probs[b * heads + h];
                    for i in 0..seq {
                        let dorow = &datt_concat.row(b * seq + i)[h * hd..(h + 1) * hd];
                        let mut dp = vec![0.0f32; seq];
                        let mut dot = 0.0f64;
                        for j in 0..seq {
                            let vrow = &lc.v.row(b * seq + j)[h * hd..(h + 1) * hd];
                            let mut acc = 0.0f32;
                            for t in 0..hd {
                                acc += dorow[t] * vrow[t];
                            }
                            dp[j] = acc;
                            dot += (acc * p.at(i, j)) as f64;
                        }
                        for j in 0..seq {
                            let pij = p.at(i, j);
                            let ds = pij * (dp[j] - dot as f32);
                            let krow = &lc.k.row(b * seq + j)[h * hd..(h + 1) * hd];
                            let qrow = &lc.q.row(b * seq + i)[h * hd..(h + 1) * hd];
                            let dqrow = dq.row_mut(b * seq + i);
                            for t in 0..hd {
                                dqrow[h * hd + t] += ds * scale * krow[t];
                            }
                            let dkrow = dk.row_mut(b * seq + j);
                            for t in 0..hd {
                                dkrow[h * hd + t] += ds * scale * qrow[t];
                            }
                            let dvrow = dv.row_mut(b * seq + j);
                            for t in 0..hd {
                                dvrow[h * hd + t] += pij * dorow[t];
                            }
                        }
                    }
                }
            }
            let dwq = matmul_tn(&lc.xn1, &dq);
            let dwk = matmul_tn(&lc.xn1, &dk);
            let dwv = matmul_tn(&lc.xn1, &dv);
            let mut dxn1 = matmul_nt(&dq, &lp.wq);
            dxn1.axpy(1.0, &matmul_nt(&dk, &lp.wk));
            dxn1.axpy(1.0, &matmul_nt(&dv, &lp.wv));
            let mut dnorm1 = vec![0.0f32; d];
            let dx_attn = rmsnorm_bwd(&lc.x_in, &lp.norm1, &lc.rms1, &dxn1, &mut dnorm1);
            let mut dx_in = dx_mid;
            dx_in.axpy(1.0, &dx_attn);
            dx = dx_in;

            layer_grads.push(EncLayerGrads {
                wq: dwq,
                wk: dwk,
                wv: dwv,
                wo: dwo,
                ff1: dff1,
                ff3: dff3,
                ff2: dff2,
                norm1: dnorm1,
                norm2: dnorm2,
            });
        }
        layer_grads.reverse();

        // embedding + positional backward
        let mut d_embed = Matrix::zeros(cfg.vocab, d);
        let mut d_pos = Matrix::zeros(cfg.seq_len, d);
        for (i, &t) in tokens.iter().enumerate() {
            let pos = i % seq;
            let drow = dx.row(i);
            let erow = d_embed.row_mut(t as usize);
            for j in 0..d {
                erow[j] += drow[j];
            }
            let prow = d_pos.row_mut(pos);
            for j in 0..d {
                prow[j] += drow[j];
            }
        }

        (
            loss,
            EncGrads {
                embed: d_embed,
                pos: d_pos,
                layers: layer_grads,
                final_norm: d_final_norm,
                head: d_head,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EncoderConfig {
        EncoderConfig {
            vocab: 12,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            d_ff: 10,
            seq_len: 4,
            n_classes: 3,
        }
    }

    #[test]
    fn classify_grad_matches_fd() {
        let cfg = tiny_cfg();
        let mut m = EncoderModel::new(cfg, HeadKind::Classify(3), 11);
        let mut rng = Rng::new(12);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(12) as u32).collect();
        let labels = vec![0.0f32, 2.0];
        let (_, g) = m.loss_and_grad(&toks, &labels, 2, 4);
        let eps = 1e-3f32;
        let analytics = [
            g.layers[0].wq.at(1, 3),
            g.head.at(2, 0),
            g.pos.at(3, 5),
            g.embed.at(4, 2),
            g.layers[0].ff2.at(0, 7),
        ];
        let read = |m: &EncoderModel, which: usize| -> f32 {
            match which {
                0 => m.params.layers[0].wq.at(1, 3),
                1 => m.params.head.at(2, 0),
                2 => m.params.pos.at(3, 5),
                3 => m.params.embed.at(4, 2),
                _ => m.params.layers[0].ff2.at(0, 7),
            }
        };
        let write = |m: &mut EncoderModel, which: usize, v: f32| match which {
            0 => *m.params.layers[0].wq.at_mut(1, 3) = v,
            1 => *m.params.head.at_mut(2, 0) = v,
            2 => *m.params.pos.at_mut(3, 5) = v,
            3 => *m.params.embed.at_mut(4, 2) = v,
            _ => *m.params.layers[0].ff2.at_mut(0, 7) = v,
        };
        for (which, &analytic) in analytics.iter().enumerate() {
            let orig = read(&m, which);
            write(&mut m, which, orig + eps);
            let lp = m.loss(&toks, &labels, 2, 4);
            write(&mut m, which, orig - eps);
            let lm = m.loss(&toks, &labels, 2, 4);
            write(&mut m, which, orig);
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let rel = (numeric - analytic).abs() / numeric.abs().max(analytic.abs()).max(1e-4);
            assert!(rel < 0.06, "case {which}: analytic={analytic} numeric={numeric}");
        }
    }

    #[test]
    fn regress_grad_matches_fd() {
        let cfg = tiny_cfg();
        let mut m = EncoderModel::new(cfg, HeadKind::Regress, 13);
        let mut rng = Rng::new(14);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(12) as u32).collect();
        let labels = vec![0.3f32, 0.8];
        let (_, g) = m.loss_and_grad(&toks, &labels, 2, 4);
        let eps = 1e-3f32;
        let analytic = g.head.at(5, 0);
        let orig = m.params.head.at(5, 0);
        *m.params.head.at_mut(5, 0) = orig + eps;
        let lp = m.loss(&toks, &labels, 2, 4);
        *m.params.head.at_mut(5, 0) = orig - eps;
        let lm = m.loss(&toks, &labels, 2, 4);
        *m.params.head.at_mut(5, 0) = orig;
        let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
        let rel = (numeric - analytic).abs() / numeric.abs().max(analytic.abs()).max(1e-4);
        assert!(rel < 0.05, "analytic={analytic} numeric={numeric}");
    }

    #[test]
    fn overfits_tiny_task() {
        use crate::optim::{Adam, Hyper, Optimizer};
        let cfg = tiny_cfg();
        let mut m = EncoderModel::new(cfg, HeadKind::Classify(3), 15);
        let mut rng = Rng::new(16);
        let toks: Vec<u32> = (0..4 * 4).map(|_| rng.below(12) as u32).collect();
        let labels = vec![0.0f32, 1.0, 2.0, 1.0];
        let l0 = m.loss(&toks, &labels, 4, 4);
        let hyper = Hyper { lr: 5e-3, ..Default::default() };
        // full Adam on every tensor (simplest path)
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut opts: Vec<Adam> = vec![
            Adam::new(d, d),
            Adam::new(d, d),
            Adam::new(d, d),
            Adam::new(d, d),
            Adam::new(d, f),
            Adam::new(d, f),
            Adam::new(f, d),
        ];
        let mut e_opt = Adam::new(cfg.vocab, d);
        let mut p_opt = Adam::new(cfg.seq_len, d);
        let mut h_opt = Adam::new(d, 3);
        for t in 1..=120 {
            let (_, g) = m.loss_and_grad(&toks, &labels, 4, 4);
            let lp = &mut m.params.layers[0];
            let lg = &g.layers[0];
            for (oi, (w, gw)) in [
                (&mut lp.wq, &lg.wq),
                (&mut lp.wk, &lg.wk),
                (&mut lp.wv, &lg.wv),
                (&mut lp.wo, &lg.wo),
                (&mut lp.ff1, &lg.ff1),
                (&mut lp.ff3, &lg.ff3),
                (&mut lp.ff2, &lg.ff2),
            ]
            .into_iter()
            .enumerate()
            {
                opts[oi].step(w, gw, &hyper, t);
            }
            e_opt.step(&mut m.params.embed, &g.embed, &hyper, t);
            p_opt.step(&mut m.params.pos, &g.pos, &hyper, t);
            h_opt.step(&mut m.params.head, &g.head, &hyper, t);
        }
        let l1 = m.loss(&toks, &labels, 4, 4);
        assert!(l1 < l0 * 0.5, "l0={l0} l1={l1}");
        // and predictions match
        let preds = m.predict(&toks, 4, 4);
        let correct = preds
            .iter()
            .zip(&labels)
            .filter(|(p, l)| (**p - **l).abs() < 0.5)
            .count();
        assert!(correct >= 3, "preds={preds:?}");
    }
}
