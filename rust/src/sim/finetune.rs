//! Fine-tuning loop over the GLUE-sim suite (Table 2 engine).
//!
//! For each task: build an [`EncoderModel`] (fresh "pre-trained" seed —
//! the same initial weights for every method, so comparisons are
//! apples-to-apples), attach per-matrix optimizers per the method spec,
//! train for a fixed number of epochs, evaluate the task's paper metric
//! on the dev split.

use super::encoder::{EncoderModel, HeadKind};
use super::trainer::Method;
use crate::data::glue::{GlueTask, TaskKind};
use crate::eval;
use crate::models::EncoderConfig;
use crate::optim::registry::{self, TrainPhase};
use crate::optim::{Adam, Hyper, Optimizer, StepEvent};
use crate::subspace::SubspaceStats;
use crate::telemetry::{span, SpanKind};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Result of fine-tuning one task.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub task: &'static str,
    pub method: &'static str,
    /// The task's paper metric, scaled ×100 (as Table 2 reports).
    pub metric: f64,
    pub final_loss: f64,
    pub stats: SubspaceStats,
    pub state_bytes: u64,
    pub wall_s: f64,
    /// Smallest post-switch projection rank seen across all matrices
    /// (None when no subspace switch fired) — the observable for
    /// AdaRankGrad's decay schedule, which fine-tune used to drop.
    pub min_rank: Option<usize>,
    /// Batches whose update was withheld by the numerical guard
    /// (non-finite loss or gradient) — PR 6 skip-step semantics.
    pub skipped_steps: u64,
}

/// Fine-tune one task; returns the paper metric (×100).
pub fn finetune_task(
    enc_cfg: &EncoderConfig,
    task: &GlueTask,
    method: Method,
    rank: usize,
    epochs: usize,
    batch: usize,
    hyper: &Hyper,
    seed: u64,
) -> FinetuneReport {
    let t0 = std::time::Instant::now();
    let head = match task.kind {
        TaskKind::Pearson => HeadKind::Regress,
        _ => HeadKind::Classify(task.n_classes),
    };
    let mut cfg = *enc_cfg;
    cfg.n_classes = task.n_classes;
    cfg.seq_len = task.seq_len;
    cfg.vocab = task.vocab;
    // identical init across methods: seed depends only on the task
    let mut model = EncoderModel::new(cfg, head, 7777 ^ task.name.len() as u64);

    let d = cfg.d_model;
    let f = cfg.d_ff;
    let mut rng = Rng::new(seed);
    // one registry, one construction path — the same optimizers (and the
    // same AdaRankGrad decay schedule) the pre-training sim builds
    let mut opts: Vec<Box<dyn Optimizer>> = Vec::new();
    for li in 0..cfg.n_layers {
        for (rows, cols) in [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)] {
            let s = seed ^ ((li as u64) << 8) ^ opts.len() as u64;
            opts.push(registry::build(
                method,
                rank,
                rows,
                cols,
                s,
                &mut rng,
                TrainPhase::FineTune,
            ));
        }
    }
    // embeddings/positions/head/norms always plain Adam (tiny, and GaLore
    // fine-tuning also leaves them full-rank)
    let mut emb_opt = Adam::new(cfg.vocab, d);
    let mut pos_opt = Adam::new(cfg.seq_len, d);
    let n_out = match head {
        HeadKind::Classify(c) => c,
        HeadKind::Regress => 1,
    };
    let mut head_opt = Adam::new(d, n_out);
    let mut norm_opts: Vec<Adam> = (0..(2 * cfg.n_layers + 1)).map(|_| Adam::new(1, d)).collect();

    let mut stats = SubspaceStats::default();
    let mut min_rank: Option<usize> = None;
    let mut order: Vec<usize> = (0..task.train.len()).collect();
    let mut t = 0u64;
    let mut final_loss = 0.0f64;
    let mut skipped_steps = 0u64;
    for _epoch in 0..epochs {
        rng.shuffle(&mut order);
        for chunk in order.chunks(batch) {
            if chunk.len() < batch {
                continue; // drop ragged tail for fixed shapes
            }
            t += 1;
            let _step_sp = span(SpanKind::Step);
            let mut tokens = Vec::with_capacity(batch * task.seq_len);
            let mut labels = Vec::with_capacity(batch);
            for &i in chunk {
                tokens.extend_from_slice(&task.train[i].tokens);
                labels.push(task.train[i].label);
            }
            let (loss, grads) = {
                let _sp = span(SpanKind::Grad);
                model.loss_and_grad(&tokens, &labels, batch, task.seq_len)
            };
            if !loss.is_finite() || grads.has_non_finite() {
                // numerical guard: a poisoned batch must not contaminate
                // weights or moments — withhold the whole update
                skipped_steps += 1;
                crate::log_info!("finetune step {t}: non-finite loss/gradient — update skipped");
                continue;
            }
            final_loss = loss;
            let _update_sp = span(SpanKind::Update);
            let mut oi = 0;
            for (li, lg) in grads.layers.iter().enumerate() {
                let lp = &mut model.params.layers[li];
                for (w, g) in [
                    (&mut lp.wq, &lg.wq),
                    (&mut lp.wk, &lg.wk),
                    (&mut lp.wv, &lg.wv),
                    (&mut lp.wo, &lg.wo),
                    (&mut lp.ff1, &lg.ff1),
                    (&mut lp.ff3, &lg.ff3),
                    (&mut lp.ff2, &lg.ff2),
                ] {
                    stats.record_observation();
                    match opts[oi].step(w, g, hyper, t) {
                        StepEvent::Switched { reason, lifetime, rank } => {
                            // true post-switch rank + lifetime (switches
                            // used to be recorded at 0)
                            stats.record_switch(reason, lifetime);
                            min_rank = Some(min_rank.map_or(rank, |r| r.min(rank)));
                        }
                        StepEvent::Merged { .. } => stats.record_merge(),
                        StepEvent::None | StepEvent::SkippedNonFinite => {}
                    }
                    oi += 1;
                }
                let mut n1 = Matrix::from_vec(1, lp.norm1.len(), lp.norm1.clone());
                let g1 = Matrix::from_vec(1, lg.norm1.len(), lg.norm1.clone());
                norm_opts[2 * li].step(&mut n1, &g1, hyper, t);
                lp.norm1.copy_from_slice(&n1.data);
                let mut n2 = Matrix::from_vec(1, lp.norm2.len(), lp.norm2.clone());
                let g2 = Matrix::from_vec(1, lg.norm2.len(), lg.norm2.clone());
                norm_opts[2 * li + 1].step(&mut n2, &g2, hyper, t);
                lp.norm2.copy_from_slice(&n2.data);
            }
            let mut fnorm =
                Matrix::from_vec(1, model.params.final_norm.len(), model.params.final_norm.clone());
            let gf = Matrix::from_vec(1, grads.final_norm.len(), grads.final_norm.clone());
            let last = norm_opts.len() - 1;
            norm_opts[last].step(&mut fnorm, &gf, hyper, t);
            model.params.final_norm.copy_from_slice(&fnorm.data);
            emb_opt.step(&mut model.params.embed, &grads.embed, hyper, t);
            pos_opt.step(&mut model.params.pos, &grads.pos, hyper, t);
            head_opt.step(&mut model.params.head, &grads.head, hyper, t);
        }
    }

    // dev evaluation with the task's paper metric
    let metric = evaluate(&model, task);
    let state_bytes = opts.iter().map(|o| o.state_bytes() as u64).sum::<u64>()
        + emb_opt.state_bytes() as u64
        + pos_opt.state_bytes() as u64
        + head_opt.state_bytes() as u64;

    FinetuneReport {
        task: task.name,
        method: method.name(),
        metric: metric * 100.0,
        final_loss,
        stats,
        state_bytes,
        wall_s: t0.elapsed().as_secs_f64(),
        min_rank,
        skipped_steps,
    }
}

/// Evaluate the task's paper metric on the dev split (unscaled, 0..1).
pub fn evaluate(model: &EncoderModel, task: &GlueTask) -> f64 {
    let batch = 16usize;
    let mut preds_f = Vec::with_capacity(task.dev.len());
    let mut labels_f = Vec::with_capacity(task.dev.len());
    for chunk in task.dev.chunks(batch) {
        let mut tokens = Vec::with_capacity(chunk.len() * task.seq_len);
        for ex in chunk {
            tokens.extend_from_slice(&ex.tokens);
        }
        let p = model.predict(&tokens, chunk.len(), task.seq_len);
        preds_f.extend_from_slice(&p);
        labels_f.extend(chunk.iter().map(|e| e.label));
    }
    match task.kind {
        TaskKind::Pearson => {
            let x: Vec<f64> = preds_f.iter().map(|&v| v as f64).collect();
            let y: Vec<f64> = labels_f.iter().map(|&v| v as f64).collect();
            eval::pearson(&x, &y)
        }
        TaskKind::Matthews => {
            let p: Vec<usize> = preds_f.iter().map(|&v| v as usize).collect();
            let l: Vec<usize> = labels_f.iter().map(|&v| v as usize).collect();
            eval::matthews(&p, &l)
        }
        TaskKind::F1 => {
            let p: Vec<usize> = preds_f.iter().map(|&v| v as usize).collect();
            let l: Vec<usize> = labels_f.iter().map(|&v| v as usize).collect();
            eval::f1(&p, &l)
        }
        TaskKind::Accuracy => {
            let p: Vec<usize> = preds_f.iter().map(|&v| v as usize).collect();
            let l: Vec<usize> = labels_f.iter().map(|&v| v as usize).collect();
            eval::accuracy(&p, &l)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::glue::generate_suite;

    fn small_enc() -> EncoderConfig {
        EncoderConfig {
            vocab: 256,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 48,
            seq_len: 16,
            n_classes: 2,
        }
    }

    #[test]
    fn finetune_beats_chance_on_sst2() {
        let cfg = small_enc();
        let suite = generate_suite(cfg.vocab, cfg.seq_len, 50);
        let sst = suite.iter().find(|t| t.name == "SST2").unwrap();
        let hyper = Hyper { lr: 2e-3, galore_scale: 1.0, ..Default::default() };
        let r = finetune_task(&cfg, sst, Method::FullRank, 8, 2, 8, &hyper, 1);
        assert!(r.metric > 60.0, "metric={} (chance=50)", r.metric);
    }

    #[test]
    fn lotus_finetune_runs_and_switches() {
        let cfg = small_enc();
        let suite = generate_suite(cfg.vocab, cfg.seq_len, 51);
        let rte = suite.iter().find(|t| t.name == "RTE").unwrap();
        let hyper = Hyper { lr: 2e-3, galore_scale: 2.0, ..Default::default() };
        let r = finetune_task(
            &cfg,
            rte,
            Method::Lotus { gamma: 0.05, eta: 5, t_min: 5 },
            4,
            2,
            8,
            &hyper,
            2,
        );
        assert!(r.stats.subspace_count >= 7, "subspaces={}", r.stats.subspace_count);
        assert!(r.metric.is_finite());
    }

    #[test]
    fn adarankgrad_rank_decays_in_finetune() {
        // Regression: fine-tune used to build AdaRankGrad as a plain
        // fixed-rank rSVD optimizer, silently dropping the decay
        // schedule. Through the registry the rank must now shrink as
        // switches fire — and switch stats must carry true lifetimes
        // (they were recorded as 0 before).
        let cfg = small_enc();
        let suite = generate_suite(cfg.vocab, cfg.seq_len, 53);
        let rte = suite.iter().find(|t| t.name == "RTE").unwrap();
        let hyper = Hyper { lr: 2e-3, galore_scale: 1.0, ..Default::default() };
        let r = finetune_task(
            &cfg,
            rte,
            Method::AdaRankGrad { interval: 5, decay: 0.5 },
            8,
            2,
            8,
            &hyper,
            4,
        );
        let min_rank = r.min_rank.expect("AdaRankGrad must switch subspaces");
        assert!(min_rank < 8, "rank never decayed: min_rank={min_rank}");
        assert!(min_rank >= 2, "decay floor violated: min_rank={min_rank}");
        assert!(
            r.stats.mean_lifetime() > 0.0,
            "interval switches must report true lifetimes: {:?}",
            r.stats
        );
        assert!(r.metric.is_finite());
    }

    #[test]
    fn non_finite_finetune_steps_are_skipped() {
        // An absurd learning rate overflows the FFN products within a few
        // batches; the guard must count skips instead of propagating NaN
        // into the optimizer moments.
        let cfg = small_enc();
        let suite = generate_suite(cfg.vocab, cfg.seq_len, 54);
        let sst = suite.iter().find(|t| t.name == "SST2").unwrap();
        let hyper = Hyper { lr: 1e20, galore_scale: 1.0, ..Default::default() };
        let r = finetune_task(&cfg, sst, Method::FullRank, 8, 1, 8, &hyper, 5);
        assert!(r.skipped_steps > 0, "guard never fired: {r:?}");
        assert!(r.final_loss.is_finite(), "reported loss must stay finite");
    }

    #[test]
    fn regression_task_produces_pearson() {
        let cfg = small_enc();
        let suite = generate_suite(cfg.vocab, cfg.seq_len, 52);
        let sts = suite.iter().find(|t| t.name == "STS-B").unwrap();
        let hyper = Hyper { lr: 2e-3, ..Default::default() };
        let r = finetune_task(&cfg, sts, Method::FullRank, 4, 2, 8, &hyper, 3);
        assert!(r.metric > 20.0, "pearson×100={}", r.metric);
    }
}
