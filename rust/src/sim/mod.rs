//! Rust-native training simulator.
//!
//! A complete decoder transformer (and a bidirectional encoder variant)
//! with **hand-written backprop** over the [`crate::linalg`] substrate.
//! This path needs no Python and no artifacts; it is what the paper-table
//! benches sweep (7 methods × 4 model sizes would be prohibitively slow
//! through interpret-mode PJRT) and the cross-check oracle for the PJRT
//! path (`rust/tests/runtime_pjrt.rs` verifies both paths produce the
//! same losses/gradients on the same weights).
//!
//! Gradient correctness is enforced by finite-difference checks in
//! `model::tests` — every backward formula here is validated numerically.

pub mod model;
pub mod encoder;
pub mod trainer;
pub mod finetune;

pub use model::{Gradients, KvCache, SimModel};
pub use trainer::{SimTrainer, TrainReport};
pub use finetune::{finetune_task, FinetuneReport};
