//! Typed run configuration: parse/validate/print. The CLI launcher and
//! the PJRT trainer both consume [`RunConfig`].

use super::toml::{parse_toml, TomlValue};
use crate::dist::DistCfg;
use crate::faults::FaultPlan;
use crate::models::LlamaConfig;
use crate::optim::Hyper;
use crate::quant::QuantCfg;
use crate::sim::trainer::Method;
use std::collections::BTreeMap;

/// Method + its hyper-parameters, as configured.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MethodCfg {
    pub method: Method,
    pub rank: usize,
}

/// A complete training-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub name: String,
    pub model: LlamaConfig,
    pub method: MethodCfg,
    pub batch: usize,
    pub steps: u64,
    pub eval_every: u64,
    pub hyper: Hyper,
    pub seed: u64,
    /// Synthetic-corpus coherence (0..1).
    pub coherence: f64,
    /// Output directory for metrics/checkpoints.
    pub out_dir: String,
    /// Checkpoint interval in steps (0 = disabled).
    pub ckpt_every: u64,
    /// Artifact directory for the PJRT path.
    pub artifacts: String,
    /// Data-parallel run shape (`[dist] workers = N`); workers = 1 and
    /// shards = 0 means single-process training.
    pub dist: DistCfg,
    /// Fault injection + numerical guards (`[faults]`, PR 6).
    pub faults: FaultsCfg,
    /// Observability sinks (`[telemetry]`): Chrome trace + JSONL
    /// metrics output paths. Empty = disabled.
    pub telemetry: TelemetryCfg,
    /// Quantization surfaces (`[quant]`, PR 8): dist wire dtype, KV
    /// cache dtype, optimizer-moment dtype, int8 scale-block length.
    /// All-f32 default keeps every legacy path bit-exact.
    pub quant: QuantCfg,
}

/// `[telemetry]` block: where to write the Chrome `trace_event` file
/// and the structured JSONL metrics stream. Empty paths disable the
/// respective sink (the default) — the instrumented hot paths then
/// cost one atomic load per site.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryCfg {
    /// Chrome trace output path (`--trace-out`). Empty = off.
    pub trace_out: String,
    /// JSONL metrics output path (`--metrics-out`). Empty = off.
    pub metrics_out: String,
    /// Prometheus-text snapshot path (`--prom-out`), rewritten
    /// atomically on every flush for `lotus top` / scrapers. Empty = off.
    pub prom_out: String,
    /// Trace buffering (`--trace-mode`): "" or "full" keeps every event;
    /// "ring" keeps only the newest `trace_cap` complete events.
    pub trace_mode: String,
    /// Ring capacity in events for `trace_mode = "ring"` (0 = the 4096
    /// default).
    pub trace_cap: u64,
    /// Subspace-quality probe cadence in steps (`--probe-every`):
    /// 0 = probes off (the default; disabled probes cost one relaxed
    /// atomic load per step), k = sample every k-th step.
    pub probe_every: u64,
}

/// `[faults]` block: a seeded fault-injection schedule and the
/// numerical-guard knobs ([`crate::faults::GuardCfg`]). An empty `plan`
/// means no injector is armed; the guards are always active in the dist
/// trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsCfg {
    /// Fault schedule, e.g. `"flip@3#0,drop@5,kill1@8,nan@10,spike@12"`
    /// (see [`FaultPlan::parse`]). Empty = no injection.
    pub plan: String,
    /// Seed of the injector's private RNG stream (bit-flip positions).
    pub seed: u64,
    /// Loss-spike detector window (steps of history).
    pub spike_window: usize,
    /// Spike threshold: loss > factor × windowed mean ⇒ spike.
    pub spike_factor: f64,
    /// Max automatic rollbacks before degrading to log-and-continue.
    pub max_rollbacks: u32,
    /// Global gradient-norm clip threshold (`--clip-norm`), applied
    /// after the non-finite guard and upstream of the loss-spike
    /// detector. 0.0 = off (the bit-exact default).
    pub clip_norm: f64,
}

impl Default for FaultsCfg {
    fn default() -> Self {
        FaultsCfg {
            plan: String::new(),
            seed: 0xFA017,
            spike_window: 8,
            spike_factor: 2.5,
            max_rollbacks: 4,
            clip_norm: 0.0,
        }
    }
}

impl FaultsCfg {
    /// Parse the schedule into a [`FaultPlan`] (None when empty). The
    /// typed [`crate::faults::PlanError`] is rendered to a string here —
    /// config validation reports messages, the injector layer keeps the
    /// typed value.
    pub fn plan(&self) -> Result<Option<FaultPlan>, String> {
        if self.plan.trim().is_empty() {
            return Ok(None);
        }
        FaultPlan::parse(&self.plan, self.seed).map(Some).map_err(|e| e.to_string())
    }

    /// The guard knobs as the trainer's [`crate::faults::GuardCfg`].
    pub fn guard(&self) -> crate::faults::GuardCfg {
        crate::faults::GuardCfg {
            spike_window: self.spike_window,
            spike_factor: self.spike_factor,
            max_rollbacks: self.max_rollbacks,
            clip_norm: self.clip_norm,
        }
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "run".into(),
            model: crate::models::presets::llama_tiny_cfg(),
            method: MethodCfg { method: Method::lotus_default(), rank: 16 },
            batch: 8,
            steps: 200,
            eval_every: 50,
            hyper: Hyper { lr: 3e-3, galore_scale: 1.0, ..Default::default() },
            seed: 42,
            coherence: 0.75,
            out_dir: "runs".into(),
            ckpt_every: 0,
            artifacts: "artifacts".into(),
            dist: DistCfg::default(),
            faults: FaultsCfg::default(),
            telemetry: TelemetryCfg::default(),
            quant: QuantCfg::default(),
        }
    }
}

fn get_u(t: &BTreeMap<String, TomlValue>, k: &str, d: u64) -> Result<u64, String> {
    match t.get(k) {
        None => Ok(d),
        Some(v) => v.as_i64().map(|x| x as u64).ok_or_else(|| format!("{k}: expected integer")),
    }
}

fn get_us(t: &BTreeMap<String, TomlValue>, k: &str, d: usize) -> Result<usize, String> {
    get_u(t, k, d as u64).map(|x| x as usize)
}

fn get_f(t: &BTreeMap<String, TomlValue>, k: &str, d: f64) -> Result<f64, String> {
    match t.get(k) {
        None => Ok(d),
        Some(v) => v.as_f64().ok_or_else(|| format!("{k}: expected number")),
    }
}

fn get_s(t: &BTreeMap<String, TomlValue>, k: &str, d: &str) -> Result<String, String> {
    match t.get(k) {
        None => Ok(d.to_string()),
        Some(v) => v.as_str().map(|s| s.to_string()).ok_or_else(|| format!("{k}: expected string")),
    }
}

impl RunConfig {
    /// Parse from TOML text. Layout:
    ///
    /// ```toml
    /// name = "my-run"
    /// steps = 500
    /// batch = 8
    /// seed = 42
    /// lr = 0.003
    ///
    /// [model]            # or: preset = "llama-tiny" | "llama-mini" | ...
    /// vocab = 2048
    /// d_model = 256
    /// n_layers = 4
    /// n_heads = 8
    /// d_ff = 688
    /// seq_len = 128
    ///
    /// [method]
    /// name = "lotus"     # full|galore|lowrank|lora|relora|adarankgrad|apollo|lotus|rsvd-fixed
    /// rank = 16
    /// gamma = 0.01
    /// eta = 50
    /// t_min = 50
    /// interval = 200
    /// ```
    pub fn from_toml(text: &str) -> Result<RunConfig, String> {
        let doc = parse_toml(text)?;
        let root = doc.get("").cloned().unwrap_or_default();
        let mut cfg = RunConfig::default();
        cfg.name = get_s(&root, "name", &cfg.name)?;
        cfg.steps = get_u(&root, "steps", cfg.steps)?;
        cfg.batch = get_us(&root, "batch", cfg.batch)?;
        cfg.eval_every = get_u(&root, "eval_every", cfg.eval_every)?;
        cfg.seed = get_u(&root, "seed", cfg.seed)?;
        cfg.coherence = get_f(&root, "coherence", cfg.coherence)?;
        cfg.out_dir = get_s(&root, "out_dir", &cfg.out_dir)?;
        cfg.ckpt_every = get_u(&root, "ckpt_every", cfg.ckpt_every)?;
        cfg.artifacts = get_s(&root, "artifacts", &cfg.artifacts)?;
        cfg.hyper.lr = get_f(&root, "lr", cfg.hyper.lr as f64)? as f32;
        cfg.hyper.weight_decay = get_f(&root, "weight_decay", 0.0)? as f32;
        cfg.hyper.galore_scale = get_f(&root, "scale", cfg.hyper.galore_scale as f64)? as f32;

        if let Some(model) = doc.get("model") {
            if let Some(p) = model.get("preset") {
                let name = p.as_str().ok_or("model.preset: expected string")?;
                cfg.model = preset_model(name)?;
            } else {
                cfg.model = LlamaConfig {
                    vocab: get_us(model, "vocab", cfg.model.vocab)?,
                    d_model: get_us(model, "d_model", cfg.model.d_model)?,
                    n_layers: get_us(model, "n_layers", cfg.model.n_layers)?,
                    n_heads: get_us(model, "n_heads", cfg.model.n_heads)?,
                    d_ff: get_us(model, "d_ff", cfg.model.d_ff)?,
                    seq_len: get_us(model, "seq_len", cfg.model.seq_len)?,
                };
            }
        }

        if let Some(d) = doc.get("dist") {
            cfg.dist.workers = get_us(d, "workers", cfg.dist.workers)?;
            cfg.dist.shards = get_us(d, "shards", cfg.dist.shards)?;
            cfg.dist.quorum = get_f(d, "quorum", cfg.dist.quorum)?;
        }

        if let Some(f) = doc.get("faults") {
            cfg.faults.plan = get_s(f, "plan", &cfg.faults.plan)?;
            cfg.faults.seed = get_u(f, "seed", cfg.faults.seed)?;
            cfg.faults.spike_window = get_us(f, "spike_window", cfg.faults.spike_window)?;
            cfg.faults.spike_factor = get_f(f, "spike_factor", cfg.faults.spike_factor)?;
            cfg.faults.max_rollbacks =
                get_u(f, "max_rollbacks", cfg.faults.max_rollbacks as u64)? as u32;
            cfg.faults.clip_norm = get_f(f, "clip_norm", cfg.faults.clip_norm)?;
        }

        if let Some(t) = doc.get("telemetry") {
            cfg.telemetry.trace_out = get_s(t, "trace_out", &cfg.telemetry.trace_out)?;
            cfg.telemetry.metrics_out = get_s(t, "metrics_out", &cfg.telemetry.metrics_out)?;
            cfg.telemetry.prom_out = get_s(t, "prom_out", &cfg.telemetry.prom_out)?;
            cfg.telemetry.trace_mode = get_s(t, "trace_mode", &cfg.telemetry.trace_mode)?;
            cfg.telemetry.trace_cap = get_u(t, "trace_cap", cfg.telemetry.trace_cap)?;
            cfg.telemetry.probe_every = get_u(t, "probe_every", cfg.telemetry.probe_every)?;
        }

        if let Some(q) = doc.get("quant") {
            use crate::quant::QuantDtype;
            let wire = get_s(q, "wire", cfg.quant.wire.as_str())?;
            cfg.quant.wire =
                wire.parse::<QuantDtype>().map_err(|e| format!("quant.wire: {e}"))?;
            let kv = get_s(q, "kv", cfg.quant.kv.as_str())?;
            cfg.quant.kv = kv.parse::<QuantDtype>().map_err(|e| format!("quant.kv: {e}"))?;
            let state = get_s(q, "state", cfg.quant.state.as_str())?;
            cfg.quant.state =
                state.parse::<QuantDtype>().map_err(|e| format!("quant.state: {e}"))?;
            cfg.quant.int8_block = get_us(q, "int8_block", cfg.quant.int8_block)?;
        }

        if let Some(m) = doc.get("method") {
            let rank = get_us(m, "rank", cfg.method.rank)?;
            let name = get_s(m, "name", "lotus")?;
            let interval = get_u(m, "interval", 200)?;
            let gamma = get_f(m, "gamma", 0.01)?;
            let eta = get_u(m, "eta", 50)?;
            let t_min = get_u(m, "t_min", 50)?;
            let method = match name.as_str() {
                "full" | "full-rank" => Method::FullRank,
                "galore" => Method::GaLore { interval },
                "lowrank" | "low-rank" => Method::LowRank,
                "lora" => Method::LoRA,
                "relora" => Method::ReLoRA { merge_every: get_u(m, "merge_every", interval)? },
                "adarankgrad" => {
                    Method::AdaRankGrad { interval, decay: get_f(m, "decay", 0.85)? }
                }
                "apollo" => Method::Apollo { refresh_every: get_u(m, "refresh_every", interval)? },
                "lotus" => Method::Lotus { gamma, eta, t_min },
                "rsvd-fixed" => Method::RsvdFixed { interval },
                other => return Err(format!("unknown method '{other}'")),
            };
            cfg.method = MethodCfg { method, rank };
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity constraints.
    pub fn validate(&self) -> Result<(), String> {
        if self.model.d_model % self.model.n_heads != 0 {
            return Err(format!(
                "d_model {} not divisible by n_heads {}",
                self.model.d_model, self.model.n_heads
            ));
        }
        if self.method.rank == 0 || self.method.rank > self.model.d_model {
            return Err(format!(
                "rank {} out of range (1..={})",
                self.method.rank, self.model.d_model
            ));
        }
        if self.batch == 0 || self.steps == 0 {
            return Err("batch and steps must be positive".into());
        }
        if self.eval_every == 0 {
            return Err("eval_every must be positive (trainers eval on step % eval_every)".into());
        }
        if let Method::Lotus { gamma, eta, .. } = self.method.method {
            if !(0.0..1.0).contains(&gamma) {
                return Err(format!("gamma {gamma} outside (0,1)"));
            }
            if eta == 0 {
                return Err("eta must be positive".into());
            }
        }
        self.dist.validate(self.batch)?;
        self.quant.validate()?;
        self.faults.plan().map_err(|e| format!("faults.plan: {e}"))?;
        if self.faults.spike_window == 0 {
            return Err("faults.spike_window must be positive".into());
        }
        if !self.faults.spike_factor.is_finite() || self.faults.spike_factor <= 1.0 {
            return Err("faults.spike_factor must exceed 1".into());
        }
        if !self.faults.clip_norm.is_finite() || self.faults.clip_norm < 0.0 {
            return Err("faults.clip_norm must be finite and >= 0 (0 disables clipping)".into());
        }
        match self.telemetry.trace_mode.as_str() {
            "" | "full" | "ring" => {}
            other => {
                return Err(format!(
                    "telemetry.trace_mode '{other}' unknown (expected \"full\" or \"ring\")"
                ))
            }
        }
        Ok(())
    }

    /// Render back to TOML (for `lotus inspect` and run provenance).
    pub fn to_toml(&self) -> String {
        let m = &self.model;
        let method_block = match self.method.method {
            Method::FullRank => "name = \"full\"".to_string(),
            Method::GaLore { interval } => format!("name = \"galore\"\ninterval = {interval}"),
            Method::LowRank => "name = \"lowrank\"".to_string(),
            Method::LoRA => "name = \"lora\"".to_string(),
            Method::ReLoRA { merge_every } => {
                format!("name = \"relora\"\nmerge_every = {merge_every}")
            }
            Method::AdaRankGrad { interval, decay } => {
                format!("name = \"adarankgrad\"\ninterval = {interval}\ndecay = {decay}")
            }
            Method::Apollo { refresh_every } => {
                format!("name = \"apollo\"\nrefresh_every = {refresh_every}")
            }
            Method::Lotus { gamma, eta, t_min } => {
                format!("name = \"lotus\"\ngamma = {gamma}\neta = {eta}\nt_min = {t_min}")
            }
            Method::RsvdFixed { interval } => {
                format!("name = \"rsvd-fixed\"\ninterval = {interval}")
            }
        };
        format!(
            "name = \"{}\"\nsteps = {}\nbatch = {}\neval_every = {}\nseed = {}\nlr = {}\nscale = {}\ncoherence = {}\nout_dir = \"{}\"\nckpt_every = {}\nartifacts = \"{}\"\n\n[model]\nvocab = {}\nd_model = {}\nn_layers = {}\nn_heads = {}\nd_ff = {}\nseq_len = {}\n\n[method]\n{}\nrank = {}\n\n[dist]\nworkers = {}\nshards = {}\nquorum = {}\n\n[quant]\nwire = \"{}\"\nkv = \"{}\"\nstate = \"{}\"\nint8_block = {}\n\n[faults]\nplan = \"{}\"\nseed = {}\nspike_window = {}\nspike_factor = {}\nmax_rollbacks = {}\nclip_norm = {}\n\n[telemetry]\ntrace_out = \"{}\"\nmetrics_out = \"{}\"\nprom_out = \"{}\"\ntrace_mode = \"{}\"\ntrace_cap = {}\nprobe_every = {}\n",
            self.name,
            self.steps,
            self.batch,
            self.eval_every,
            self.seed,
            self.hyper.lr,
            self.hyper.galore_scale,
            self.coherence,
            self.out_dir,
            self.ckpt_every,
            self.artifacts,
            m.vocab,
            m.d_model,
            m.n_layers,
            m.n_heads,
            m.d_ff,
            m.seq_len,
            method_block,
            self.method.rank,
            self.dist.workers,
            self.dist.shards,
            self.dist.quorum,
            self.quant.wire.as_str(),
            self.quant.kv.as_str(),
            self.quant.state.as_str(),
            self.quant.int8_block,
            self.faults.plan,
            self.faults.seed,
            self.faults.spike_window,
            self.faults.spike_factor,
            self.faults.max_rollbacks,
            self.faults.clip_norm,
            self.telemetry.trace_out,
            self.telemetry.metrics_out,
            self.telemetry.prom_out,
            self.telemetry.trace_mode,
            self.telemetry.trace_cap,
            self.telemetry.probe_every,
        )
    }
}

/// Resolve a named model preset.
pub fn preset_model(name: &str) -> Result<LlamaConfig, String> {
    use crate::models::presets::*;
    Ok(match name {
        "llama-tiny" => llama_tiny_cfg(),
        "llama-mini" => llama_mini_cfg(),
        "llama-20m" => llama_20m_cfg(),
        "llama-100m" => llama_100m_cfg(),
        other => return Err(format!("unknown model preset '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn roundtrip_through_toml() {
        let cfg = RunConfig::default();
        let text = cfg.to_toml();
        let back = RunConfig::from_toml(&text).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.steps, cfg.steps);
        assert_eq!(back.method, cfg.method);
        assert_eq!(back.model.d_model, cfg.model.d_model);
        assert_eq!(back.hyper.lr, cfg.hyper.lr);
    }

    #[test]
    fn parses_preset_and_method() {
        let cfg = RunConfig::from_toml(
            "steps = 10\n[model]\npreset = \"llama-mini\"\n[method]\nname = \"galore\"\nrank = 8\ninterval = 100\n",
        )
        .unwrap();
        assert_eq!(cfg.model.d_model, 256);
        assert_eq!(cfg.method.method, Method::GaLore { interval: 100 });
    }

    #[test]
    fn rejects_invalid() {
        // bad head divisibility
        assert!(RunConfig::from_toml("[model]\nd_model = 100\nn_heads = 3\n").is_err());
        // bad method
        assert!(RunConfig::from_toml("[method]\nname = \"magic\"\n").is_err());
        // rank too large
        assert!(RunConfig::from_toml("[method]\nrank = 100000\n").is_err());
        // bad gamma
        assert!(RunConfig::from_toml("[method]\nname = \"lotus\"\ngamma = 5.0\n").is_err());
        // eval_every = 0 would divide-by-zero in the train loops
        assert!(RunConfig::from_toml("eval_every = 0\n").is_err());
    }

    #[test]
    fn dist_block_parses_and_roundtrips() {
        let cfg = RunConfig::from_toml(
            "batch = 8\n[dist]\nworkers = 2\nshards = 4\nquorum = 0.75\n",
        )
        .unwrap();
        assert_eq!(cfg.dist.workers, 2);
        assert_eq!(cfg.dist.shards, 4);
        assert!((cfg.dist.quorum - 0.75).abs() < 1e-12);
        assert!(cfg.dist.is_distributed());
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.dist, cfg.dist);
        // defaults stay single-process
        assert!(!RunConfig::default().dist.is_distributed());
    }

    #[test]
    fn dist_block_is_validated() {
        // workers must divide shards
        assert!(RunConfig::from_toml("batch = 8\n[dist]\nworkers = 3\nshards = 4\n").is_err());
        // shards must divide the global batch
        assert!(RunConfig::from_toml("batch = 6\n[dist]\nworkers = 4\n").is_err());
        // quorum range
        assert!(RunConfig::from_toml("batch = 8\n[dist]\nworkers = 2\nquorum = 1.5\n").is_err());
    }

    #[test]
    fn faults_block_parses_roundtrips_and_validates() {
        let cfg = RunConfig::from_toml(
            "[faults]\nplan = \"flip@3#0,drop@5,kill1@8,nan@10,spike@12\"\nseed = 99\nspike_window = 4\nspike_factor = 3.0\nmax_rollbacks = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.faults.seed, 99);
        assert_eq!(cfg.faults.spike_window, 4);
        let plan = cfg.faults.plan().unwrap().expect("non-empty plan");
        assert_eq!(plan.events.len(), 5);
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.faults, cfg.faults);
        // defaults: no plan armed
        assert!(RunConfig::default().faults.plan().unwrap().is_none());
        // malformed schedules are a config error, not a runtime surprise
        assert!(RunConfig::from_toml("[faults]\nplan = \"explode@fr\"\n").is_err());
        assert!(RunConfig::from_toml("[faults]\nspike_factor = 0.5\n").is_err());
        assert!(RunConfig::from_toml("[faults]\nspike_window = 0\n").is_err());
        // serve-path and load-scoped kinds flow through the same grammar
        let cfg = RunConfig::from_toml(
            "[faults]\nplan = \"lane0@3,stall@5,ckpt_corrupt@load,vote1@7\"\n",
        )
        .unwrap();
        assert_eq!(cfg.faults.plan().unwrap().unwrap().events.len(), 4);
        assert!(RunConfig::from_toml("[faults]\nplan = \"ckpt_corrupt@5\"\n").is_err());
    }

    #[test]
    fn clip_norm_parses_roundtrips_and_validates() {
        let cfg = RunConfig::from_toml("[faults]\nclip_norm = 2.5\n").unwrap();
        assert!((cfg.faults.clip_norm - 2.5).abs() < 1e-12);
        assert!((cfg.faults.guard().clip_norm - 2.5).abs() < 1e-12);
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.faults, cfg.faults);
        // default: clipping off, guard sees 0.0
        assert_eq!(RunConfig::default().faults.clip_norm, 0.0);
        assert!(RunConfig::from_toml("[faults]\nclip_norm = -1.0\n").is_err());
    }

    #[test]
    fn telemetry_block_parses_and_roundtrips() {
        let cfg = RunConfig::from_toml(
            "[telemetry]\ntrace_out = \"trace.json\"\nmetrics_out = \"metrics.jsonl\"\n",
        )
        .unwrap();
        assert_eq!(cfg.telemetry.trace_out, "trace.json");
        assert_eq!(cfg.telemetry.metrics_out, "metrics.jsonl");
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.telemetry, cfg.telemetry);
        // default: both sinks off
        assert_eq!(RunConfig::default().telemetry, TelemetryCfg::default());
        assert!(RunConfig::default().telemetry.trace_out.is_empty());
    }

    #[test]
    fn diagnostics_telemetry_fields_parse_and_roundtrip() {
        let cfg = RunConfig::from_toml(
            "[telemetry]\nprom_out = \"run.prom\"\ntrace_mode = \"ring\"\ntrace_cap = 256\nprobe_every = 5\n",
        )
        .unwrap();
        assert_eq!(cfg.telemetry.prom_out, "run.prom");
        assert_eq!(cfg.telemetry.trace_mode, "ring");
        assert_eq!(cfg.telemetry.trace_cap, 256);
        assert_eq!(cfg.telemetry.probe_every, 5);
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.telemetry, cfg.telemetry);
        // defaults: prom off, full trace, probes off
        let d = RunConfig::default().telemetry;
        assert!(d.prom_out.is_empty() && d.trace_mode.is_empty());
        assert_eq!(d.probe_every, 0);
        // unknown trace modes are config errors
        assert!(RunConfig::from_toml("[telemetry]\ntrace_mode = \"laser\"\n").is_err());
    }

    #[test]
    fn quant_block_parses_roundtrips_and_validates() {
        use crate::quant::QuantDtype;
        let cfg = RunConfig::from_toml(
            "[quant]\nwire = \"int8\"\nkv = \"bf16\"\nstate = \"bf16\"\nint8_block = 32\n",
        )
        .unwrap();
        assert_eq!(cfg.quant.wire, QuantDtype::Int8);
        assert_eq!(cfg.quant.kv, QuantDtype::Bf16);
        assert_eq!(cfg.quant.state, QuantDtype::Bf16);
        assert_eq!(cfg.quant.int8_block, 32);
        let back = RunConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.quant, cfg.quant);
        // default: all surfaces f32 (bit-exact legacy paths)
        assert_eq!(RunConfig::default().quant, QuantCfg::default());
        // int8 K/V is not implemented; unknown dtypes are config errors
        assert!(RunConfig::from_toml("[quant]\nkv = \"int8\"\n").is_err());
        assert!(RunConfig::from_toml("[quant]\nwire = \"fp8\"\n").is_err());
        assert!(RunConfig::from_toml("[quant]\nint8_block = 0\n").is_err());
    }

    #[test]
    fn every_method_name_parses() {
        for name in
            ["full", "galore", "lowrank", "lora", "relora", "adarankgrad", "apollo", "lotus", "rsvd-fixed"]
        {
            let text = format!("[method]\nname = \"{name}\"\nrank = 8\n");
            RunConfig::from_toml(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
