//! Configuration system: a TOML-subset parser (offline stand-in for
//! `toml` + `serde`) plus the typed run-configuration schema and named
//! presets used by the CLI launcher.
//!
//! Supported TOML subset: `[table]` headers, `key = value` with strings,
//! integers, floats, booleans and flat arrays, comments with `#`.
//! That covers every config this project ships; nested tables and dotted
//! keys are rejected with a clear error.

pub mod toml;
pub mod schema;
pub mod presets;

pub use schema::{MethodCfg, RunConfig};
pub use toml::{parse_toml, TomlValue};
