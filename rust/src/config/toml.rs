//! Minimal TOML parser (tables, scalars, flat arrays, comments).

use std::collections::BTreeMap;

/// A TOML scalar or flat array.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: table name → (key → value). Root keys live under "".
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML-subset document.
pub fn parse_toml(input: &str) -> Result<TomlDoc, String> {
    let mut doc = TomlDoc::new();
    let mut current = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: malformed table header", lineno + 1));
            }
            let name = line[1..line.len() - 1].trim();
            if name.is_empty() || name.contains('[') {
                return Err(format!("line {}: bad table name '{name}'", lineno + 1));
            }
            current = name.to_string();
            doc.entry(current.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() || key.contains('.') {
            return Err(format!("line {}: unsupported key '{key}'", lineno + 1));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.get_mut(&current).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            return Err(format!("unterminated string: {s}"));
        }
        let inner = &s[1..s.len() - 1];
        // minimal escapes
        let out = inner.replace("\\n", "\n").replace("\\t", "\t").replace("\\\"", "\"");
        return Ok(TomlValue::Str(out));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split an array body on top-level commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_document() {
        let doc = parse_toml(
            r#"
# run config
name = "lotus-test"
steps = 1_000
lr = 3e-3
verbose = true

[model]
d_model = 256
layers = 4
ranks = [4, 8, 16]
"#,
        )
        .unwrap();
        assert_eq!(doc[""]["name"], TomlValue::Str("lotus-test".into()));
        assert_eq!(doc[""]["steps"], TomlValue::Int(1000));
        assert_eq!(doc[""]["lr"].as_f64().unwrap(), 3e-3);
        assert_eq!(doc[""]["verbose"], TomlValue::Bool(true));
        assert_eq!(doc["model"]["d_model"], TomlValue::Int(256));
        assert_eq!(
            doc["model"]["ranks"],
            TomlValue::Array(vec![TomlValue::Int(4), TomlValue::Int(8), TomlValue::Int(16)])
        );
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = parse_toml("s = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc[""]["s"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unterminated\n").is_err());
        assert!(parse_toml("novalue\n").is_err());
        assert!(parse_toml("k = \n").is_err());
        assert!(parse_toml("a.b = 1\n").is_err());
    }

    #[test]
    fn int_float_coercion() {
        let doc = parse_toml("x = 5\ny = 5.5\n").unwrap();
        assert_eq!(doc[""]["x"].as_f64().unwrap(), 5.0);
        assert_eq!(doc[""]["y"].as_f64().unwrap(), 5.5);
        assert_eq!(doc[""]["y"].as_i64(), None);
    }
}
