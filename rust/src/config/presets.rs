//! Named run presets for the CLI and the library quick-start.

use super::schema::{MethodCfg, RunConfig};
use crate::dist::DistCfg;
use crate::models::presets as mp;
use crate::sim::trainer::{Method, SimRunCfg};

/// Quick sim config over the tiny model (library doc example).
pub fn llama_tiny() -> SimRunCfg {
    SimRunCfg::quick(mp::llama_tiny_cfg(), 16, 200)
}

/// Sim config over the ~11M model (Table 1 sim scale).
pub fn llama_mini() -> SimRunCfg {
    SimRunCfg::quick(mp::llama_mini_cfg(), 32, 400)
}

/// E2E PJRT pre-training default (~22M params).
pub fn pretrain_20m() -> RunConfig {
    RunConfig {
        name: "pretrain-c4sim-20m".into(),
        model: mp::llama_20m_cfg(),
        method: MethodCfg { method: Method::lotus_default(), rank: 64 },
        batch: 8,
        steps: 300,
        eval_every: 25,
        ckpt_every: 100,
        ..Default::default()
    }
}

/// The ~100M-parameter proof config.
pub fn pretrain_100m() -> RunConfig {
    RunConfig {
        name: "pretrain-c4sim-100m".into(),
        model: mp::llama_100m_cfg(),
        method: MethodCfg { method: Method::lotus_default(), rank: 128 },
        batch: 4,
        steps: 40,
        eval_every: 10,
        ckpt_every: 0,
        ..Default::default()
    }
}

/// 4-worker data-parallel pre-training over the tiny model: the
/// quick-start for `lotus sim --workers 4` (low-rank gradient exchange +
/// subspace consensus; see `EXPERIMENTS.md` §Scale).
pub fn dist_tiny() -> RunConfig {
    RunConfig {
        name: "dist-tiny-x4".into(),
        steps: 100,
        eval_every: 25,
        dist: DistCfg { workers: 4, shards: 4, quorum: 0.5 },
        ..Default::default()
    }
}

/// Resolve a named run preset.
pub fn run_preset(name: &str) -> Option<RunConfig> {
    match name {
        "pretrain-20m" => Some(pretrain_20m()),
        "pretrain-100m" => Some(pretrain_100m()),
        "tiny" => Some(RunConfig::default()),
        "dist-tiny" => Some(dist_tiny()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn presets_are_valid() {
        for name in ["pretrain-20m", "pretrain-100m", "tiny", "dist-tiny"] {
            super::run_preset(name).unwrap().validate().unwrap();
        }
        assert!(super::run_preset("nope").is_none());
    }

    #[test]
    fn dist_preset_is_distributed() {
        let cfg = super::dist_tiny();
        assert!(cfg.dist.is_distributed());
        assert_eq!(cfg.batch % cfg.dist.shard_count(), 0);
    }
}
