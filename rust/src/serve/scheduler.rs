//! Slot-based continuous batching: a FIFO request queue over a fixed
//! number of decode slots, admitting and retiring sequences at *token*
//! granularity — a finished request frees its slot for the next queued
//! one on the very next engine step, so short and long requests share a
//! batch without head-of-line blocking.
//!
//! The scheduler owns request bookkeeping (per-request RNG stream,
//! generated tokens, latency stamps); the engine
//! ([`crate::serve::ServeEngine`]) owns the model-side lane state (KV
//! cache, scratch, logits). Slot `i` here corresponds to lane `i` there.

use super::sample::{self, Sampling};
use crate::util::Rng;
use std::collections::VecDeque;
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens to generate (≥ 1).
    pub max_new: usize,
    pub sampling: Sampling,
    /// Seed of this request's private sampling stream.
    pub seed: u64,
}

/// A finished request with its latency stamps.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// The generated tokens (`max_new` of them).
    pub tokens: Vec<u32>,
    /// Engine step at which the request entered a slot.
    pub admitted_step: u64,
    /// Engine step that produced the final token.
    pub finished_step: u64,
    /// Wall-clock submission → first generated token. Measured from
    /// [`Scheduler::submit`], so queue wait counts — this is the
    /// user-perceived latency, not the slot-residency time.
    pub ttft_s: f64,
    /// Wall-clock submission → final token (queue wait included).
    pub total_s: f64,
}

/// In-flight request state (one per occupied slot).
struct Active {
    req: Request,
    rng: Rng,
    tokens: Vec<u32>,
    submitted: Instant,
    admitted_step: u64,
    ttft_s: Option<f64>,
}

/// The request queue + slot table. Queued requests carry their
/// submission stamp so latency percentiles include queue wait.
pub struct Scheduler {
    queue: VecDeque<(Request, Instant)>,
    slots: Vec<Option<Active>>,
}

impl Scheduler {
    pub fn new(n_slots: usize) -> Self {
        assert!(n_slots >= 1, "scheduler needs at least one slot");
        Scheduler { queue: VecDeque::new(), slots: (0..n_slots).map(|_| None).collect() }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Enqueue a request (admitted into a slot on a later
    /// [`Scheduler::admit`], strictly in submission order). The latency
    /// clock starts here.
    pub fn submit(&mut self, req: Request) {
        self.queue.push_back((req, Instant::now()));
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently occupying a slot.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when nothing is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.slots[slot].is_some()
    }

    /// Move queued requests into free slots (FIFO), appending the slot
    /// indices admitted this call to `admitted`. The engine prefills
    /// exactly these slots this step.
    pub fn admit(&mut self, step: u64, admitted: &mut Vec<usize>) {
        for (si, slot) in self.slots.iter_mut().enumerate() {
            if slot.is_some() {
                continue;
            }
            let Some((req, submitted)) = self.queue.pop_front() else { break };
            let rng = Rng::new(req.seed);
            *slot = Some(Active {
                req,
                rng,
                tokens: Vec::new(),
                submitted,
                admitted_step: step,
                ttft_s: None,
            });
            admitted.push(si);
        }
    }

    /// The prompt of the request occupying `slot`.
    pub fn prompt(&self, slot: usize) -> &[u32] {
        &self.slots[slot].as_ref().expect("prompt() on an empty slot").req.prompt
    }

    /// Sample the next token for `slot` from a logits row, record it,
    /// and retire the request when it reaches `max_new` (freeing the
    /// slot for the next admission). Returns the token and, on
    /// retirement, the completion.
    pub fn next_token(
        &mut self,
        slot: usize,
        logits: &[f32],
        step: u64,
    ) -> (u32, Option<Completion>) {
        let a = self.slots[slot].as_mut().expect("next_token() on an empty slot");
        let tok = sample::draw(logits, &a.req.sampling, &mut a.rng);
        a.tokens.push(tok);
        if a.ttft_s.is_none() {
            a.ttft_s = Some(a.submitted.elapsed().as_secs_f64());
        }
        if a.tokens.len() < a.req.max_new {
            return (tok, None);
        }
        let a = self.slots[slot].take().expect("slot vanished");
        let completion = Completion {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            tokens: a.tokens,
            admitted_step: a.admitted_step,
            finished_step: step,
            ttft_s: a.ttft_s.unwrap_or(0.0),
            total_s: a.submitted.elapsed().as_secs_f64(),
        };
        (tok, Some(completion))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            max_new,
            sampling: Sampling::Greedy,
            seed: id,
        }
    }

    #[test]
    fn admits_fifo_and_reuses_freed_slots_at_token_granularity() {
        let mut s = Scheduler::new(2);
        for i in 0..4 {
            s.submit(req(i, 3, if i == 0 { 1 } else { 3 }));
        }
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        assert_eq!(adm, vec![0, 1], "first two requests fill the slots in order");
        assert_eq!(s.queued(), 2);
        // slot 0's request finishes after a single token…
        let logits = [0.0f32, 2.0, 1.0];
        let (tok, fin) = s.next_token(0, &logits, 1);
        assert_eq!(tok, 1);
        let c = fin.expect("max_new=1 retires immediately");
        assert_eq!((c.id, c.prompt_len, c.finished_step), (0, 3, 1));
        let (_, fin) = s.next_token(1, &logits, 1);
        assert!(fin.is_none(), "slot 1 still mid-flight");
        // …and the freed slot is re-filled on the next admit while slot 1
        // keeps decoding: that is continuous batching
        adm.clear();
        s.admit(2, &mut adm);
        assert_eq!(adm, vec![0], "request 2 takes the freed slot");
        assert!(s.is_active(1));
        assert_eq!(s.queued(), 1);
    }

    #[test]
    fn completion_collects_all_tokens() {
        let mut s = Scheduler::new(1);
        s.submit(req(7, 2, 3));
        let mut adm = Vec::new();
        s.admit(5, &mut adm);
        let logits = [3.0f32, 1.0];
        let mut fin = None;
        for step in 5..8 {
            let (tok, f) = s.next_token(0, &logits, step);
            assert_eq!(tok, 0);
            fin = f;
        }
        let c = fin.expect("retired after 3 tokens");
        assert_eq!(c.tokens, vec![0, 0, 0]);
        assert_eq!((c.admitted_step, c.finished_step), (5, 7));
        assert!(c.total_s >= c.ttft_s);
        assert!(s.is_idle());
    }
}
