//! Slot-based continuous batching: a FIFO request queue over a fixed
//! number of decode slots, admitting and retiring sequences at *token*
//! granularity — a finished request frees its slot for the next queued
//! one on the very next engine step, so short and long requests share a
//! batch without head-of-line blocking.
//!
//! The scheduler owns request bookkeeping (per-request RNG stream,
//! generated tokens, latency stamps); the engine
//! ([`crate::serve::ServeEngine`]) owns the model-side lane state (KV
//! cache, scratch, logits). Slot `i` here corresponds to lane `i` there.

use super::sample::{self, Sampling};
use crate::util::Rng;
use std::collections::VecDeque;
use std::time::Instant;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens to generate (≥ 1).
    pub max_new: usize,
    pub sampling: Sampling,
    /// Seed of this request's private sampling stream.
    pub seed: u64,
}

/// How a request left the scheduler — normal completion, or shed by a
/// graceful-degradation limit (PR 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompletionStatus {
    /// Generated its full `max_new` tokens.
    Ok,
    /// Exceeded its per-request deadline (queued or mid-flight); carries
    /// whatever tokens were generated before expiry.
    TimedOut,
}

/// The typed rejection returned by [`Scheduler::submit`] when the
/// bounded queue is full — callers either apply backpressure (drive the
/// engine, retry) or drop the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueFull {
    pub max_queue: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request queue full ({} waiting) — request shed", self.max_queue)
    }
}

impl std::error::Error for QueueFull {}

/// A finished request with its latency stamps.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub prompt_len: usize,
    /// How the request finished ([`CompletionStatus::Ok`] when it
    /// generated all `max_new` tokens).
    pub status: CompletionStatus,
    /// The generated tokens (`max_new` of them, fewer on timeout).
    pub tokens: Vec<u32>,
    /// Engine step at which the request entered a slot.
    pub admitted_step: u64,
    /// Engine step that produced the final token.
    pub finished_step: u64,
    /// Wall-clock submission → first generated token. Measured from
    /// [`Scheduler::submit`], so queue wait counts — this is the
    /// user-perceived latency, not the slot-residency time.
    pub ttft_s: f64,
    /// Wall-clock submission → final token (queue wait included).
    pub total_s: f64,
}

/// In-flight request state (one per occupied slot).
struct Active {
    req: Request,
    rng: Rng,
    tokens: Vec<u32>,
    submitted: Instant,
    /// Engine step at submission — per-request deadlines count from here.
    submit_step: u64,
    admitted_step: u64,
    ttft_s: Option<f64>,
}

/// Default bound on queued (not yet admitted) requests.
pub const DEFAULT_MAX_QUEUE: usize = 1024;

/// The request queue + slot table. Queued requests carry their
/// submission stamp so latency percentiles include queue wait. The
/// queue is bounded ([`Scheduler::set_limits`]) and requests can carry
/// a deadline in engine steps — overload degrades to typed shedding and
/// timeouts instead of unbounded memory growth and infinite waits.
pub struct Scheduler {
    queue: VecDeque<(Request, Instant, u64)>,
    /// In-flight requests evicted from a dead lane, waiting for
    /// re-admission ahead of the regular queue. The whole [`Active`] is
    /// stashed — sampling stream, generated tokens, latency stamps — so
    /// the retried completion is token-identical to an unfaulted run.
    requeued: VecDeque<Active>,
    slots: Vec<Option<Active>>,
    max_queue: usize,
    /// Per-request deadline in engine steps from submission (None: no
    /// deadline).
    deadline_steps: Option<u64>,
    shed: u64,
    timed_out: u64,
    requeues: u64,
}

impl Scheduler {
    pub fn new(n_slots: usize) -> Self {
        assert!(n_slots >= 1, "scheduler needs at least one slot");
        Scheduler {
            queue: VecDeque::new(),
            requeued: VecDeque::new(),
            slots: (0..n_slots).map(|_| None).collect(),
            max_queue: DEFAULT_MAX_QUEUE,
            deadline_steps: None,
            shed: 0,
            timed_out: 0,
            requeues: 0,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Configure graceful degradation: the queue bound and the
    /// per-request deadline (engine steps from submission; None disables
    /// timeouts). A deadline of 0 would expire requests on the step they
    /// were submitted, so it is rounded up to 1.
    pub fn set_limits(&mut self, max_queue: usize, deadline_steps: Option<u64>) {
        assert!(max_queue >= 1, "max_queue must be at least 1");
        self.max_queue = max_queue;
        self.deadline_steps = deadline_steps.map(|d| d.max(1));
    }

    /// Requests shed at submission because the queue was full.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Requests retired by deadline expiry (queued or mid-flight).
    pub fn timed_out(&self) -> u64 {
        self.timed_out
    }

    /// In-flight requests evicted from a dead lane and requeued.
    pub fn requeues(&self) -> u64 {
        self.requeues
    }

    /// The configured per-request deadline in engine steps, if any.
    pub fn deadline(&self) -> Option<u64> {
        self.deadline_steps
    }

    /// Evict the request occupying `slot` (the lane died mid-decode)
    /// and stash its full in-flight state — sampling stream, tokens
    /// generated so far, latency stamps — for front-priority
    /// re-admission by the next [`Scheduler::admit`]. The engine must
    /// clear the lane; re-admission re-prefills prompt + generated
    /// tokens, so the preserved stream continues token-identically.
    /// Returns the evicted request's id (None when the slot was idle).
    pub fn kill(&mut self, slot: usize) -> Option<u64> {
        let a = self.slots[slot].take()?;
        let id = a.req.id;
        self.requeued.push_back(a);
        self.requeues += 1;
        Some(id)
    }

    /// Enqueue a request (admitted into a slot on a later
    /// [`Scheduler::admit`], strictly in submission order). The latency
    /// clock starts here; `step` is the engine step the deadline counts
    /// from. A full queue sheds the request with a typed [`QueueFull`].
    pub fn submit(&mut self, req: Request, step: u64) -> Result<(), QueueFull> {
        if self.queue.len() >= self.max_queue {
            self.shed += 1;
            return Err(QueueFull { max_queue: self.max_queue });
        }
        self.queue.push_back((req, Instant::now(), step));
        Ok(())
    }

    /// Retire every queued or in-flight request whose deadline has
    /// passed, appending a [`CompletionStatus::TimedOut`] completion per
    /// casualty and the freed slot indices to `freed` (the engine must
    /// clear those lanes). No-op without a configured deadline.
    pub fn expire(&mut self, step: u64, out: &mut Vec<Completion>, freed: &mut Vec<usize>) {
        let Some(deadline) = self.deadline_steps else { return };
        // requeued casualties keep their original submission stamp, so a
        // lane death does not extend a request's deadline
        let mut keep = VecDeque::with_capacity(self.requeued.len());
        while let Some(a) = self.requeued.pop_front() {
            if step.saturating_sub(a.submit_step) < deadline {
                keep.push_back(a);
                continue;
            }
            out.push(Completion {
                id: a.req.id,
                prompt_len: a.req.prompt.len(),
                status: CompletionStatus::TimedOut,
                tokens: a.tokens,
                admitted_step: a.admitted_step,
                finished_step: step,
                ttft_s: a.ttft_s.unwrap_or_else(|| a.submitted.elapsed().as_secs_f64()),
                total_s: a.submitted.elapsed().as_secs_f64(),
            });
            self.timed_out += 1;
        }
        self.requeued = keep;
        while let Some((req, submitted, submit_step)) = self.queue.front() {
            if step.saturating_sub(*submit_step) < deadline {
                break; // FIFO queue: later entries are younger
            }
            out.push(Completion {
                id: req.id,
                prompt_len: req.prompt.len(),
                status: CompletionStatus::TimedOut,
                tokens: Vec::new(),
                admitted_step: 0,
                finished_step: step,
                ttft_s: submitted.elapsed().as_secs_f64(),
                total_s: submitted.elapsed().as_secs_f64(),
            });
            self.timed_out += 1;
            self.queue.pop_front();
        }
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let expired = slot
                .as_ref()
                .is_some_and(|a| step.saturating_sub(a.submit_step) >= deadline);
            if !expired {
                continue;
            }
            let a = slot.take().expect("slot checked occupied");
            out.push(Completion {
                id: a.req.id,
                prompt_len: a.req.prompt.len(),
                status: CompletionStatus::TimedOut,
                tokens: a.tokens,
                admitted_step: a.admitted_step,
                finished_step: step,
                ttft_s: a.ttft_s.unwrap_or_else(|| a.submitted.elapsed().as_secs_f64()),
                total_s: a.submitted.elapsed().as_secs_f64(),
            });
            self.timed_out += 1;
            freed.push(si);
        }
    }

    /// Requests waiting for a slot (fresh submissions plus requeued
    /// lane-death casualties).
    pub fn queued(&self) -> usize {
        self.queue.len() + self.requeued.len()
    }

    /// Requests currently occupying a slot.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when nothing is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.requeued.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.slots[slot].is_some()
    }

    /// Move waiting requests into free slots, appending the slot indices
    /// admitted this call to `admitted`. Requeued lane-death casualties
    /// go first (their stashed state is resumed untouched), then the
    /// FIFO queue. The engine prefills exactly these slots this step.
    pub fn admit(&mut self, step: u64, admitted: &mut Vec<usize>) {
        for (si, slot) in self.slots.iter_mut().enumerate() {
            if slot.is_some() {
                continue;
            }
            if let Some(a) = self.requeued.pop_front() {
                *slot = Some(a);
                admitted.push(si);
                continue;
            }
            let Some((req, submitted, submit_step)) = self.queue.pop_front() else { break };
            let rng = Rng::new(req.seed);
            *slot = Some(Active {
                req,
                rng,
                tokens: Vec::new(),
                submitted,
                submit_step,
                admitted_step: step,
                ttft_s: None,
            });
            admitted.push(si);
        }
    }

    /// The prompt of the request occupying `slot`.
    pub fn prompt(&self, slot: usize) -> &[u32] {
        &self.slots[slot].as_ref().expect("prompt() on an empty slot").req.prompt
    }

    /// The tokens generated so far by the request occupying `slot`
    /// (non-empty only for a re-admitted lane-death casualty). The
    /// engine prefills prompt + generated to rebuild the lane's KV
    /// prefix exactly, so the preserved sampling stream continues
    /// token-identically.
    pub fn generated(&self, slot: usize) -> &[u32] {
        &self.slots[slot].as_ref().expect("generated() on an empty slot").tokens
    }

    /// Sample the next token for `slot` from a logits row, record it,
    /// and retire the request when it reaches `max_new` (freeing the
    /// slot for the next admission). Returns the token and, on
    /// retirement, the completion.
    pub fn next_token(
        &mut self,
        slot: usize,
        logits: &[f32],
        step: u64,
    ) -> (u32, Option<Completion>) {
        let a = self.slots[slot].as_mut().expect("next_token() on an empty slot");
        let tok = sample::draw(logits, &a.req.sampling, &mut a.rng);
        a.tokens.push(tok);
        if a.ttft_s.is_none() {
            a.ttft_s = Some(a.submitted.elapsed().as_secs_f64());
        }
        if a.tokens.len() < a.req.max_new {
            return (tok, None);
        }
        let a = self.slots[slot].take().expect("slot vanished");
        let completion = Completion {
            id: a.req.id,
            prompt_len: a.req.prompt.len(),
            status: CompletionStatus::Ok,
            tokens: a.tokens,
            admitted_step: a.admitted_step,
            finished_step: step,
            ttft_s: a.ttft_s.unwrap_or(0.0),
            total_s: a.submitted.elapsed().as_secs_f64(),
        };
        (tok, Some(completion))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: vec![1; prompt_len],
            max_new,
            sampling: Sampling::Greedy,
            seed: id,
        }
    }

    #[test]
    fn admits_fifo_and_reuses_freed_slots_at_token_granularity() {
        let mut s = Scheduler::new(2);
        for i in 0..4 {
            s.submit(req(i, 3, if i == 0 { 1 } else { 3 }), 0).unwrap();
        }
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        assert_eq!(adm, vec![0, 1], "first two requests fill the slots in order");
        assert_eq!(s.queued(), 2);
        // slot 0's request finishes after a single token…
        let logits = [0.0f32, 2.0, 1.0];
        let (tok, fin) = s.next_token(0, &logits, 1);
        assert_eq!(tok, 1);
        let c = fin.expect("max_new=1 retires immediately");
        assert_eq!((c.id, c.prompt_len, c.finished_step), (0, 3, 1));
        let (_, fin) = s.next_token(1, &logits, 1);
        assert!(fin.is_none(), "slot 1 still mid-flight");
        // …and the freed slot is re-filled on the next admit while slot 1
        // keeps decoding: that is continuous batching
        adm.clear();
        s.admit(2, &mut adm);
        assert_eq!(adm, vec![0], "request 2 takes the freed slot");
        assert!(s.is_active(1));
        assert_eq!(s.queued(), 1);
    }

    #[test]
    fn completion_collects_all_tokens() {
        let mut s = Scheduler::new(1);
        s.submit(req(7, 2, 3), 0).unwrap();
        let mut adm = Vec::new();
        s.admit(5, &mut adm);
        let logits = [3.0f32, 1.0];
        let mut fin = None;
        for step in 5..8 {
            let (tok, f) = s.next_token(0, &logits, step);
            assert_eq!(tok, 0);
            fin = f;
        }
        let c = fin.expect("retired after 3 tokens");
        assert_eq!(c.status, CompletionStatus::Ok);
        assert_eq!(c.tokens, vec![0, 0, 0]);
        assert_eq!((c.admitted_step, c.finished_step), (5, 7));
        assert!(c.total_s >= c.ttft_s);
        assert!(s.is_idle());
    }

    #[test]
    fn full_queue_sheds_with_typed_error() {
        let mut s = Scheduler::new(1);
        s.set_limits(2, None);
        assert!(s.submit(req(0, 2, 1), 0).is_ok());
        assert!(s.submit(req(1, 2, 1), 0).is_ok());
        assert_eq!(s.submit(req(2, 2, 1), 0), Err(QueueFull { max_queue: 2 }));
        assert_eq!(s.shed(), 1);
        assert_eq!(s.queued(), 2, "shed request never entered the queue");
        // draining a slot makes room again
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        assert!(s.submit(req(3, 2, 1), 1).is_ok());
    }

    #[test]
    fn kill_stashes_in_flight_state_and_readmits_front_priority() {
        let mut s = Scheduler::new(1);
        s.submit(req(0, 2, 3), 0).unwrap();
        s.submit(req(1, 2, 1), 0).unwrap();
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        let logits = [0.0f32, 1.0];
        s.next_token(0, &logits, 1);
        assert!(s.kill(0).is_some_and(|id| id == 0), "evicts the occupant");
        assert!(s.kill(0).is_none(), "slot already empty");
        assert_eq!(s.requeues(), 1);
        assert_eq!(s.queued(), 2, "casualty waits alongside request 1");
        assert!(!s.is_idle());
        // the casualty outranks the older queue entry…
        adm.clear();
        s.admit(2, &mut adm);
        assert_eq!(adm, vec![0]);
        assert_eq!(s.prompt(0), &[1, 1]);
        assert_eq!(s.generated(0), &[1], "…with its generated prefix intact");
        // and its counter is preserved: 2 more tokens retire it
        s.next_token(0, &logits, 2);
        let (_, fin) = s.next_token(0, &logits, 3);
        let c = fin.expect("resumes from 1 generated token, not 0");
        assert_eq!(c.status, CompletionStatus::Ok);
        assert_eq!(c.tokens.len(), 3);
        assert_eq!(c.admitted_step, 1, "original admission stamp survives the requeue");
    }

    #[test]
    fn requeued_casualties_keep_their_original_deadline() {
        let mut s = Scheduler::new(1);
        s.set_limits(16, Some(3));
        s.submit(req(0, 2, 10), 0).unwrap();
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        let logits = [1.0f32, 0.0];
        s.next_token(0, &logits, 1);
        s.kill(0);
        let (mut out, mut freed) = (Vec::new(), Vec::new());
        s.expire(3, &mut out, &mut freed);
        assert_eq!(out.len(), 1, "submit step 0 + deadline 3 expires the casualty at 3");
        assert_eq!(out[0].status, CompletionStatus::TimedOut);
        assert_eq!(out[0].tokens.len(), 1, "partial progress surfaces");
        assert!(freed.is_empty(), "the casualty held no slot");
        assert!(s.is_idle());
    }

    #[test]
    fn deadline_expiring_exactly_at_admit_retires_before_any_token() {
        // The engine expires before it admits, so a request whose
        // deadline lands on its would-be admission step never occupies a
        // slot: expiry wins the race.
        let mut s = Scheduler::new(1);
        s.set_limits(16, Some(2));
        s.submit(req(0, 2, 4), 0).unwrap();
        let (mut out, mut freed, mut adm) = (Vec::new(), Vec::new(), Vec::new());
        s.expire(2, &mut out, &mut freed);
        s.admit(2, &mut adm);
        assert_eq!(out.len(), 1, "expired on the admission boundary");
        assert!(out[0].tokens.is_empty());
        assert!(adm.is_empty(), "nothing left to admit");
        assert!(s.is_idle());
    }

    #[test]
    fn minimum_capacity_queue_sheds_everything_past_one() {
        let mut s = Scheduler::new(1);
        s.set_limits(1, None);
        assert!(s.submit(req(0, 2, 1), 0).is_ok());
        assert_eq!(s.submit(req(1, 2, 1), 0), Err(QueueFull { max_queue: 1 }));
        assert_eq!(s.submit(req(2, 2, 1), 0), Err(QueueFull { max_queue: 1 }));
        assert_eq!(s.shed(), 2);
        assert_eq!(s.queued(), 1);
    }

    #[test]
    fn timeout_racing_retirement_resolves_to_timeout() {
        // A request one token short of retiring when its deadline hits:
        // the engine calls expire() before next_token(), so the step
        // that would have produced the final token times the request out
        // with max_new - 1 tokens instead.
        let mut s = Scheduler::new(1);
        s.set_limits(16, Some(3));
        s.submit(req(0, 2, 3), 0).unwrap();
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        let logits = [1.0f32, 0.0];
        s.next_token(0, &logits, 1);
        s.next_token(0, &logits, 2);
        let (mut out, mut freed) = (Vec::new(), Vec::new());
        s.expire(3, &mut out, &mut freed);
        assert_eq!(freed, vec![0]);
        assert_eq!(out[0].status, CompletionStatus::TimedOut);
        assert_eq!(out[0].tokens.len(), 2, "expiry wins over the final token");
        assert_eq!(s.timed_out(), 1);
    }

    #[test]
    fn deadline_expires_queued_and_active_requests() {
        let mut s = Scheduler::new(1);
        s.set_limits(16, Some(3));
        s.submit(req(0, 2, 10), 0).unwrap(); // will occupy the slot
        s.submit(req(1, 4, 10), 0).unwrap(); // will starve in the queue
        let mut adm = Vec::new();
        s.admit(1, &mut adm);
        assert_eq!(adm, vec![0]);
        let logits = [1.0f32, 0.0];
        s.next_token(0, &logits, 1);
        s.next_token(0, &logits, 2);

        let mut out = Vec::new();
        let mut freed = Vec::new();
        s.expire(2, &mut out, &mut freed);
        assert!(out.is_empty() && freed.is_empty(), "deadline 3 not yet reached at step 2");
        s.expire(3, &mut out, &mut freed);
        assert_eq!(s.timed_out(), 2);
        assert_eq!(freed, vec![0], "active slot freed for the engine to clear");
        assert_eq!(out.len(), 2);
        let queued = out.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(queued.status, CompletionStatus::TimedOut);
        assert!(queued.tokens.is_empty(), "never admitted, no tokens");
        let active = out.iter().find(|c| c.id == 0).unwrap();
        assert_eq!(active.status, CompletionStatus::TimedOut);
        assert_eq!(active.tokens.len(), 2, "partial progress is returned");
        assert!(s.is_idle());
    }
}
