//! The serving engine: continuous-batched autoregressive generation
//! over a trained [`SimModel`].
//!
//! Each scheduler slot owns a *lane*: a per-sequence [`KvCache`], a
//! [`Workspace`] scratch arena and a logits row. One engine step (i)
//! admits queued requests into free lanes, (ii) runs
//! [`SimModel::forward_step`] for every occupied lane — whole sequences
//! fan across the worker pool, prefills (many tokens) and decodes (one
//! token) sharing the same batch — and (iii) samples one token per lane,
//! retiring finished requests.
//!
//! Determinism: every lane's arithmetic is shared-nothing (its own
//! cache/scratch, per-row-exact kernels, a per-request sampling stream),
//! so a request's tokens are bit-identical at any `LOTUS_THREADS`, any
//! slot count, and regardless of what else shares its batch — and equal
//! to the full-context forward ([`SimModel::forward_logits`]) on the
//! same sequence. `rust/tests/serve.rs` enforces all three.

use super::sample::Sampling;
use super::scheduler::{Completion, Request, Scheduler};
use crate::faults::{FaultInjector, FaultKind, FaultPlan, FaultStats};
use crate::models::LlamaConfig;
use crate::quant::QuantDtype;
use crate::runtime::pool;
use crate::sim::model::{KvCache, SimModel};
use crate::telemetry::{self, span, SpanKind, SPAN_KINDS};
use crate::tensor::{Matrix, Workspace};
use crate::train::checkpoint;
use crate::util::json::JsonValue;
use anyhow::{anyhow, bail, Context, Result};

/// Steps a `stall@step` fault jumps the engine clock by when no
/// deadline is configured (with one, the jump is the deadline itself,
/// so every request submitted before the stall expires — the storm).
const STALL_JUMP_STEPS: u64 = 8;

/// Model-side state of one scheduler slot.
struct Lane {
    cache: KvCache,
    ws: Workspace,
    logits: Matrix,
    /// Tokens to append on the next forward: the whole prompt right
    /// after admission, then the previously sampled token. Non-empty
    /// exactly while the slot is occupied (cleared on retirement), so
    /// it doubles as the lane's activity flag.
    pending: Vec<u32>,
}

/// Continuous-batching inference engine over a decoder LM.
pub struct ServeEngine {
    model: SimModel,
    sched: Scheduler,
    lanes: Vec<Lane>,
    max_seq: usize,
    step: u64,
    next_id: u64,
    prefill_tokens: u64,
    generated_tokens: u64,
    /// Armed serve-path fault schedule (None = fault-free, zero
    /// overhead): lane deaths, stalls, corrupt-checkpoint reloads.
    faults: Option<FaultInjector>,
}

impl ServeEngine {
    /// Engine with `slots` concurrent lanes, each holding up to
    /// `max_seq` tokens (prompt + generation), with exact f32 K/V.
    pub fn new(model: SimModel, slots: usize, max_seq: usize) -> Self {
        Self::with_kv_dtype(model, slots, max_seq, QuantDtype::F32)
    }

    /// Engine with an explicit K/V cache storage dtype (`--kv-dtype`):
    /// bf16 halves the per-lane cache footprint at ~8 mantissa bits of
    /// K/V precision; f32 is the bit-exact default.
    pub fn with_kv_dtype(model: SimModel, slots: usize, max_seq: usize, kv: QuantDtype) -> Self {
        assert!(slots >= 1, "serve engine needs at least one slot");
        assert!(max_seq >= 2, "max_seq must fit a prompt token and a generated token");
        let lanes = (0..slots)
            .map(|_| Lane {
                cache: KvCache::with_dtype(&model.cfg, max_seq, kv),
                ws: Workspace::new(),
                logits: Matrix::zeros(0, 0),
                pending: Vec::with_capacity(max_seq),
            })
            .collect();
        ServeEngine {
            model,
            sched: Scheduler::new(slots),
            lanes,
            max_seq,
            step: 0,
            next_id: 0,
            prefill_tokens: 0,
            generated_tokens: 0,
            faults: None,
        }
    }

    /// Arm a serve-path fault schedule (`lane<k>@step`, `stall@step`,
    /// `ckpt_corrupt@load`). Training-side kinds in the plan are simply
    /// never triggered by this engine.
    pub fn arm_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(FaultInjector::new(plan));
    }

    /// Counters of the faults actually injected so far.
    pub fn fault_stats(&self) -> FaultStats {
        self.faults.as_ref().map(|f| f.stats).unwrap_or_default()
    }

    /// In-flight requests evicted from a dead lane and requeued.
    pub fn requeues(&self) -> u64 {
        self.sched.requeues()
    }

    /// Engine over the weights of a saved checkpoint (weights-only or a
    /// full trainer container; shapes are validated against `cfg`).
    /// Returns the checkpoint's training step alongside the engine.
    pub fn from_checkpoint(
        cfg: LlamaConfig,
        path: impl AsRef<std::path::Path>,
        slots: usize,
        max_seq: usize,
    ) -> Result<(u64, ServeEngine)> {
        Self::from_checkpoint_with_kv(cfg, path, slots, max_seq, QuantDtype::F32)
    }

    /// [`Self::from_checkpoint`] with an explicit K/V cache dtype.
    pub fn from_checkpoint_with_kv(
        cfg: LlamaConfig,
        path: impl AsRef<std::path::Path>,
        slots: usize,
        max_seq: usize,
        kv: QuantDtype,
    ) -> Result<(u64, ServeEngine)> {
        let (step, params) = checkpoint::load_weights(path, cfg)?;
        Ok((step, ServeEngine::with_kv_dtype(SimModel { cfg, params }, slots, max_seq, kv)))
    }

    /// The served model (read access — tests decode against it).
    pub fn model(&self) -> &SimModel {
        &self.model
    }

    pub fn slots(&self) -> usize {
        self.lanes.len()
    }

    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Engine steps executed so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Prompt tokens prefilled so far (all lanes).
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_tokens
    }

    /// Tokens sampled so far (all lanes).
    pub fn generated_tokens(&self) -> u64 {
        self.generated_tokens
    }

    /// Total K/V cache bytes held by all lanes (diagnostics).
    pub fn kv_bytes(&self) -> usize {
        self.lanes.iter().map(|l| l.cache.bytes()).sum()
    }

    /// True when nothing is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Requests waiting for a lane.
    pub fn queued(&self) -> usize {
        self.sched.queued()
    }

    /// Requests currently occupying a lane.
    pub fn active(&self) -> usize {
        self.sched.active()
    }

    /// Requests shed at submission because the bounded queue was full.
    pub fn shed(&self) -> u64 {
        self.sched.shed()
    }

    /// Requests retired by deadline expiry.
    pub fn timed_out(&self) -> u64 {
        self.sched.timed_out()
    }

    /// Graceful-degradation limits: bound the request queue at
    /// `max_queue` and retire any request still unfinished
    /// `deadline_steps` engine steps after submission (None: no
    /// deadline). Under overload the engine then sheds and times out
    /// with typed statuses instead of growing without bound.
    pub fn configure_limits(&mut self, max_queue: usize, deadline_steps: Option<u64>) {
        self.sched.set_limits(max_queue, deadline_steps);
    }

    /// Enqueue a generation request; returns its id. The request is
    /// admitted into a lane by a later [`ServeEngine::step`], in
    /// submission order. When the bounded queue is full the request is
    /// shed with a typed [`super::scheduler::QueueFull`] inside the
    /// error (downcastable for backpressure loops).
    pub fn submit(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Result<u64> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if max_new == 0 {
            bail!("max_new must be at least 1");
        }
        if prompt.len() + max_new > self.max_seq {
            bail!(
                "prompt {} + max_new {max_new} exceeds the engine's max_seq {}",
                prompt.len(),
                self.max_seq
            );
        }
        let vocab = self.model.cfg.vocab;
        if let Some(&t) = prompt.iter().find(|&&t| t as usize >= vocab) {
            bail!("prompt token {t} outside the model vocabulary (0..{vocab})");
        }
        let id = self.next_id;
        let req = Request { id, prompt: prompt.to_vec(), max_new, sampling, seed };
        self.sched.submit(req, self.step).map_err(anyhow::Error::new)?;
        self.next_id += 1;
        Ok(id)
    }

    /// One engine iteration: admit → forward every occupied lane (fanned
    /// across the pool) → sample one token per lane, appending finished
    /// requests to `out`. Returns the number of tokens sampled (0 when
    /// idle).
    pub fn step(&mut self, out: &mut Vec<Completion>) -> usize {
        if self.sched.is_idle() {
            return 0;
        }
        self.step += 1;
        let emit = telemetry::metrics_enabled();
        let (ns0, c0) = if emit {
            (telemetry::phase_totals_ns(), telemetry::phase_counts())
        } else {
            ([0u64; SPAN_KINDS], [0u64; SPAN_KINDS])
        };
        self.inject_serve_faults(emit);
        // deadline expiry first: expired lanes free their slots for this
        // very step's admissions, and their partial completions surface
        // in `out` with a TimedOut status
        {
            let _sp = span(SpanKind::Admit);
            let mut freed: Vec<usize> = Vec::new();
            self.sched.expire(self.step, out, &mut freed);
            for &si in &freed {
                self.lanes[si].pending.clear();
            }
            let mut admitted: Vec<usize> = Vec::new();
            self.sched.admit(self.step, &mut admitted);
            let sched = &self.sched;
            for &si in &admitted {
                let lane = &mut self.lanes[si];
                lane.cache.clear();
                lane.pending.clear();
                lane.pending.extend_from_slice(sched.prompt(si));
                // a re-admitted lane-death casualty replays its generated
                // prefix too, rebuilding the KV state its preserved
                // sampling stream expects (empty for fresh admissions)
                lane.pending.extend_from_slice(sched.generated(si));
                self.prefill_tokens += lane.pending.len() as u64;
            }
        }

        // forward: whole lanes are shared-nothing, so fan them across
        // the pool; inside a worker the GEMMs degrade to serial
        // (pool::effective), so there is no pool-of-pools oversubscription.
        // Only occupied lanes enter the fan-out — par_items_mut chunks
        // contiguously, so idle slots would otherwise cluster the real
        // work onto one worker at partial occupancy (e.g. a trace tail).
        let model = &self.model;
        let mut busy: Vec<&mut Lane> =
            self.lanes.iter_mut().filter(|l| !l.pending.is_empty()).collect();
        // A prefill lane carries the whole prompt; a decode lane carries
        // exactly the one token sampled last step. Both kinds share the
        // batch, so the phase spans overlap when both are present.
        let (_pf_sp, _dc_sp) = if telemetry::spans_enabled() {
            let prefills = busy.iter().filter(|l| l.pending.len() > 1).count();
            (
                (prefills > 0).then(|| span(SpanKind::Prefill)),
                (busy.len() > prefills).then(|| span(SpanKind::Decode)),
            )
        } else {
            (None, None)
        };
        pool::global().par_items_mut(&mut busy, |_i, lane| {
            model.forward_step(&lane.pending, &mut lane.cache, &mut lane.ws, &mut lane.logits);
        });
        drop(_pf_sp);
        drop(_dc_sp);

        // sample + advance / retire (every occupied slot ran this step)
        let retire_sp = span(SpanKind::Retire);
        let step = self.step;
        let mut sampled = 0usize;
        for si in 0..self.lanes.len() {
            if !self.sched.is_active(si) {
                continue;
            }
            let (tok, fin) = self.sched.next_token(si, self.lanes[si].logits.row(0), step);
            let lane = &mut self.lanes[si];
            lane.pending.clear();
            match fin {
                Some(c) => out.push(c),
                None => lane.pending.push(tok),
            }
            sampled += 1;
        }
        self.generated_tokens += sampled as u64;
        drop(retire_sp);
        if emit {
            let ns1 = telemetry::phase_totals_ns();
            let c1 = telemetry::phase_counts();
            telemetry::emit_record(&JsonValue::obj(vec![
                ("type", JsonValue::str("serve")),
                ("step", JsonValue::num(self.step as f64)),
                ("queued", JsonValue::num(self.sched.queued() as f64)),
                ("active", JsonValue::num(self.sched.active() as f64)),
                ("shed", JsonValue::num(self.sched.shed() as f64)),
                ("timed_out", JsonValue::num(self.sched.timed_out() as f64)),
                ("requeues", JsonValue::num(self.sched.requeues() as f64)),
                ("sampled", JsonValue::num(sampled as f64)),
                ("generated", JsonValue::num(self.generated_tokens as f64)),
                ("wall", telemetry::phase_delta_json(&ns0, &c0, &ns1, &c1)),
            ]));
        }
        // live queue-depth gauges for the Prometheus snapshot (`lotus
        // top` renders these alongside the training gauges)
        if telemetry::diag::prom_enabled() {
            telemetry::REGISTRY.gauge("serve.queued").set(self.sched.queued() as u64);
            telemetry::REGISTRY.gauge("serve.active").set(self.sched.active() as u64);
            telemetry::diag::flush_prom();
        }
        sampled
    }

    /// Fire any serve-path faults scheduled for the current step: a
    /// `lane<k>` death evicts the occupant through the scheduler's
    /// typed requeue (retried token-identically on re-admission), a
    /// `stall` jumps the engine clock so every over-deadline request
    /// expires in one storm. Each injection surfaces as a typed
    /// `serve_fault` telemetry record.
    fn inject_serve_faults(&mut self, emit: bool) {
        let kinds = match self.faults.as_mut() {
            Some(inj) => {
                inj.begin_step(self.step);
                inj.serve_faults()
            }
            None => return,
        };
        for kind in kinds {
            match kind {
                FaultKind::LaneKill(k) => {
                    let victim = (k < self.lanes.len()).then(|| self.sched.kill(k)).flatten();
                    match victim {
                        Some(id) => {
                            self.lanes[k].pending.clear();
                            crate::log_info!(
                                "serve step {}: lane {k} died mid-decode — request {id} requeued",
                                self.step
                            );
                            if emit {
                                telemetry::emit_record(&JsonValue::obj(vec![
                                    ("type", JsonValue::str("serve_fault")),
                                    ("kind", JsonValue::str("lane_kill")),
                                    ("step", JsonValue::num(self.step as f64)),
                                    ("lane", JsonValue::num(k as f64)),
                                    ("request", JsonValue::num(id as f64)),
                                ]));
                            }
                        }
                        None => crate::log_info!(
                            "serve step {}: lane-kill fault on idle/unknown lane {k} — no-op",
                            self.step
                        ),
                    }
                }
                FaultKind::Stall => {
                    let jump = self.sched.deadline().unwrap_or(STALL_JUMP_STEPS);
                    crate::log_info!(
                        "serve step {}: stall — clock jumps {jump} steps (deadline storm)",
                        self.step
                    );
                    self.step += jump;
                    if emit {
                        telemetry::emit_record(&JsonValue::obj(vec![
                            ("type", JsonValue::str("serve_fault")),
                            ("kind", JsonValue::str("stall")),
                            ("step", JsonValue::num(self.step as f64)),
                            ("jump", JsonValue::num(jump as f64)),
                        ]));
                    }
                }
                other => unreachable!("serve_faults yielded non-serve kind {other:?}"),
            }
        }
    }

    /// Reload model weights from the first loadable container in a
    /// newest-first candidate chain. Every candidate is CRC-verified
    /// before a single tensor is trusted; a corrupt container — an
    /// armed `ckpt_corrupt@load` fault mangles the first candidate's
    /// bytes in memory to simulate one — is diagnosed with a typed
    /// [`crate::train::checkpoint::CkptError`] and the loader falls
    /// back to the next candidate. Errors (with the first candidate's
    /// typed diagnosis preserved for downcasting) only when every
    /// candidate fails; never panics. Requires an idle engine (a reload
    /// mid-flight would corrupt in-flight generations). Returns the
    /// training step of the container served.
    pub fn reload_from_chain(&mut self, paths: &[impl AsRef<std::path::Path>]) -> Result<u64> {
        if !self.sched.is_idle() {
            bail!("checkpoint reload requires an idle engine");
        }
        let mut first_err: Option<anyhow::Error> = None;
        for (i, p) in paths.iter().enumerate() {
            let p = p.as_ref();
            let loaded = std::fs::read(p)
                .with_context(|| format!("opening checkpoint {p:?}"))
                .and_then(|mut buf| {
                    if self.faults.as_mut().is_some_and(|f| f.load_fault()) && !buf.is_empty() {
                        let mid = buf.len() / 2;
                        buf[mid] ^= 0xFF;
                        crate::log_info!("ckpt_corrupt: mangled byte {mid} of {p:?} on reload");
                    }
                    checkpoint::load_weights_bytes(&buf, self.model.cfg)
                        .with_context(|| format!("loading checkpoint {p:?}"))
                });
            match loaded {
                Ok((step, params)) => {
                    if i > 0 {
                        crate::log_info!(
                            "checkpoint chain: fell back {i} container(s) to {p:?} (step {step})"
                        );
                    }
                    self.model.params = params;
                    return Ok(step);
                }
                Err(e) => {
                    crate::log_info!("checkpoint chain: candidate {p:?} rejected: {e:#}");
                    first_err.get_or_insert(e);
                }
            }
        }
        Err(match first_err {
            Some(e) => {
                e.context(format!("no loadable checkpoint among {} candidate(s)", paths.len()))
            }
            None => anyhow!("empty checkpoint chain"),
        })
    }

    /// Drive [`ServeEngine::step`] until every queued and in-flight
    /// request has completed; returns the completions in finish order.
    pub fn run_until_idle(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while !self.sched.is_idle() {
            self.step(&mut out);
        }
        out
    }

    /// One-shot convenience: submit a single request and run it to
    /// completion (any other queued work drains too). Returns the
    /// generated tokens.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Result<Vec<u32>> {
        let id = self.submit(prompt, max_new, sampling, seed)?;
        let done = self.run_until_idle();
        done.into_iter()
            .find(|c| c.id == id)
            .map(|c| c.tokens)
            .ok_or_else(|| anyhow!("request {id} did not complete"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LlamaConfig;

    fn tiny() -> SimModel {
        let cfg =
            LlamaConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, seq_len: 8 };
        SimModel::new(cfg, 3)
    }

    #[test]
    fn submit_validates_requests() {
        let mut e = ServeEngine::new(tiny(), 2, 16);
        assert!(e.submit(&[], 4, Sampling::Greedy, 0).is_err(), "empty prompt");
        assert!(e.submit(&[1, 2], 0, Sampling::Greedy, 0).is_err(), "zero max_new");
        assert!(e.submit(&[1; 15], 2, Sampling::Greedy, 0).is_err(), "overflows max_seq");
        assert!(e.submit(&[99], 2, Sampling::Greedy, 0).is_err(), "token outside vocab");
        assert!(e.submit(&[1, 2, 3], 4, Sampling::Greedy, 0).is_ok());
    }

    #[test]
    fn generate_produces_the_requested_token_count() {
        let mut e = ServeEngine::new(tiny(), 2, 16);
        let toks = e.generate(&[0, 5, 9], 6, Sampling::Greedy, 1).unwrap();
        assert_eq!(toks.len(), 6);
        assert!(toks.iter().all(|&t| (t as usize) < 32));
        assert!(e.is_idle());
        assert_eq!(e.prefill_tokens(), 3);
        assert_eq!(e.generated_tokens(), 6);
    }

    #[test]
    fn overload_sheds_and_times_out_gracefully() {
        use super::super::scheduler::{CompletionStatus, QueueFull};
        let mut e = ServeEngine::new(tiny(), 1, 16);
        e.configure_limits(2, Some(4));
        e.submit(&[1, 2], 8, Sampling::Greedy, 0).unwrap();
        let mut pre = Vec::new();
        e.step(&mut pre); // request 0 occupies the single lane
        assert!(pre.is_empty());
        e.submit(&[1], 8, Sampling::Greedy, 1).unwrap();
        e.submit(&[2], 8, Sampling::Greedy, 2).unwrap();
        let err = e.submit(&[3], 8, Sampling::Greedy, 3).unwrap_err();
        assert!(err.downcast_ref::<QueueFull>().is_some(), "typed shed error: {err}");
        assert_eq!(e.shed(), 1);

        let done = e.run_until_idle();
        assert!(e.is_idle());
        assert_eq!(done.len(), 3, "every admitted/queued request retires");
        assert!(done.iter().all(|c| c.status == CompletionStatus::TimedOut));
        assert_eq!(e.timed_out(), 3);
        assert!(
            done.iter().all(|c| c.tokens.len() < 8),
            "deadline 4 cannot fit 8 generated tokens"
        );
    }

    #[test]
    fn bf16_kv_engine_halves_cache_bytes_and_completes() {
        let f32_bytes = ServeEngine::new(tiny(), 2, 16).kv_bytes();
        let mut e = ServeEngine::with_kv_dtype(tiny(), 2, 16, QuantDtype::Bf16);
        assert_eq!(e.kv_bytes() * 2, f32_bytes, "bf16 lanes are half the footprint");
        let a = e.generate(&[0, 5, 9], 6, Sampling::Greedy, 1).unwrap();
        let b = e.generate(&[0, 5, 9], 6, Sampling::Greedy, 1).unwrap();
        assert_eq!(a, b, "bf16 decode is deterministic across slot reuse");
        assert_eq!(a.len(), 6);
    }

    #[test]
    fn killed_lane_requeues_and_retries_token_identically() {
        // stochastic sampling so the test proves the *stream* is
        // preserved across the kill, not just the argmax
        let sampling = Sampling::TopK { k: 8, temperature: 0.9 };
        let mut oracle = ServeEngine::new(tiny(), 2, 16);
        let want = oracle.generate(&[0, 5, 9], 6, sampling, 42).unwrap();

        let mut e = ServeEngine::new(tiny(), 2, 16);
        e.arm_faults(FaultPlan::parse("lane0@3", 0).unwrap());
        let got = e.generate(&[0, 5, 9], 6, sampling, 42).unwrap();
        assert_eq!(got, want, "requeued retry is token-identical to the unfaulted run");
        assert_eq!(e.fault_stats().lane_kills, 1);
        assert_eq!(e.requeues(), 1);
    }

    #[test]
    fn stall_fault_storms_the_deadline() {
        use super::super::scheduler::CompletionStatus;
        let mut e = ServeEngine::new(tiny(), 1, 16);
        e.configure_limits(8, Some(10));
        e.arm_faults(FaultPlan::parse("stall@2", 0).unwrap());
        e.submit(&[1, 2], 8, Sampling::Greedy, 0).unwrap();
        e.submit(&[3], 8, Sampling::Greedy, 1).unwrap();
        let done = e.run_until_idle();
        assert_eq!(e.fault_stats().stalls, 1);
        assert_eq!(done.len(), 2);
        assert!(
            done.iter().all(|c| c.status == CompletionStatus::TimedOut),
            "the clock jump expires the active and the queued request together"
        );
        assert_eq!(e.timed_out(), 2);
    }

    #[test]
    fn corrupt_reload_falls_back_through_the_chain_with_typed_error() {
        use crate::train::checkpoint::{save_weights, CkptError};
        let m = tiny();
        let dir = std::env::temp_dir().join("lotus_serve_reload");
        std::fs::create_dir_all(&dir).unwrap();
        let newest = dir.join("ck-10.ckpt");
        let older = dir.join("ck-5.ckpt");
        save_weights(&newest, 10, &m.params).unwrap();
        save_weights(&older, 5, &m.params).unwrap();

        // a clean reload serves the newest container
        let mut e = ServeEngine::new(tiny(), 1, 16);
        assert_eq!(e.reload_from_chain(&[&newest, &older]).unwrap(), 10);

        // sole candidate mangled: a typed diagnosis, not a panic
        let mut e = ServeEngine::new(tiny(), 1, 16);
        e.arm_faults(FaultPlan::parse("ckpt_corrupt@load", 0).unwrap());
        let err = e.reload_from_chain(&[&newest]).unwrap_err();
        assert!(err.downcast_ref::<CkptError>().is_some(), "typed diagnosis: {err:#}");

        // with a fallback the chain recovers on the older container
        let mut e = ServeEngine::new(tiny(), 1, 16);
        e.arm_faults(FaultPlan::parse("ckpt_corrupt@load", 0).unwrap());
        let step = e.reload_from_chain(&[&newest, &older]).unwrap();
        assert_eq!(step, 5, "served the CRC-verified fallback");
        assert_eq!(e.fault_stats().ckpt_corruptions, 1, "the load fault fires exactly once");
        let _ = std::fs::remove_file(newest);
        let _ = std::fs::remove_file(older);
    }

    #[test]
    fn more_requests_than_slots_all_complete() {
        let mut e = ServeEngine::new(tiny(), 2, 16);
        let mut ids = Vec::new();
        for i in 0..5u64 {
            ids.push(e.submit(&[0, (i + 1) as u32], 1 + i as usize, Sampling::Greedy, i).unwrap());
        }
        let mut done = e.run_until_idle();
        assert_eq!(done.len(), 5);
        done.sort_by_key(|c| c.id);
        for (c, id) in done.iter().zip(&ids) {
            assert_eq!(c.id, *id);
            assert_eq!(c.tokens.len(), 1 + c.id as usize);
        }
    }
}
