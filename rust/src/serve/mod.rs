//! Inference serving: KV-cached incremental decoding with continuous
//! batching over trained checkpoints.
//!
//! This closes the train→serve loop: any checkpoint written by the sim,
//! dist or PJRT trainers (or a weights-only file from
//! [`crate::train::checkpoint::save_weights`]) loads into a
//! [`ServeEngine`], which drives [`crate::sim::SimModel::forward_step`]
//! — per-sequence K/V caches, Workspace-backed scratch, one token per
//! occupied slot per engine step — under a slot-based
//! continuous-batching [`Scheduler`].
//!
//! The contract throughout is bit-determinism: prefill + incremental
//! decode reproduces the full-context forward exactly, at any
//! `LOTUS_THREADS` and any batch composition, and sampling
//! ([`sample`]) is greedy or seeded top-k with a per-request RNG
//! stream. Throughput (prefill vs decode tokens/s, batched-vs-single
//! speedup) is tracked by `benches/serve.rs` in `BENCH_serve.json`; the
//! CLI entry points are `lotus generate` (one-shot) and `lotus serve`
//! (synthetic trace with latency percentiles).

pub mod engine;
pub mod sample;
pub mod scheduler;
pub mod trace;

pub use engine::ServeEngine;
pub use sample::Sampling;
pub use scheduler::{Completion, CompletionStatus, QueueFull, Request, Scheduler};
pub use trace::{synthetic_trace, LatencySummary, TraceCfg};
