//! Synthetic request traces and latency accounting for `lotus serve`
//! and `benches/serve.rs`.
//!
//! Prompts are drawn from the same Markov corpus the trainers consume
//! ([`CorpusGen`]), so a served checkpoint sees in-distribution text;
//! prompt lengths and generation budgets vary per request (seeded), so
//! the continuous-batching scheduler actually has to admit and retire
//! mid-flight rather than running in lockstep.

use super::scheduler::{Completion, CompletionStatus};
use crate::data::corpus::CorpusGen;
use crate::util::Rng;

/// Shape of a synthetic serving workload.
#[derive(Clone, Copy, Debug)]
pub struct TraceCfg {
    /// Number of requests.
    pub requests: usize,
    /// Maximum prompt length (per-request lengths vary in
    /// `[max(1, prompt_len/2), prompt_len]`).
    pub prompt_len: usize,
    /// Maximum generation budget (varies in `[max(1, max_new/2),
    /// max_new]`).
    pub max_new: usize,
    /// Model vocabulary (prompts stay inside it).
    pub vocab: usize,
    /// Corpus coherence (same knob as training).
    pub coherence: f64,
    pub seed: u64,
}

/// Build the trace: one `(prompt, max_new)` per request, deterministic
/// in `cfg.seed`.
pub fn synthetic_trace(cfg: &TraceCfg) -> Vec<(Vec<u32>, usize)> {
    assert!(cfg.requests >= 1 && cfg.prompt_len >= 1 && cfg.max_new >= 1);
    let mut gen = CorpusGen::new(cfg.vocab, cfg.seed, cfg.coherence);
    let mut rng = Rng::new(cfg.seed ^ 0x5E27E);
    let mut out = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let plen = rng.range(cfg.prompt_len.div_ceil(2).max(1), cfg.prompt_len + 1);
        let new = rng.range(cfg.max_new.div_ceil(2).max(1), cfg.max_new + 1);
        let prompt: Vec<u32> = (0..plen).map(|_| gen.next_token()).collect();
        out.push((prompt, new));
    }
    out
}

/// Percentile of an ascending-sorted slice (`p` in 0..=100), linearly
/// interpolated between the two enclosing ranks (the numpy `linear`
/// convention). The old nearest-rank truncation made p50 of `[1, 2]`
/// read 1.0 — a half-sample bias that inflated small-trace jitter.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Latency/throughput digest of a finished trace. Degradation outcomes
/// (PR 6) are first-class: timed-out retirements are counted separately
/// and excluded from the latency percentiles (their partial latencies
/// would read as impossibly good), and shed submissions ride along so
/// one struct tells the whole overload story.
#[derive(Clone, Copy, Debug)]
pub struct LatencySummary {
    /// Requests that generated their full token budget.
    pub completed: usize,
    /// Requests retired by deadline expiry.
    pub timed_out: usize,
    /// Submissions rejected by the bounded queue.
    pub shed: u64,
    pub generated_tokens: u64,
    pub wall_s: f64,
    /// Generated tokens per wall-clock second across the whole trace.
    pub tokens_per_s: f64,
    pub ttft_p50_s: f64,
    pub ttft_p90_s: f64,
    pub ttft_p99_s: f64,
    pub total_p50_s: f64,
    pub total_p90_s: f64,
    pub total_p99_s: f64,
}

impl LatencySummary {
    /// Digest `completions` measured over `wall_s` seconds; `shed` is
    /// the engine's shed-submission count for the same window.
    pub fn digest(completions: &[Completion], wall_s: f64, shed: u64) -> Self {
        let ok: Vec<&Completion> =
            completions.iter().filter(|c| c.status == CompletionStatus::Ok).collect();
        let mut ttft: Vec<f64> = ok.iter().map(|c| c.ttft_s).collect();
        let mut total: Vec<f64> = ok.iter().map(|c| c.total_s).collect();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        total.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // partial tokens from timed-out requests were still generated
        let generated = completions.iter().map(|c| c.tokens.len() as u64).sum::<u64>();
        LatencySummary {
            completed: ok.len(),
            timed_out: completions.len() - ok.len(),
            shed,
            generated_tokens: generated,
            wall_s,
            tokens_per_s: generated as f64 / wall_s.max(1e-12),
            ttft_p50_s: percentile(&ttft, 50.0),
            ttft_p90_s: percentile(&ttft, 90.0),
            ttft_p99_s: percentile(&ttft, 99.0),
            total_p50_s: percentile(&total, 50.0),
            total_p90_s: percentile(&total, 90.0),
            total_p99_s: percentile(&total, 99.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_in_bounds() {
        let cfg = TraceCfg {
            requests: 12,
            prompt_len: 10,
            max_new: 8,
            vocab: 64,
            coherence: 0.5,
            seed: 9,
        };
        let a = synthetic_trace(&cfg);
        let b = synthetic_trace(&cfg);
        assert_eq!(a, b, "same seed, same trace");
        assert_eq!(a.len(), 12);
        for (prompt, new) in &a {
            assert!((5..=10).contains(&prompt.len()));
            assert!((4..=8).contains(new));
            assert!(prompt.iter().all(|&t| (t as usize) < 64));
        }
    }

    #[test]
    fn percentile_interpolates_between_ranks() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!((percentile(&xs, 90.0) - 3.7).abs() < 1e-12);
        assert!((percentile(&xs, 99.0) - 3.97).abs() < 1e-12);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        // median of two samples is their midpoint, not the lower one
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.5);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
