//! Deterministic token sampling for the serving engine.
//!
//! Two strategies, both bit-reproducible: greedy argmax (ties break to
//! the lowest token id) and seeded top-k (deterministic k-largest
//! selection, f64 softmax over the survivors, one [`Rng`] draw). Every
//! request carries its own RNG stream, so a request's tokens never
//! depend on what else shares its batch — the same independence
//! property the decode kernels guarantee for the logits.

use crate::util::Rng;

/// Sampling strategy for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax; ties break to the lowest token id. Needs no RNG — the
    /// golden-token CI smoke and the bit-identity tests use this.
    Greedy,
    /// Sample from the `k` highest-logit tokens after a temperature
    /// rescale (seeded per request).
    TopK { k: usize, temperature: f32 },
}

impl Sampling {
    /// Parse the CLI spelling: `--top-k 0` (or omitted) means greedy.
    pub fn from_cli(top_k: usize, temperature: f32) -> Sampling {
        if top_k == 0 {
            Sampling::Greedy
        } else {
            Sampling::TopK { k: top_k, temperature }
        }
    }
}

/// Argmax with lowest-index tie-break.
pub fn argmax(logits: &[f32]) -> u32 {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    let mut best_v = logits[0];
    for (i, &v) in logits.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best as u32
}

/// Seeded top-k: pick the k largest logits (repeated max scan — ties
/// break to the lowest index, so the selection is deterministic),
/// softmax over them in f64 with the max subtracted, and draw once from
/// `rng`. `k = 1` reduces to [`argmax`]; temperature is clamped away
/// from zero.
pub fn top_k(logits: &[f32], k: usize, temperature: f32, rng: &mut Rng) -> u32 {
    let k = k.clamp(1, logits.len());
    let temp = temperature.max(1e-6) as f64;
    // k-largest indices, best first
    let mut picked: Vec<usize> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best: Option<(usize, f32)> = None;
        for (i, &v) in logits.iter().enumerate() {
            if picked.contains(&i) {
                continue;
            }
            if best.map_or(true, |(_, bv)| v > bv) {
                best = Some((i, v));
            }
        }
        picked.push(best.expect("k clamped to len").0);
    }
    // softmax over the survivors (picked[0] holds the max)
    let maxv = logits[picked[0]] as f64;
    let mut weights: Vec<f64> = Vec::with_capacity(k);
    let mut total = 0.0f64;
    for &i in &picked {
        let w = ((logits[i] as f64 - maxv) / temp).exp();
        weights.push(w);
        total += w;
    }
    let mut x = rng.f64() * total;
    for (wi, &i) in picked.iter().enumerate() {
        x -= weights[wi];
        if x <= 0.0 {
            return i as u32;
        }
    }
    picked[k - 1] as u32
}

/// Draw one token under `s` from a logits row.
pub fn draw(logits: &[f32], s: &Sampling, rng: &mut Rng) -> u32 {
    match *s {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => top_k(logits, k, temperature, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[0.5, 2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn top_k_one_is_greedy() {
        let logits = [0.1, 4.0, -2.0, 3.9];
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            assert_eq!(top_k(&logits, 1, 1.0, &mut rng), argmax(&logits));
        }
    }

    #[test]
    fn top_k_is_seed_deterministic_and_stays_in_the_top_set() {
        let logits: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let a: Vec<u32> = {
            let mut rng = Rng::new(42);
            (0..50).map(|_| top_k(&logits, 4, 0.8, &mut rng)).collect()
        };
        let b: Vec<u32> = {
            let mut rng = Rng::new(42);
            (0..50).map(|_| top_k(&logits, 4, 0.8, &mut rng)).collect()
        };
        assert_eq!(a, b, "same seed, same stream");
        // every draw must come from the 4 largest logits
        let mut order: Vec<usize> = (0..logits.len()).collect();
        order.sort_by(|&x, &y| logits[y].partial_cmp(&logits[x]).unwrap().then(x.cmp(&y)));
        let top: Vec<u32> = order[..4].iter().map(|&i| i as u32).collect();
        assert!(a.iter().all(|t| top.contains(t)), "{a:?} outside top set {top:?}");
        // a different seed should eventually differ
        let mut rng = Rng::new(43);
        let c: Vec<u32> = (0..50).map(|_| top_k(&logits, 4, 0.8, &mut rng)).collect();
        assert_ne!(a, c, "independent seeds gave identical streams");
    }

    #[test]
    fn from_cli_maps_zero_to_greedy() {
        assert_eq!(Sampling::from_cli(0, 1.0), Sampling::Greedy);
        assert_eq!(Sampling::from_cli(5, 0.7), Sampling::TopK { k: 5, temperature: 0.7 });
    }
}
