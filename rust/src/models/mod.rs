//! Model shape registries: which weight matrices exist, which are
//! candidates for low-rank projection (GaLore/Lotus project the 2-D
//! matmul weights, not norms/embedding vectors), and parameter counts.
//!
//! Two families:
//! * [`LlamaConfig`] — decoder-only LLaMA-style transformer used for the
//!   Table 1 pre-training experiments and the E2E PJRT driver.
//! * [`EncoderConfig`] — RoBERTa-like bidirectional encoder for the
//!   Table 2 GLUE fine-tuning experiments.
//!
//! The *paper-size* presets mirror Table 1's (r, d_model) rows for the
//! analytic memory model; the *scaled* presets are what we actually
//! train on this testbed (DESIGN.md §2 substitutions).

/// One named weight matrix in a model.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    /// True if the low-rank methods project this matrix (all 2-D matmul
    /// weights; embeddings/norm vectors are excluded, as in GaLore).
    pub project: bool,
}

/// Anything that can enumerate its weight matrices.
pub trait Shaped {
    fn matrices(&self) -> Vec<MatrixSpec>;
    /// Parameters living in vectors (norm gains, biases) — always
    /// trained full-rank.
    fn vector_params(&self) -> usize;
    fn param_count(&self) -> u64 {
        self.matrices().iter().map(|m| (m.rows * m.cols) as u64).sum::<u64>()
            + self.vector_params() as u64
    }
}

/// Generic model shape handle used by [`crate::memcount`].
#[derive(Clone, Debug)]
pub struct ModelShape {
    pub name: String,
    mats: Vec<MatrixSpec>,
    vecs: usize,
}

impl ModelShape {
    pub fn new(name: impl Into<String>, mats: Vec<MatrixSpec>, vecs: usize) -> Self {
        ModelShape { name: name.into(), mats, vecs }
    }

    pub fn matrices(&self) -> &[MatrixSpec] {
        &self.mats
    }

    pub fn vector_params(&self) -> usize {
        self.vecs
    }

    pub fn param_count(&self) -> u64 {
        self.mats.iter().map(|m| (m.rows * m.cols) as u64).sum::<u64>() + self.vecs as u64
    }
}

/// LLaMA-family decoder config (RMSNorm + SwiGLU + RoPE, tied embedding).
#[derive(Clone, Copy, Debug)]
pub struct LlamaConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl LlamaConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Enumerate weight matrices (per layer: q,k,v,o + SwiGLU w1,w2,w3;
    /// plus tied embedding).
    pub fn shape(&self, name: &str) -> ModelShape {
        let d = self.d_model;
        let f = self.d_ff;
        let mut mats = Vec::new();
        mats.push(MatrixSpec {
            name: "embed".into(),
            rows: self.vocab,
            cols: d,
            project: false, // GaLore leaves embeddings full-rank
        });
        for l in 0..self.n_layers {
            for (nm, r, c) in [
                ("wq", d, d),
                ("wk", d, d),
                ("wv", d, d),
                ("wo", d, d),
                ("w1", d, f), // gate
                ("w3", d, f), // up
                ("w2", f, d), // down
            ] {
                mats.push(MatrixSpec {
                    name: format!("layer{l}.{nm}"),
                    rows: r,
                    cols: c,
                    project: true,
                });
            }
        }
        // vector params: 2 RMSNorm gains per layer + final norm
        let vecs = (2 * self.n_layers + 1) * d;
        ModelShape::new(name, mats, vecs)
    }

    pub fn param_count(&self) -> u64 {
        self.shape("tmp").param_count()
    }
}

/// RoBERTa-like encoder config (LayerNorm + GELU MLP, learned positions,
/// classification head).
#[derive(Clone, Copy, Debug)]
pub struct EncoderConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub n_classes: usize,
}

impl EncoderConfig {
    pub fn shape(&self, name: &str) -> ModelShape {
        let d = self.d_model;
        let f = self.d_ff;
        let mut mats = Vec::new();
        mats.push(MatrixSpec { name: "embed".into(), rows: self.vocab, cols: d, project: false });
        mats.push(MatrixSpec { name: "pos".into(), rows: self.seq_len, cols: d, project: false });
        for l in 0..self.n_layers {
            for (nm, r, c) in [
                ("wq", d, d),
                ("wk", d, d),
                ("wv", d, d),
                ("wo", d, d),
                ("ff1", d, f),
                ("ff2", f, d),
            ] {
                mats.push(MatrixSpec {
                    name: format!("layer{l}.{nm}"),
                    rows: r,
                    cols: c,
                    project: true,
                });
            }
        }
        mats.push(MatrixSpec {
            name: "classifier".into(),
            rows: d,
            cols: self.n_classes,
            project: false, // tiny head trained full-rank
        });
        // LayerNorm gain+bias ×2 per layer + final + biases ignored
        let vecs = (4 * self.n_layers + 2) * d;
        ModelShape::new(name, mats, vecs)
    }

    pub fn param_count(&self) -> u64 {
        self.shape("tmp").param_count()
    }
}

/// Named presets.
pub mod presets {
    use super::*;

    // ----- paper-size shapes (analytic memory model only) -----

    /// Table 1's 60M row: d=256 in the paper's r/d column ⇒ LLaMA-60M
    /// (the GaLore 60M config: d=512, 8 layers — the table's r/d row
    /// lists r=128/d=256 which corresponds to attention-head granularity;
    /// we use the GaLore public config).
    pub fn llama_paper_60m() -> ModelShape {
        LlamaConfig { vocab: 32000, d_model: 512, n_layers: 8, n_heads: 8, d_ff: 1376, seq_len: 256 }
            .shape("llama-60m")
    }

    pub fn llama_paper_130m() -> ModelShape {
        LlamaConfig { vocab: 32000, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 2048, seq_len: 256 }
            .shape("llama-130m")
    }

    pub fn llama_paper_350m() -> ModelShape {
        LlamaConfig { vocab: 32000, d_model: 1024, n_layers: 24, n_heads: 16, d_ff: 2736, seq_len: 256 }
            .shape("llama-350m")
    }

    pub fn llama_paper_1b() -> ModelShape {
        LlamaConfig { vocab: 32000, d_model: 2048, n_layers: 24, n_heads: 32, d_ff: 5461, seq_len: 256 }
            .shape("llama-1b")
    }

    pub fn llama_paper_3b() -> ModelShape {
        LlamaConfig { vocab: 32000, d_model: 2560, n_layers: 32, n_heads: 32, d_ff: 6848, seq_len: 256 }
            .shape("llama-3b")
    }

    /// RoBERTa-Base shape for the Table 2 memory column.
    pub fn roberta_base() -> ModelShape {
        EncoderConfig {
            vocab: 50265,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_ff: 3072,
            seq_len: 512,
            n_classes: 2,
        }
        .shape("roberta-base")
    }

    // ----- scaled shapes actually trained on this testbed -----

    /// ~1.1M params: unit tests and fast iteration.
    pub fn llama_tiny_cfg() -> LlamaConfig {
        LlamaConfig { vocab: 512, d_model: 128, n_layers: 2, n_heads: 4, d_ff: 344, seq_len: 64 }
    }

    /// ~11M params: Table 1 sim-scale runs.
    pub fn llama_mini_cfg() -> LlamaConfig {
        LlamaConfig { vocab: 2048, d_model: 256, n_layers: 4, n_heads: 8, d_ff: 688, seq_len: 128 }
    }

    /// ~22M params: E2E PJRT pre-training driver default.
    pub fn llama_20m_cfg() -> LlamaConfig {
        LlamaConfig { vocab: 4096, d_model: 384, n_layers: 6, n_heads: 8, d_ff: 1024, seq_len: 128 }
    }

    /// ~110M params: the "~100M transformer" config for the E2E proof run.
    pub fn llama_100m_cfg() -> LlamaConfig {
        LlamaConfig { vocab: 8192, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 2048, seq_len: 128 }
    }

    /// Scaled encoder for the GLUE-sim fine-tuning runs (~0.3M params —
    /// sized so the full 8-task × 6-method × 2-rank Table 2 sweep runs
    /// in minutes on this CPU testbed).
    pub fn encoder_small_cfg() -> EncoderConfig {
        EncoderConfig {
            vocab: 512,
            d_model: 64,
            n_layers: 3,
            n_heads: 4,
            d_ff: 160,
            seq_len: 32,
            n_classes: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn paper_param_counts_in_band() {
        // these names come from GaLore's public configs; counts should
        // land near the nominal sizes
        let m60 = llama_paper_60m().param_count();
        assert!((40e6..80e6).contains(&(m60 as f64)), "60M preset = {m60}");
        let m130 = llama_paper_130m().param_count();
        assert!((100e6..170e6).contains(&(m130 as f64)), "130M preset = {m130}");
        let m1b = llama_paper_1b().param_count();
        assert!((0.8e9..1.6e9).contains(&(m1b as f64)), "1B preset = {m1b}");
    }

    #[test]
    fn roberta_base_is_125m() {
        let n = roberta_base().param_count();
        assert!((100e6..160e6).contains(&(n as f64)), "roberta = {n}");
    }

    #[test]
    fn scaled_configs_sizes() {
        let t = llama_tiny_cfg().param_count();
        assert!((0.3e6..3e6).contains(&(t as f64)), "tiny = {t}");
        let h = llama_100m_cfg().param_count();
        assert!((80e6..140e6).contains(&(h as f64)), "100m = {h}");
    }

    #[test]
    fn projection_flags() {
        let s = llama_tiny_cfg().shape("t");
        assert!(!s.matrices()[0].project, "embedding not projected");
        assert!(s.matrices()[1..].iter().all(|m| m.project));
    }

    #[test]
    fn head_dim_divides() {
        let c = llama_mini_cfg();
        assert_eq!(c.head_dim() * c.n_heads, c.d_model);
    }
}
