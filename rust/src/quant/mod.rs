//! Quantization engine: bf16/int8 codecs for the dist wire, the
//! serving K/V cache, and optimizer moments.
//!
//! Three independent surfaces share one [`Codec`] abstraction:
//!
//! * **Wire** (`[quant] wire` / `--wire-dtype`): tree all-reduce
//!   payloads in `dist/comm.rs` are encoded per edge, checksummed over
//!   the quantized bytes, and reduced in f32 at the receiving shard
//!   owner. `CommStats` counts the real encoded bytes.
//! * **KV cache** (`[quant] kv` / `--kv-dtype`): `sim/model.rs` stores
//!   K/V rows as bf16 and dequantizes into `Workspace` scratch on read;
//!   serving memory per slot halves.
//! * **Optimizer state** (`[quant] state` / `--state-dtype`): Adam
//!   moments are snapped to a bf16/int8 grid after every update
//!   ([`MomentQuant`]), behind the `Optimizer`/`OptState` API so
//!   quantized state checkpoints round-trip through the v2 container.
//!
//! Determinism contract: a quantized run need not bit-match f32, but it
//! is bit-identical to itself at any `LOTUS_THREADS` and any worker
//! count, because every codec kernel is a pure function of its input
//! bytes and the wire transform is applied uniformly per tree edge
//! (see `dist/comm.rs`).

pub mod codec;

pub use codec::{Codec, QuantDtype, QuantError};

/// Moment-quantization policy for Adam-family optimizers: after each
/// moment update, `m`/`v` are snapped to this grid so the live state
/// carries only bf16/int8 information. Checkpoints export the
/// dequantized f32 mirror; a restored run therefore resumes from
/// exactly the bytes the uninterrupted run held, and the two stay
/// bit-identical (pinned by `rust/tests/quant.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentQuant {
    Bf16,
    Int8 { block: usize },
}

impl MomentQuant {
    /// The codec implementing this policy.
    pub fn codec(&self) -> Codec {
        match *self {
            MomentQuant::Bf16 => Codec::new(QuantDtype::Bf16, 1),
            MomentQuant::Int8 { block } => Codec::new(QuantDtype::Int8, block),
        }
    }

    /// Snap a moment tensor to the quantized grid in place.
    pub fn apply(&self, xs: &mut [f32]) {
        self.codec().quantize_pooled(xs);
    }

    /// Measured bytes an `n`-element moment tensor occupies on this grid.
    pub fn state_bytes(&self, n: usize) -> usize {
        self.codec().encoded_len(n)
    }

    /// Stable name suffix for method listings ("bf16" / "int8").
    pub fn as_str(&self) -> &'static str {
        match self {
            MomentQuant::Bf16 => "bf16",
            MomentQuant::Int8 { .. } => "int8",
        }
    }
}

/// The `[quant]` config block: one dtype per surface plus the int8
/// scale-block length. Defaults are all-f32 (bit-exact legacy paths).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantCfg {
    /// Dist all-reduce payload dtype (f32 | bf16 | int8).
    pub wire: QuantDtype,
    /// Serving K/V cache dtype (f32 | bf16).
    pub kv: QuantDtype,
    /// Adam moment dtype (f32 | bf16 | int8).
    pub state: QuantDtype,
    /// Elements per int8 scale block (wire and state).
    pub int8_block: usize,
}

impl Default for QuantCfg {
    fn default() -> Self {
        QuantCfg {
            wire: QuantDtype::F32,
            kv: QuantDtype::F32,
            state: QuantDtype::F32,
            int8_block: 64,
        }
    }
}

impl QuantCfg {
    pub fn validate(&self) -> Result<(), String> {
        if self.int8_block == 0 {
            return Err("quant: int8_block must be at least 1".into());
        }
        if self.kv == QuantDtype::Int8 {
            return Err("quant: kv supports f32 or bf16 (int8 K/V is not implemented)".into());
        }
        Ok(())
    }

    /// Codec for dist all-reduce payloads.
    pub fn wire_codec(&self) -> Codec {
        Codec::new(self.wire, self.int8_block)
    }

    /// Moment-quantization policy implied by `state` (None at f32).
    pub fn state_quant(&self) -> Option<MomentQuant> {
        match self.state {
            QuantDtype::F32 => None,
            QuantDtype::Bf16 => Some(MomentQuant::Bf16),
            QuantDtype::Int8 => Some(MomentQuant::Int8 { block: self.int8_block }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_all_f32() {
        let q = QuantCfg::default();
        assert_eq!(q.wire, QuantDtype::F32);
        assert_eq!(q.kv, QuantDtype::F32);
        assert_eq!(q.state, QuantDtype::F32);
        assert!(q.validate().is_ok());
        assert!(q.state_quant().is_none());
    }

    #[test]
    fn validate_rejects_bad_combinations() {
        let mut q = QuantCfg { int8_block: 0, ..QuantCfg::default() };
        assert!(q.validate().is_err());
        q.int8_block = 64;
        q.kv = QuantDtype::Int8;
        assert!(q.validate().is_err());
        q.kv = QuantDtype::Bf16;
        assert!(q.validate().is_ok());
    }

    #[test]
    fn bf16_moment_quant_is_idempotent() {
        // bf16 values round-trip exactly, so re-applying the policy is a
        // no-op. (Int8 makes no such promise: the re-derived block scale
        // can move by an ulp; checkpoint round-trips never rely on it.)
        let mut rng = crate::util::Rng::new(7);
        let xs: Vec<f32> = (0..300).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let q = MomentQuant::Bf16;
        let mut once = xs.clone();
        q.apply(&mut once);
        let mut twice = once.clone();
        q.apply(&mut twice);
        let a: Vec<u32> = once.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = twice.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn state_bytes_track_dtype() {
        let n = 1000usize;
        assert_eq!(MomentQuant::Bf16.state_bytes(n), 2 * n);
        assert_eq!(MomentQuant::Int8 { block: 64 }.state_bytes(n), n + n.div_ceil(64) * 4);
    }
}
