//! The byte codecs behind the quantization engine: bf16 round-trip and
//! blockwise symmetric int8 with per-block f32 scales.
//!
//! Contracts (pinned by `rust/tests/quant.rs`):
//!
//! * **Encode → decode is a pure function of the input bytes.** Same
//!   input slice, same output bytes; same bytes, same decoded floats —
//!   no ambient state, no allocation-order dependence.
//! * **`quantize` ≡ decode∘encode, bit for bit.** The in-place
//!   fixed-point transform and the wire round-trip compute the *same*
//!   arithmetic, so a value that went over the wire equals the value a
//!   local replica produced without a wire (this is what makes the
//!   quantized all-reduce worker-count invariant).
//! * **Rounding is deterministic round-to-nearest-even** (bf16 via
//!   [`crate::tensor::bf16::f32_to_bf16`]; int8 via `f32::round` on the
//!   scaled value, ties away from zero — deterministic either way).
//! * **NaN/Inf are rejected with a typed error** by the int8 encoder
//!   (the block scale would be poisoned); bf16 represents them natively
//!   and passes them through.
//! * **Decoding never panics**, whatever the bytes: a length that does
//!   not match the expected encoded size is a typed
//!   [`QuantError::Malformed`], and any byte *content* of the right
//!   length decodes to some floats (a mangled scale yields garbage
//!   values, caught one layer up by the transfer checksum).

use crate::runtime::pool;
use crate::tensor::bf16::{bf16_to_f32, f32_to_bf16, quantize_int8_blockwise, quantize_slice};

/// Element dtype for a quantized surface (wire payloads, K/V rows,
/// optimizer moments).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantDtype {
    /// No quantization: 4 bytes/element, bit-exact.
    F32,
    /// bfloat16 round-trip: 2 bytes/element, round-to-nearest-even.
    Bf16,
    /// Blockwise symmetric int8: 1 byte/element + one f32 absmax-derived
    /// scale per block.
    Int8,
}

impl QuantDtype {
    /// Stable lower-case name (config/CLI/telemetry).
    pub fn as_str(self) -> &'static str {
        match self {
            QuantDtype::F32 => "f32",
            QuantDtype::Bf16 => "bf16",
            QuantDtype::Int8 => "int8",
        }
    }

    /// Analytic bytes per element (int8 excludes the per-block scales;
    /// use [`Codec::encoded_len`] for exact wire sizes).
    pub fn element_bytes(self) -> u64 {
        match self {
            QuantDtype::F32 => 4,
            QuantDtype::Bf16 => 2,
            QuantDtype::Int8 => 1,
        }
    }
}

impl std::str::FromStr for QuantDtype {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "f32" | "fp32" | "float32" => Ok(QuantDtype::F32),
            "bf16" | "bfloat16" => Ok(QuantDtype::Bf16),
            "int8" | "i8" => Ok(QuantDtype::Int8),
            other => Err(format!("unknown dtype '{other}' (expected f32, bf16 or int8)")),
        }
    }
}

/// Typed codec failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum QuantError {
    /// The int8 encoder met a NaN/Inf at this element index; the block
    /// scale would be poisoned, so the payload is rejected instead.
    NonFinite { index: usize },
    /// The byte buffer's length does not match the encoded size implied
    /// by the output length (truncated / overlong payload).
    Malformed { expected: usize, got: usize },
}

impl std::fmt::Display for QuantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuantError::NonFinite { index } => {
                write!(f, "non-finite value at element {index} cannot be int8-quantized")
            }
            QuantError::Malformed { expected, got } => {
                write!(f, "malformed payload: expected {expected} bytes, got {got}")
            }
        }
    }
}

impl std::error::Error for QuantError {}

/// A concrete encoding: dtype + int8 block length. Copy-cheap; every
/// method is a pure function of its inputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Codec {
    pub dtype: QuantDtype,
    /// Elements per int8 scale block (ignored by f32/bf16).
    pub block: usize,
}

impl Codec {
    pub fn new(dtype: QuantDtype, block: usize) -> Codec {
        assert!(block >= 1, "int8 block must be at least 1");
        Codec { dtype, block }
    }

    /// Exact encoded byte length of an `n`-element payload.
    pub fn encoded_len(&self, n: usize) -> usize {
        match self.dtype {
            QuantDtype::F32 => 4 * n,
            QuantDtype::Bf16 => 2 * n,
            QuantDtype::Int8 => n + n.div_ceil(self.block) * 4,
        }
    }

    /// Encode `src` into `out` (cleared first). Int8 rejects NaN/Inf
    /// with [`QuantError::NonFinite`]; f32/bf16 cannot fail.
    pub fn encode_into(&self, src: &[f32], out: &mut Vec<u8>) -> Result<(), QuantError> {
        out.clear();
        out.reserve(self.encoded_len(src.len()));
        match self.dtype {
            QuantDtype::F32 => {
                for x in src {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            QuantDtype::Bf16 => {
                for x in src {
                    out.extend_from_slice(&f32_to_bf16(*x).to_le_bytes());
                }
            }
            QuantDtype::Int8 => {
                if let Some(i) = src.iter().position(|x| !x.is_finite()) {
                    return Err(QuantError::NonFinite { index: i });
                }
                for chunk in src.chunks(self.block) {
                    let absmax = chunk.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let scale = if absmax == 0.0 { 0.0 } else { absmax / 127.0 };
                    out.extend_from_slice(&scale.to_le_bytes());
                    if scale == 0.0 {
                        let zeroed = out.len() + chunk.len();
                        out.resize(zeroed, 0);
                    } else {
                        for x in chunk {
                            let q = (*x / scale).round().clamp(-127.0, 127.0) as i8;
                            out.push(q as u8);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Decode `bytes` into `out` (fully overwritten). The byte length
    /// must equal [`Codec::encoded_len`]\(out.len()) — anything else is
    /// a typed [`QuantError::Malformed`], never a panic. Byte *content*
    /// is unconstrained: arbitrary bytes decode to some floats.
    pub fn decode_into(&self, bytes: &[u8], out: &mut [f32]) -> Result<(), QuantError> {
        let expected = self.encoded_len(out.len());
        if bytes.len() != expected {
            return Err(QuantError::Malformed { expected, got: bytes.len() });
        }
        match self.dtype {
            QuantDtype::F32 => {
                for (x, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                    *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
            }
            QuantDtype::Bf16 => {
                for (x, b) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                    *x = bf16_to_f32(u16::from_le_bytes([b[0], b[1]]));
                }
            }
            QuantDtype::Int8 => {
                let mut cursor = bytes;
                for chunk in out.chunks_mut(self.block) {
                    let (head, rest) = cursor.split_at(4 + chunk.len());
                    cursor = rest;
                    let scale = f32::from_le_bytes([head[0], head[1], head[2], head[3]]);
                    for (x, q) in chunk.iter_mut().zip(&head[4..]) {
                        *x = (*q as i8) as f32 * scale;
                    }
                }
            }
        }
        Ok(())
    }

    /// In-place fixed-point transform: every element becomes the value
    /// it would hold after one encode → decode round trip, computed with
    /// the *identical* arithmetic (asserted by `rust/tests/quant.rs`).
    /// F32 is the identity. Tolerates NaN/Inf (f32/bf16 pass them
    /// through; an int8 block holding one decodes from whatever scale
    /// the fold produced — callers that must reject them encode instead).
    pub fn quantize(&self, xs: &mut [f32]) {
        match self.dtype {
            QuantDtype::F32 => {}
            QuantDtype::Bf16 => quantize_slice(xs),
            QuantDtype::Int8 => {
                quantize_int8_blockwise(xs, self.block);
            }
        }
    }

    /// Pooled [`Codec::quantize`]: int8-block-aligned chunks fan across
    /// the worker pool ([`pool::effective`], so nested callers degrade
    /// to serial). Blocks never straddle a chunk boundary and bf16 is
    /// elementwise, so the result is bit-identical to the serial
    /// transform at any `LOTUS_THREADS`.
    pub fn quantize_pooled(&self, xs: &mut [f32]) {
        if self.dtype == QuantDtype::F32 {
            return;
        }
        let p = pool::effective();
        let threads = p.threads();
        if threads <= 1 || xs.len() <= 4 * self.block {
            self.quantize(xs);
            return;
        }
        let blocks = xs.len().div_ceil(self.block);
        let per = blocks.div_ceil(threads) * self.block;
        let mut jobs: Vec<&mut [f32]> = xs.chunks_mut(per).collect();
        p.par_items_mut(&mut jobs, |_, chunk| self.quantize(chunk));
    }

    /// Pooled [`Codec::encode_into`]: the output buffer is sized
    /// exactly, split at int8-block-aligned offsets, and the chunk pairs
    /// fan across the pool. Bit-identical to the serial encoder at any
    /// thread count (blocks never straddle a chunk, so per-block scales
    /// are computed from exactly the serial operand sets).
    pub fn encode_into_pooled(&self, src: &[f32], out: &mut Vec<u8>) -> Result<(), QuantError> {
        let p = pool::effective();
        let threads = p.threads();
        if threads <= 1 || src.len() <= 4 * self.block {
            return self.encode_into(src, out);
        }
        if self.dtype == QuantDtype::Int8 {
            if let Some(i) = src.iter().position(|x| !x.is_finite()) {
                return Err(QuantError::NonFinite { index: i });
            }
        }
        out.clear();
        out.resize(self.encoded_len(src.len()), 0);
        let per = src.len().div_ceil(self.block).div_ceil(threads) * self.block;
        let mut jobs: Vec<(&[f32], &mut [u8])> = Vec::with_capacity(threads);
        let mut rest_src = src;
        let mut rest_out = &mut out[..];
        while !rest_src.is_empty() {
            let take = per.min(rest_src.len());
            let (s, st) = rest_src.split_at(take);
            let (o, ot) = std::mem::take(&mut rest_out).split_at_mut(self.encoded_len(take));
            rest_src = st;
            rest_out = ot;
            jobs.push((s, o));
        }
        p.par_items_mut(&mut jobs, |_, job| {
            let mut buf = Vec::with_capacity(job.1.len());
            // non-finite values were screened above, so the per-chunk
            // encode cannot fail
            let _ = self.encode_into(job.0, &mut buf);
            job.1.copy_from_slice(&buf);
        });
        Ok(())
    }

    /// Pooled [`Codec::decode_into`]: the byte buffer is split at the
    /// same block-aligned offsets as the pooled encoder and decoded
    /// chunkwise. Same typed errors as the serial decoder, never panics.
    pub fn decode_into_pooled(&self, bytes: &[u8], out: &mut [f32]) -> Result<(), QuantError> {
        let p = pool::effective();
        let threads = p.threads();
        if threads <= 1 || out.len() <= 4 * self.block {
            return self.decode_into(bytes, out);
        }
        let expected = self.encoded_len(out.len());
        if bytes.len() != expected {
            return Err(QuantError::Malformed { expected, got: bytes.len() });
        }
        let per = out.len().div_ceil(self.block).div_ceil(threads) * self.block;
        let mut jobs: Vec<(&[u8], &mut [f32])> = Vec::with_capacity(threads);
        let mut rest_bytes = bytes;
        let mut rest_out = out;
        while !rest_out.is_empty() {
            let take = per.min(rest_out.len());
            let (o, ot) = std::mem::take(&mut rest_out).split_at_mut(take);
            let (b, bt) = rest_bytes.split_at(self.encoded_len(take));
            rest_out = ot;
            rest_bytes = bt;
            jobs.push((b, o));
        }
        p.par_items_mut(&mut jobs, |_, job| {
            // lengths match by construction, so the chunk decode cannot fail
            let _ = self.decode_into(job.0, job.1);
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn f32_roundtrip_is_exact() {
        let c = Codec::new(QuantDtype::F32, 64);
        let xs = random_vec(37, 1);
        let mut bytes = Vec::new();
        c.encode_into(&xs, &mut bytes).unwrap();
        assert_eq!(bytes.len(), c.encoded_len(37));
        let mut back = vec![0.0f32; 37];
        c.decode_into(&bytes, &mut back).unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn bf16_roundtrip_matches_scalar_kernel() {
        let c = Codec::new(QuantDtype::Bf16, 64);
        let xs = random_vec(129, 2);
        let mut bytes = Vec::new();
        c.encode_into(&xs, &mut bytes).unwrap();
        let mut back = vec![0.0f32; xs.len()];
        c.decode_into(&bytes, &mut back).unwrap();
        for (x, b) in xs.iter().zip(&back) {
            assert_eq!(crate::tensor::bf16::quantize_bf16(*x), *b);
        }
    }

    #[test]
    fn quantize_equals_decode_of_encode_bitwise() {
        for dtype in [QuantDtype::F32, QuantDtype::Bf16, QuantDtype::Int8] {
            for n in [1usize, 7, 64, 65, 300] {
                let c = Codec::new(dtype, 64);
                let xs = random_vec(n, 3 + n as u64);
                let mut bytes = Vec::new();
                c.encode_into(&xs, &mut bytes).unwrap();
                let mut decoded = vec![0.0f32; n];
                c.decode_into(&bytes, &mut decoded).unwrap();
                let mut inplace = xs.clone();
                c.quantize(&mut inplace);
                let db: Vec<u32> = decoded.iter().map(|x| x.to_bits()).collect();
                let ib: Vec<u32> = inplace.iter().map(|x| x.to_bits()).collect();
                assert_eq!(db, ib, "dtype {dtype:?} n {n}");
            }
        }
    }

    #[test]
    fn encode_is_deterministic() {
        let c = Codec::new(QuantDtype::Int8, 32);
        let xs = random_vec(100, 5);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        c.encode_into(&xs, &mut a).unwrap();
        c.encode_into(&xs, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn int8_rejects_non_finite() {
        let c = Codec::new(QuantDtype::Int8, 8);
        let mut bytes = Vec::new();
        let mut xs = random_vec(20, 6);
        xs[13] = f32::NAN;
        assert_eq!(c.encode_into(&xs, &mut bytes), Err(QuantError::NonFinite { index: 13 }));
        xs[13] = f32::INFINITY;
        assert_eq!(c.encode_into(&xs, &mut bytes), Err(QuantError::NonFinite { index: 13 }));
    }

    #[test]
    fn decode_length_mismatch_is_typed() {
        let c = Codec::new(QuantDtype::Int8, 8);
        let mut out = vec![0.0f32; 20];
        let err = c.decode_into(&[0u8; 5], &mut out).unwrap_err();
        assert_eq!(err, QuantError::Malformed { expected: c.encoded_len(20), got: 5 });
    }

    #[test]
    fn pooled_variants_match_serial() {
        for dtype in [QuantDtype::Bf16, QuantDtype::Int8] {
            let c = Codec::new(dtype, 16);
            let xs = random_vec(1000, 9);
            let mut serial = Vec::new();
            c.encode_into(&xs, &mut serial).unwrap();
            let mut pooled = Vec::new();
            c.encode_into_pooled(&xs, &mut pooled).unwrap();
            assert_eq!(serial, pooled, "{dtype:?}");
            let mut dec_serial = vec![0.0f32; xs.len()];
            let mut dec_pooled = vec![0.0f32; xs.len()];
            c.decode_into(&serial, &mut dec_serial).unwrap();
            c.decode_into_pooled(&pooled, &mut dec_pooled).unwrap();
            assert_eq!(dec_serial, dec_pooled, "{dtype:?}");
            let mut qs = xs.clone();
            let mut qp = xs.clone();
            c.quantize(&mut qs);
            c.quantize_pooled(&mut qp);
            let sb: Vec<u32> = qs.iter().map(|x| x.to_bits()).collect();
            let pb: Vec<u32> = qp.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, pb, "{dtype:?}");
        }
    }

    #[test]
    fn dtype_parses_and_prints() {
        assert_eq!("f32".parse::<QuantDtype>().unwrap(), QuantDtype::F32);
        assert_eq!("bf16".parse::<QuantDtype>().unwrap(), QuantDtype::Bf16);
        assert_eq!("int8".parse::<QuantDtype>().unwrap(), QuantDtype::Int8);
        assert!("fp8".parse::<QuantDtype>().is_err());
        assert_eq!(QuantDtype::Bf16.as_str(), "bf16");
    }
}
