//! Byte-accounted tree all-reduce over canonical data shards.
//!
//! The reduction tree is indexed by **shard**, never by worker: stride
//! doubling over shard slots (`s[i] += s[i+stride]`) gives a fixed
//! binary combine order that depends only on the shard count, so the
//! summed gradient is bit-identical however many workers execute the
//! shards — the comm-side half of the dist engine's worker-count
//! invariance (the data-side half is [`crate::data::batch::ShardSampler`]).
//!
//! Communication volume is *accounted*, not simulated: an edge of the
//! tree whose two shards live on different workers would cross the wire
//! in a real deployment, so it is charged `payload` bytes for the reduce
//! leg and `payload` again for the broadcast leg of the all-reduce
//! (workers below the root need the reduced result back). Edges interior
//! to one worker are free. [`CommStats`] keeps the low-rank r×n traffic
//! separate from dense traffic so the bench can report the projected
//! all-reduce saving against a dense-gradient baseline — the analytic
//! twin lives in [`crate::memcount::allreduce_layer_bytes`].

/// Shard→worker placement: `shards` canonical shards in contiguous
/// blocks of `shards / workers` per worker (validated divisible).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    pub shards: usize,
    pub workers: usize,
}

impl Topology {
    pub fn new(shards: usize, workers: usize) -> Topology {
        assert!(workers >= 1 && shards >= workers, "need shards >= workers >= 1");
        assert_eq!(shards % workers, 0, "workers must divide shards");
        Topology { shards, workers }
    }

    /// Worker owning shard `s` (contiguous blocks).
    pub fn owner(&self, s: usize) -> usize {
        debug_assert!(s < self.shards);
        s / (self.shards / self.workers)
    }

    /// Number of cross-worker edges in the stride-doubling tree over the
    /// shard slots (`workers - 1` when the per-worker block size is a
    /// power of two, slightly more otherwise).
    pub fn cross_edges(&self) -> u64 {
        let mut edges = 0u64;
        let mut stride = 1;
        while stride < self.shards {
            let mut i = 0;
            while i + stride < self.shards {
                if self.owner(i) != self.owner(i + stride) {
                    edges += 1;
                }
                i += 2 * stride;
            }
            stride *= 2;
        }
        edges
    }
}

/// Tree-reduce `items` (one per shard, index order) by summing the f32
/// buffers `get` exposes into item 0, in stride-doubling order. Returns
/// the number of cross-worker edges (for byte accounting). The combine
/// order depends only on `items.len()`, so the sum in slot 0 is
/// bit-identical for every worker count.
pub fn tree_reduce_with<T, F>(items: &mut [T], mut get: F, topo: &Topology) -> u64
where
    F: FnMut(&mut T) -> &mut [f32],
{
    let n = items.len();
    assert_eq!(n, topo.shards, "one slot per shard");
    let mut edges = 0u64;
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (head, tail) = items.split_at_mut(i + stride);
            let dst = get(&mut head[i]);
            let src = get(&mut tail[0]);
            debug_assert_eq!(dst.len(), src.len(), "shard payloads must agree");
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                *d += *s;
            }
            if topo.owner(i) != topo.owner(i + stride) {
                edges += 1;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    edges
}

/// Measured communication volume of a distributed run.
///
/// `lowrank_bytes` is the steady-state projected-gradient traffic (the
/// r×n payloads that replace dense m×n exchanges); `refresh_dense_bytes`
/// is the dense gradient traffic of consensus-triggered subspace
/// refreshes; `other_dense_bytes` covers tensors that are dense in every
/// method (embedding, norm vectors, full-rank baselines).
/// `dense_equiv_bytes` is what a dense-gradient baseline would have sent
/// for the *projected* matrices over the same steps — the numerator of
/// the reported comm saving.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    pub lowrank_bytes: u64,
    pub refresh_dense_bytes: u64,
    pub other_dense_bytes: u64,
    pub dense_equiv_bytes: u64,
    pub control_bytes: u64,
    pub lowrank_reduces: u64,
    pub dense_reduces: u64,
}

impl CommStats {
    /// Account one projected-gradient all-reduce: `payload` low-rank
    /// bytes per edge per leg (reduce + broadcast), against a dense
    /// baseline of `dense_equiv` bytes per edge per leg.
    pub fn record_lowrank(&mut self, edges: u64, payload: u64, dense_equiv: u64) {
        self.lowrank_bytes += 2 * edges * payload;
        self.dense_equiv_bytes += 2 * edges * dense_equiv;
        self.lowrank_reduces += 1;
    }

    /// Account the dense gradient all-reduce of a consensus refresh (the
    /// dense baseline sends nothing extra on these steps, so no
    /// `dense_equiv` contribution).
    pub fn record_refresh_dense(&mut self, edges: u64, payload: u64) {
        self.refresh_dense_bytes += 2 * edges * payload;
        self.dense_reduces += 1;
    }

    /// Account a dense all-reduce of a tensor that is dense in every
    /// method (embedding, norms, full-rank baseline matrices).
    pub fn record_other_dense(&mut self, edges: u64, payload: u64) {
        self.other_dense_bytes += 2 * edges * payload;
        self.dense_reduces += 1;
    }

    /// Account a consensus vote gather + decision broadcast (1 byte per
    /// shard vote, 1 byte decision, per cross edge).
    pub fn record_votes(&mut self, edges: u64, shards: u64) {
        self.control_bytes += edges * (shards + 1);
    }

    /// All bytes this run actually moved.
    pub fn total_bytes(&self) -> u64 {
        self.lowrank_bytes + self.refresh_dense_bytes + self.other_dense_bytes + self.control_bytes
    }

    /// Dense-baseline / actual ratio for the projected matrices,
    /// including refresh traffic (the honest end-to-end saving).
    pub fn reduction_vs_dense(&self) -> f64 {
        let actual = (self.lowrank_bytes + self.refresh_dense_bytes) as f64;
        if actual == 0.0 {
            return f64::NAN;
        }
        self.dense_equiv_bytes as f64 / actual
    }

    /// Dense-baseline / actual ratio of the steady-state traffic alone
    /// (refresh excluded): structurally `min(m,n) / r` per matrix.
    pub fn steady_reduction_vs_dense(&self) -> f64 {
        if self.lowrank_bytes == 0 {
            return f64::NAN;
        }
        self.dense_equiv_bytes as f64 / self.lowrank_bytes as f64
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.lowrank_bytes += other.lowrank_bytes;
        self.refresh_dense_bytes += other.refresh_dense_bytes;
        self.other_dense_bytes += other.other_dense_bytes;
        self.dense_equiv_bytes += other.dense_equiv_bytes;
        self.control_bytes += other.control_bytes;
        self.lowrank_reduces += other.lowrank_reduces;
        self.dense_reduces += other.dense_reduces;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::Rng;

    fn random_slots(n: usize, len: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| Matrix::randn(1, len, 1.0, &mut rng)).collect()
    }

    #[test]
    fn owner_blocks_are_contiguous_and_cross_edges_count_workers() {
        let t = Topology::new(8, 4);
        assert_eq!((0..8).map(|s| t.owner(s)).collect::<Vec<_>>(), [0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(t.cross_edges(), 3);
        assert_eq!(Topology::new(4, 1).cross_edges(), 0);
        assert_eq!(Topology::new(4, 4).cross_edges(), 3);
        assert_eq!(Topology::new(6, 3).cross_edges(), 2);
    }

    #[test]
    fn tree_sum_is_worker_count_invariant() {
        // The reduced value must depend only on the shard count: reduce
        // the same slots under every divisor worker count and compare
        // bit-for-bit.
        for shards in [1usize, 2, 4, 6, 8] {
            let reference = {
                let mut slots = random_slots(shards, 37, 11);
                tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(shards, 1));
                slots[0].data.clone()
            };
            for workers in 1..=shards {
                if shards % workers != 0 {
                    continue;
                }
                let mut slots = random_slots(shards, 37, 11);
                let topo = Topology::new(shards, workers);
                let edges = tree_reduce_with(&mut slots, |m| &mut m.data[..], &topo);
                assert_eq!(slots[0].data, reference, "shards={shards} workers={workers}");
                assert_eq!(edges, topo.cross_edges(), "edge census");
            }
        }
    }

    #[test]
    fn tree_sum_matches_f32_tree_arithmetic() {
        // 4 slots: ((s0+s1) + (s2+s3)), elementwise in f32.
        let mut slots = random_slots(4, 9, 12);
        let expect: Vec<f32> = (0..9)
            .map(|i| {
                (slots[0].data[i] + slots[1].data[i]) + (slots[2].data[i] + slots[3].data[i])
            })
            .collect();
        tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(4, 2));
        assert_eq!(slots[0].data, expect);
    }

    #[test]
    fn byte_accounting_ratios() {
        let mut c = CommStats::default();
        // 10 steady steps of a 128×128 matrix at rank 16, 3 cross edges
        for _ in 0..10 {
            c.record_lowrank(3, 16 * 128 * 4, 128 * 128 * 4);
        }
        assert!((c.steady_reduction_vs_dense() - 8.0).abs() < 1e-12);
        // one dense refresh drags the end-to-end ratio below 8
        c.record_refresh_dense(3, 128 * 128 * 4);
        assert!(c.reduction_vs_dense() < 8.0);
        assert!(c.reduction_vs_dense() > 1.0);
        let t = c.total_bytes();
        c.record_votes(3, 4);
        assert_eq!(c.total_bytes(), t + 15);
    }

    #[test]
    #[should_panic]
    fn mismatched_topology_is_rejected() {
        let mut slots = random_slots(4, 3, 13);
        tree_reduce_with(&mut slots, |m| &mut m.data[..], &Topology::new(8, 2));
    }
}
